"""Sharded-sweep throughput cell: devices=1 vs devices=8 on one host.

Self-contained so it can force ``--xla_force_host_platform_device_count=8``
BEFORE jax initializes — which is why ``perf_bench`` runs it as a subprocess
instead of importing it (the parent's single-device cells must keep seeing
one device, per the conftest convention). Prints one JSON object on stdout;
everything else goes to stderr.

Both cells run inside the same 8-device process: devices=1 is a 1-device
``cells`` mesh-free run on device 0, devices=8 shards the seed axis of the
same grid over all host devices, so the comparison isolates the scale-out
and not the env. Execution wall time ONLY: the sweep runner is built and
compiled once per cell via the engine's own ``_build_runner`` and the timing
loop re-executes the jitted runner (``run_sweep`` would rebuild fresh jit
closures per call and the timing would be dominated by retracing).

`PYTHONPATH=src python -m benchmarks.shard_bench`
"""
from __future__ import annotations

import json
import os
import sys
import time

_FORCE = "--xla_force_host_platform_device_count=8"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {_FORCE}"

import jax  # noqa: E402  (env must be set before jax initializes)

from repro.configs.base import FLConfig  # noqa: E402
from repro.core import sweep  # noqa: E402
from repro.data.synthetic import make_fmnist_like  # noqa: E402
from repro.federated.partition import sorted_label_shards  # noqa: E402
from repro.models.logreg import logistic_regression  # noqa: E402

N, DIM, SEEDS, ROUNDS, REPS = 50, 128, tuple(range(8)), 30, 3


def _time_run(model, data, fl, devices):
    """Seconds per sweep execution at ``devices``, compile excluded.

    Builds the group runner once (the same executables ``run_sweep`` uses),
    then times REPS re-executions. The runner donates its state argument and
    returns same-shaped final states, so the timing loop ping-pongs them —
    each iteration feeds the previous iteration's output buffers back in,
    exactly the aliasing the donation exists for.
    """
    import jax.numpy as jnp

    from repro.core import sharding
    from repro.utils.tree import tree_size

    mesh = sharding.cell_mesh(devices) if devices > 1 else None
    point = sweep._stack_points([sweep.sweep_point_from_config(fl)])
    seeds_arr = jnp.asarray(SEEDS, jnp.int32)
    model_size = tree_size(model.init(jax.random.PRNGKey(0)))
    init_fn, runner = sweep._build_runner(
        model, fl, data, fl.method, noise_free=fl.noise_std == 0,
        model_size=model_size, mesh=mesh)
    states = init_fn(point, seeds_arr)
    states, hist = runner(point, states)  # warm-up: compile + execute
    jax.block_until_ready(hist)
    t0 = time.perf_counter()
    for _ in range(REPS):
        states, hist = runner(point, states)
    jax.block_until_ready((states, hist))
    return (time.perf_counter() - t0) / REPS


def main():
    x, y, xt, yt = make_fmnist_like(N * 24, N * 6, dim=DIM, seed=0)
    xs, ys = sorted_label_shards(x, y, N)
    xts, yts = sorted_label_shards(xt, yt, N)
    data = (xs, ys, xts, yts)
    model = logistic_regression(DIM, 10)
    fl = FLConfig(num_clients=N, clients_per_round=10, rounds=ROUNDS,
                  batch_size=20, lr0=0.3, method="ca_afl", eval_every=5)

    t1 = _time_run(model, data, fl, devices=1)
    t8 = _time_run(model, data, fl, devices=8)
    cells = len(SEEDS)
    payload = {
        "grid": f"1 config x {len(SEEDS)} seeds x T={ROUNDS} "
                f"(N={N}, dim={DIM})",
        "host_devices": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "devices1_seconds": t1,
        "devices8_seconds": t8,
        "devices1_cells_per_second": cells / t1,
        "devices8_cells_per_second": cells / t8,
        "speedup_devices8": t1 / t8,
    }
    print(f"[shard_bench] devices=1 {t1:.2f}s, devices=8 {t8:.2f}s "
          f"-> {payload['speedup_devices8']:.2f}x on {os.cpu_count()} cores",
          file=sys.stderr)
    json.dump(payload, sys.stdout)
    sys.stdout.write("\n")
    return payload


if __name__ == "__main__":
    main()
