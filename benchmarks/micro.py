"""Microbenchmarks: selection PMF scalability + kernel throughput.

Selection: the PS computes rho (eq. 9) + Gumbel-top-K each round; this bench
sweeps N to show the control-plane scales far past the paper's N=100.
Kernels: wall-time of the jnp reference vs. the Pallas kernel in interpret
mode is meaningless on CPU, so kernels are benchmarked as (a) correctness
checks and (b) roofline-model bytes/flops — the numbers the TPU deployment
would see.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.poe import ca_afl_logits
from repro.core.selection import gumbel_topk_mask
from repro.utils.roofline import HBM_BW

RESULTS = Path(__file__).resolve().parent / "results"


def bench_selection(ns=(100, 1_000, 10_000, 100_000, 1_000_000)):
    out = {}
    for n in ns:
        key = jax.random.PRNGKey(0)
        lam = jax.nn.softmax(jax.random.normal(key, (n,)))
        h = jnp.exp(0.5 * jax.random.normal(jax.random.fold_in(key, 1), (n,)))
        k = max(n // 10, 1)

        @jax.jit
        def select(key, lam, h):
            return gumbel_topk_mask(key, ca_afl_logits(lam, h, 8.0), k)

        select(key, lam, h).block_until_ready()  # compile
        t0 = time.perf_counter()
        reps = 20
        for i in range(reps):
            select(jax.random.fold_in(key, i), lam, h).block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        out[str(n)] = dt * 1e3
        print(f"  selection N={n:>9,}: {dt * 1e3:8.2f} ms/round")
    return out


def kernel_roofline_model():
    """Ideal bytes/flops of each Pallas kernel on its production shapes —
    what the fusion SAVES vs the unfused composition."""
    rows = {}
    # aircomp: N=100 clients x M=7850 (paper) and a 1B-param update
    for tag, n, m in (("paper", 100, 7850), ("1b_update", 40, 1_000_000_000)):
        fused = n * m * 4 + m * 4 + m * 4          # read X + z, write out
        unfused = (3 * n * m + 4 * m) * 4           # scale, add, noise passes
        rows[f"aircomp_{tag}"] = {
            "fused_bytes": fused, "unfused_bytes": unfused,
            "traffic_saving": 1 - fused / unfused,
            "t_mem_fused_ms": fused / HBM_BW * 1e3,
        }
    # flash attention: granite prefill tile
    b, h, s, d = 1, 48, 32768, 128
    qkv = 3 * b * h * s * d * 2
    scores_roundtrip = b * h * s * s * 4 * 2       # unfused writes+reads P
    rows["flash_attention_32k"] = {
        "fused_bytes": qkv + b * h * s * d * 2,
        "unfused_bytes": qkv + scores_roundtrip + b * h * s * d * 2,
        "flops": 4 * b * h * s * s * d / 2,        # causal half
    }
    rows["flash_attention_32k"]["traffic_saving"] = 1 - (
        rows["flash_attention_32k"]["fused_bytes"]
        / rows["flash_attention_32k"]["unfused_bytes"])
    # rmsnorm: one residual row-block
    r, dd = 256 * 4096, 6144
    rows["rmsnorm"] = {
        "fused_bytes": r * dd * 2 * 2,
        "unfused_bytes": r * dd * 2 * 4,
        "traffic_saving": 0.5,
    }
    for k, v in rows.items():
        print(f"  {k:22s} traffic saving {v['traffic_saving']:.0%}")
    return rows


def main():
    print("[micro] selection scalability")
    sel = bench_selection()
    print("[micro] kernel roofline model")
    kern = kernel_roofline_model()
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "micro.json").write_text(json.dumps(
        {"selection_ms": sel, "kernels": kern}, indent=2))


if __name__ == "__main__":
    main()
