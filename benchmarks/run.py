"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Order: paper-figure reproduction (Figs. 2-3, reduced-faithful by default;
--full for the paper's exact N=100/T=500/5-seed scale), microbenchmarks,
then the roofline table assembled from whatever dry-run results exist.
"""
from __future__ import annotations

import sys


def main():
    full = "--full" in sys.argv
    from benchmarks import micro, paper_figs, roofline_table

    print("=" * 72)
    print("BENCH 1/5: paper Figs. 2-3 reproduction (CA-AFL vs baselines)")
    print("=" * 72)
    checks = paper_figs.main(full=full)
    failed = [k for k, v in checks.items()
              if k.startswith("claim_") and v is False]
    if failed:
        print(f"!! claims not reproduced this run: {failed}")

    print("=" * 72)
    print("BENCH 2/5: microbenchmarks (selection scalability, kernel model)")
    print("=" * 72)
    micro.main()

    print("=" * 72)
    print("BENCH 3/5: roofline table from dry-run artifacts")
    print("=" * 72)
    roofline_table.main()

    print("=" * 72)
    print("BENCH 4/5: beyond-paper ablations (noise robustness, fading)")
    print("=" * 72)
    from benchmarks import ablations
    ablations.main()

    print("=" * 72)
    print("BENCH 5/5: batched sweep-engine smoke (BENCH_sweep.json)")
    print("=" * 72)
    from benchmarks import sweep_smoke
    sweep_smoke.main()


if __name__ == "__main__":
    main()
