"""Hot-path perf benchmark: dense [N, model] reference vs selected-K rounds.

Measures, per cell (N × {dense, sparse, sparse+eval cadence}):

  - compile seconds (AOT ``lower().compile()``)
  - execution wall seconds and rounds/sec for a T-round jitted scan
  - peak live bytes of the compiled executable (XLA memory analysis:
    arguments + outputs + temporaries)

plus a **sharded-sweep throughput cell** (``benchmarks/shard_bench.py``, run
as a subprocess so its forced 8-device host platform cannot skew the
single-device cells): the same seeds-grid swept with ``run_sweep(devices=1)``
vs ``devices=8``, recording the scale-out speedup of the cells mesh.

Writes ``benchmarks/results/BENCH_perf.json`` — the artifact CI uploads per
commit, with the headline ``speedup_n100`` = hot path (sparse gather +
eval_every cadence) over the dense path at the paper's N=100, K=10.

`PYTHONPATH=src python -m benchmarks.perf_bench`
"""
from __future__ import annotations

import json
import platform
import subprocess
import sys
import time
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.simulator import (init_sim_state, make_param_round_fn)
from repro.core.sweep import sweep_point_from_config
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression
from repro.utils.tree import tree_size

RESULTS = Path(__file__).resolve().parent / "results"

DIM = 784  # the paper's FMNIST logreg: M = 7850

# (N, rounds): dense N=1000 pays 100x the sparse model work per round, so
# its timing loop is kept short; the per-round rate is what we report.
GRIDS = ((100, 40), (1000, 8))
K = 10


def _data(n):
    per_train, per_test = 20, 5
    x, y, xt, yt = make_fmnist_like(n * per_train, n * per_test, dim=DIM,
                                    seed=0)
    xs, ys = sorted_label_shards(x, y, n)
    xts, yts = sorted_label_shards(xt, yt, n)
    return xs, ys, xts, yts


def bench_cell(model, fl, data, dense: bool):
    point = sweep_point_from_config(fl)
    state = init_sim_state(model, fl, jax.random.PRNGKey(0),
                           process=point.process)
    round_fn = make_param_round_fn(model, fl, data, tree_size(state.w),
                                   fl.method, dense=dense)

    def run(point, state):
        _, hist = jax.lax.scan(
            lambda s, t: round_fn(point, s, t), state,
            jnp.arange(fl.rounds))
        return hist

    t0 = time.perf_counter()
    compiled = jax.jit(run).lower(point, state).compile()
    compile_s = time.perf_counter() - t0

    jax.block_until_ready(compiled(point, state))  # warm-up execution
    # best-of-3: the cells feed ratio floors (quantized/sparse vs analog),
    # and a single timing window on a shared CI runner jitters +-10% — the
    # minimum is the least-contended estimate of the program's true cost
    exec_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(point, state))
        exec_s = min(exec_s, time.perf_counter() - t0)

    try:
        ma = compiled.memory_analysis()
        peak_bytes = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                         + ma.temp_size_in_bytes)
    except Exception:  # backend without memory stats
        peak_bytes = None
    return {
        "compile_seconds": compile_s,
        "exec_seconds": exec_s,
        "rounds_per_second": fl.rounds / exec_s,
        "peak_live_bytes": peak_bytes,
    }


def _write(payload):
    RESULTS.mkdir(parents=True, exist_ok=True)
    with open(RESULTS / "BENCH_perf.json", "w") as f:
        json.dump(payload, f, indent=2)


def main():
    model = logistic_regression(DIM, 10)
    payload = {
        "bench": "perf_bench",
        "model": f"logreg dim={DIM} (M={DIM * 10 + 10})",
        "clients_per_round": K,
        "jax_version": jax.__version__,
        "platform": platform.platform(),
        "device": jax.devices()[0].platform,
        "cells": {},
    }
    for n, rounds in GRIDS:
        data = _data(n)
        fl = FLConfig(num_clients=n, clients_per_round=K, rounds=rounds,
                      batch_size=50, method="ca_afl")
        cells = {
            "dense": bench_cell(model, fl, data, dense=True),
            "sparse": bench_cell(model, fl, data, dense=False),
            # the full hot path: sparse gather + eval cadence
            "sparse_eval10": bench_cell(
                model, FLConfig(**{**fl.__dict__, "eval_every": 10}), data,
                dense=False),
        }
        for name, row in cells.items():
            print(f"[perf_bench] N={n:5d} {name:13s} "
                  f"{row['rounds_per_second']:8.2f} rounds/s  "
                  f"compile {row['compile_seconds']:.2f}s  "
                  f"peak {row['peak_live_bytes'] or 0:>12,} B")
        cells["speedup_sparse"] = (cells["sparse"]["rounds_per_second"]
                                   / cells["dense"]["rounds_per_second"])
        cells["speedup_hot_path"] = (
            cells["sparse_eval10"]["rounds_per_second"]
            / cells["dense"]["rounds_per_second"])
        payload["cells"][f"n{n}"] = cells
        print(f"[perf_bench] N={n}: sparse {cells['speedup_sparse']:.1f}x, "
              f"hot path {cells['speedup_hot_path']:.1f}x over dense")

    payload["speedup_n100"] = payload["cells"]["n100"]["speedup_hot_path"]

    # ---- per-transport round throughput (N=100 hot path): the fused
    # quantize-aggregate and compress-aggregate passes must not tax the
    # round — acceptance floors are quantized AND sparse >= 0.8x analog
    # rounds/sec; digital is recorded for the energy-accounting trajectory
    # (its aggregation is the noise-free mean)
    data = _data(100)
    fl = FLConfig(num_clients=100, clients_per_round=K, rounds=40,
                  batch_size=50, method="ca_afl")
    tcells = {}
    for tr in ("analog", "quantized", "digital", "sparse"):
        row = bench_cell(model, replace(fl, transport=tr), data, dense=False)
        tcells[tr] = row
        print(f"[perf_bench] transport {tr:10s} "
              f"{row['rounds_per_second']:8.2f} rounds/s  "
              f"compile {row['compile_seconds']:.2f}s")
    for tr in ("quantized", "digital", "sparse"):
        tcells[f"{tr}_vs_analog"] = (tcells[tr]["rounds_per_second"]
                                     / tcells["analog"]["rounds_per_second"])
    payload["cells"]["transports_n100"] = tcells
    print(f"[perf_bench] quantized transport at "
          f"{tcells['quantized_vs_analog']:.2f}x, sparse at "
          f"{tcells['sparse_vs_analog']:.2f}x analog throughput")

    # ---- sharded-sweep scale-out cell (subprocess: needs its own 8-device
    # host platform, which must not leak into the cells above) -------------
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.shard_bench"],
            capture_output=True, text=True, check=True,
            cwd=Path(__file__).resolve().parent.parent)
        shard = json.loads(proc.stdout)
        payload["cells"]["sharded_sweep"] = shard
        print(f"[perf_bench] sharded sweep: devices=8 "
              f"{shard['speedup_devices8']:.2f}x devices=1 "
              f"({shard['cpu_count']} cores)")
    except subprocess.CalledProcessError as e:
        # still write the already-measured cells before failing the job —
        # same artifact-first policy as the floors below
        print(f"[perf_bench] shard_bench failed:\n{e.stderr}", file=sys.stderr)
        payload["cells"]["sharded_sweep"] = {"error": e.stderr[-2000:]}
        _write(payload)
        raise

    # ---- population-scale control-plane cell (subprocess for the same
    # 8-device isolation): N-scaling of control_plane="sharded" up to 10^6
    # clients; popscale_bench itself enforces the O(N/D) per-device-memory
    # ceiling and fails the job on a replication regression ----------------
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.popscale_bench"],
            capture_output=True, text=True, check=True,
            cwd=Path(__file__).resolve().parent.parent)
        pop = json.loads(proc.stdout)
        payload["cells"]["popscale"] = pop
        big = max(pop["cells"].values(), key=lambda c: c["n_clients"])
        print(f"[perf_bench] popscale: N={big['n_clients']:,} at "
              f"{big['rounds_per_second']:.2f} rounds/s, "
              f"{big['control_bytes_per_client']:.1f} control B/client "
              f"(x{pop['per_client_bytes_ratio_largest_vs_smallest']:.2f} "
              "vs smallest N)")
    except subprocess.CalledProcessError as e:
        print(f"[perf_bench] popscale_bench failed:\n{e.stderr}",
              file=sys.stderr)
        payload["cells"]["popscale"] = {"error": e.stderr[-2000:]}
        _write(payload)
        raise

    # ---- contract-lint cell (ISSUE 9): the CI lint lane's exact command —
    # both layers, AST rules + jaxpr program analyzers — timed end to end
    # (subprocess, so its traces can't warm this process's jit caches). The
    # per-layer seconds come from the linter's own JSON report; the wall
    # ceiling is enforced with the other floors below so the lane stays
    # cheap enough to run on every commit.
    report = RESULTS / "lint-report.json"
    RESULTS.mkdir(parents=True, exist_ok=True)
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--jaxpr", "--json",
         str(report)],
        capture_output=True, text=True,
        cwd=Path(__file__).resolve().parent.parent)
    lint_wall = time.perf_counter() - t0
    lint_report = json.loads(report.read_text())
    payload["cells"]["lint"] = {
        "wall_seconds": lint_wall,
        "ast_seconds": lint_report["ast"]["seconds"],
        "jaxpr_seconds": lint_report["jaxpr"]["seconds"],
        "exit_code": proc.returncode,
        "violations": len(lint_report["ast"]["violations"]),
        "jaxpr_checks_failed": [c["name"] for c in
                                lint_report["jaxpr"]["checks"]
                                if not c["ok"]],
    }
    print(f"[perf_bench] contract lint: {lint_wall:.1f}s wall "
          f"(AST {lint_report['ast']['seconds']:.1f}s, jaxpr "
          f"{lint_report['jaxpr']['seconds']:.1f}s), "
          f"exit {proc.returncode}")

    _write(payload)
    print(f"[perf_bench] wrote {RESULTS / 'BENCH_perf.json'} "
          f"(speedup_n100={payload['speedup_n100']:.2f}x)")
    # acceptance floors, enforced AFTER the artifact is written so a failing
    # run still leaves the measured cells behind for diagnosis:
    # (1) the hot path must stay >= 3x the dense reference at the paper's
    # N=100, K=10; (2) the sharded sweep must deliver >= 3x at devices=8 —
    # but only where the host can physically provide it (8 forced host
    # devices on a 2-core runner cap out near 2x regardless of the sharding
    # layer, so small hosts record the number without failing the job)
    if payload["speedup_n100"] < 3.0:
        raise SystemExit(
            f"hot-path regression: speedup_n100 = "
            f"{payload['speedup_n100']:.2f}x < 3x acceptance floor")
    q_ratio = payload["cells"]["transports_n100"]["quantized_vs_analog"]
    if q_ratio < 0.8:
        raise SystemExit(
            f"quantized-transport regression: {q_ratio:.2f}x analog round "
            "throughput < 0.8x acceptance floor (fused quantize-aggregate "
            "pass is taxing the round)")
    s_ratio = payload["cells"]["transports_n100"]["sparse_vs_analog"]
    if s_ratio < 0.8:
        raise SystemExit(
            f"sparse-transport regression: {s_ratio:.2f}x analog round "
            "throughput < 0.8x acceptance floor (top-k compress + "
            "error-feedback carry is taxing the round)")
    shard = payload["cells"]["sharded_sweep"]
    if (shard["cpu_count"] or 0) >= 8 and shard["speedup_devices8"] < 3.0:
        raise SystemExit(
            f"sharded-sweep regression: devices=8 speedup "
            f"{shard['speedup_devices8']:.2f}x < 3x floor on "
            f"{shard['cpu_count']} cores")
    lint = payload["cells"]["lint"]
    if lint["exit_code"] != 0:
        raise SystemExit(
            f"contract lint failed (exit {lint['exit_code']}): "
            f"{lint['violations']} violation(s), jaxpr checks failed: "
            f"{lint['jaxpr_checks_failed']}\n{proc.stdout[-2000:]}")
    if lint["wall_seconds"] > 60.0:
        raise SystemExit(
            f"contract-lint ceiling: {lint['wall_seconds']:.1f}s wall > 60s "
            "— the jaxpr analyzer harness grew too expensive for a "
            "per-commit lane")
    return payload


if __name__ == "__main__":
    main()
