"""Reproduction of the paper's Fig. 2 (metrics vs rounds) and Fig. 3
(metrics vs energy) — one benchmark per paper figure.

Full paper scale: N=100 clients, K=40, logreg M=7850, T=500, 5 seeds
(``--full``). The default is a reduced-but-faithful setting that finishes on
CPU in minutes and preserves every qualitative claim.
"""
from __future__ import annotations

import json
from dataclasses import replace
from pathlib import Path

import numpy as np

from repro.configs.base import FLConfig
from repro.core.sweep import run_sweep
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

RESULTS = Path(__file__).resolve().parent / "results"


METHODS_FULL = [
    ("fedavg", dict(method="fedavg")),
    ("afl", dict(method="afl")),
    ("gca", dict(method="gca")),
    ("ca_afl_c2", dict(method="ca_afl", energy_C=2.0)),
    ("ca_afl_c8", dict(method="ca_afl", energy_C=8.0)),
]


def make_setup(full: bool, seed: int = 0):
    if full:
        x, y, xt, yt = make_fmnist_like(60_000, 10_000, dim=784, seed=seed)
        n, k, t, bs, dim = 100, 40, 500, 50, 784
    else:
        x, y, xt, yt = make_fmnist_like(6_000, 1_500, dim=128, seed=seed)
        n, k, t, bs, dim = 40, 16, 150, 32, 128
    xs, ys = sorted_label_shards(x, y, n)
    xts, yts = sorted_label_shards(xt, yt, n)
    fl = FLConfig(num_clients=n, clients_per_round=k, rounds=t, batch_size=bs,
                  lr0=0.1 if full else 0.3, lr_decay=0.998 if full else 0.995,
                  ascent_lr=8e-3 if full else 2e-2)
    model = logistic_regression(dim=dim, num_classes=10)
    return model, fl, (xs, ys, xts, yts)


def run(full: bool = False, seeds=(0, 1, 2), out_tag: str = "paper"):
    model, fl_base, data = make_setup(full)
    if full:
        seeds = (0, 1, 2, 3, 4)  # the paper averages five runs
    # One sweep call: the seed axis is vmapped and the two CA-AFL C-values
    # share a compilation, so the 5-config × |seeds| grid compiles 4
    # executables (fedavg/afl/gca/ca_afl) instead of one per cell.
    specs = [(name, replace(fl_base, **kw)) for name, kw in METHODS_FULL]
    result = run_sweep(model, data, specs, seeds=seeds)
    rows = {}
    for name, _ in METHODS_FULL:
        hist = result.mean_history(name)
        rows[name] = {
            "avg_acc": np.asarray(hist.avg_acc).tolist(),
            "worst_acc": np.asarray(hist.worst_acc).tolist(),
            "std_acc": np.asarray(hist.std_acc).tolist(),
            "energy": np.asarray(hist.energy).tolist(),
            "num_scheduled": np.asarray(hist.num_scheduled).tolist(),
        }
        print(f"  {name:12s} final: avg={rows[name]['avg_acc'][-1]:.3f} "
              f"worst={rows[name]['worst_acc'][-1]:.3f} "
              f"std={rows[name]['std_acc'][-1]:.3f} "
              f"E={rows[name]['energy'][-1]:.2e} J "
              f"sched={np.mean(rows[name]['num_scheduled']):.1f}")
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"fig2_fig3_{out_tag}.json"
    out.write_text(json.dumps(rows))
    return rows


def validate_claims(rows) -> dict:
    """The paper's quantitative claims, checked on this run."""
    e = {k: v["energy"][-1] for k, v in rows.items()}
    worst = {k: np.mean(v["worst_acc"][-10:]) for k, v in rows.items()}
    std = {k: np.mean(v["std_acc"][-10:]) for k, v in rows.items()}
    avg = {k: np.mean(v["avg_acc"][-10:]) for k, v in rows.items()}
    return {
        # Fig. 3 headline: CA-AFL(C=8) ~ 1/3 the energy of AFL
        "c8_energy_fraction_of_afl": e["ca_afl_c8"] / e["afl"],
        "claim_3x_energy_savings": bool(e["ca_afl_c8"] < 0.45 * e["afl"]),
        # Fig. 2b: robust methods > FedAvg/GCA on worst-client acc
        "worst_acc": worst,
        "claim_ca_afl_beats_fedavg_worst": bool(
            worst["ca_afl_c8"] > worst["fedavg"]),
        "claim_ca_afl_beats_gca_worst": bool(worst["ca_afl_c8"] > worst["gca"]),
        # Fig. 2b: CA-AFL ~ AFL worst acc (negligible degradation)
        "c8_worst_gap_to_afl": float(worst["afl"] - worst["ca_afl_c8"]),
        # Fig. 2c: CA-AFL std below FedAvg/GCA
        "claim_std_below_fedavg": bool(std["ca_afl_c8"] < std["fedavg"]),
        # Fig. 2a: comparable average accuracy across methods
        "avg_acc_spread": float(max(avg.values()) - min(avg.values())),
        # C-interpolation: energy(C=8) < energy(C=2) < energy(C=0)=AFL-ish
        "claim_c_monotone_energy": bool(
            e["ca_afl_c8"] < e["ca_afl_c2"] < e["afl"]),
    }


def main(full: bool = False):
    print(f"[paper_figs] reproducing Figs. 2-3 (full={full}) ...")
    rows = run(full=full, out_tag="full" if full else "reduced")
    checks = validate_claims(rows)
    print(json.dumps(checks, indent=2, default=str))
    (RESULTS / f"claims_{'full' if full else 'reduced'}.json").write_text(
        json.dumps(checks, indent=2, default=str))
    return checks


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
