"""Beyond-paper ablations.

1. AirComp receiver-noise robustness: the paper sets z=0 in its experiments
   ("we did not impose any power control mechanism"); here we sweep the
   injected AWGN std of eq. (10) and measure the accuracy degradation —
   quantifying how much receiver noise CA-AFL tolerates.
2. Frequency-selective fading: the paper uses flat block fading (one
   coefficient per client per round). With independent per-sub-carrier
   draws, eq. (6)'s harmonic mean concentrates across clients — the
   client-to-client energy spread (the resource CA-AFL exploits) shrinks,
   and with it the achievable savings. This ablation measures that shrink.

`PYTHONPATH=src python -m benchmarks.ablations`
"""
from __future__ import annotations

import json
from dataclasses import replace
from pathlib import Path

import numpy as np

from repro.configs.base import FLConfig
from repro.core.simulator import run_simulation
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

RESULTS = Path(__file__).resolve().parent / "results"


def _setup(seed=0):
    x, y, xt, yt = make_fmnist_like(6000, 1500, dim=128, seed=seed)
    xs, ys = sorted_label_shards(x, y, 40)
    xts, yts = sorted_label_shards(xt, yt, 40)
    fl = FLConfig(num_clients=40, clients_per_round=16, rounds=150,
                  batch_size=32, lr0=0.3, lr_decay=0.995, ascent_lr=2e-2,
                  method="ca_afl", energy_C=8.0)
    return logistic_regression(128, 10), fl, (xs, ys, xts, yts)


def noise_robustness():
    model, fl, data = _setup()
    out = {}
    for std in (0.0, 1e-3, 1e-2, 3e-2, 1e-1):
        h = run_simulation(model, replace(fl, noise_std=std), data)
        out[str(std)] = {
            "avg_acc": float(np.mean(np.asarray(h.avg_acc)[-10:])),
            "worst_acc": float(np.mean(np.asarray(h.worst_acc)[-10:])),
        }
        print(f"  noise_std={std:7.3f}: avg={out[str(std)]['avg_acc']:.3f} "
              f"worst={out[str(std)]['worst_acc']:.3f}")
    return out


def frequency_selective():
    model, fl, data = _setup()
    out = {}
    for flat in (True, False):
        rows = {}
        for method, c in (("afl", 0.0), ("ca_afl", 8.0)):
            h = run_simulation(
                model, replace(fl, method=method, energy_C=c,
                               flat_fading=flat), data)
            rows[method] = float(h.energy[-1])
        out["flat" if flat else "freq_selective"] = {
            **rows, "saving": 1 - rows["ca_afl"] / rows["afl"]}
        print(f"  {'flat' if flat else 'freq-selective':15s}: "
              f"AFL={rows['afl']:.2e} J CA-AFL={rows['ca_afl']:.2e} J "
              f"saving={out['flat' if flat else 'freq_selective']['saving']:.0%}")
    return out


def main():
    print("[ablation 1] AirComp receiver-noise robustness (eq. 10 z-sweep)")
    noise = noise_robustness()
    print("[ablation 2] flat vs frequency-selective fading (eq. 6)")
    fading = frequency_selective()
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "ablations.json").write_text(json.dumps(
        {"noise_robustness": noise, "fading": fading}, indent=2))


if __name__ == "__main__":
    main()
