"""Beyond-paper ablations, driven by the batched sweep engine.

1. AirComp receiver-noise robustness: the paper sets z=0 in its experiments
   ("we did not impose any power control mechanism"); here we sweep the
   injected AWGN std of eq. (10) and measure the accuracy degradation —
   quantifying how much receiver noise CA-AFL tolerates. The whole noise
   grid is one ``vmap`` axis: one compilation for all five settings.
2. Frequency-selective fading: the paper uses flat block fading (one
   coefficient per client per round). With independent per-sub-carrier
   draws, eq. (6)'s harmonic mean concentrates across clients — the
   client-to-client energy spread (the resource CA-AFL exploits) shrinks,
   and with it the achievable savings. This ablation measures that shrink.
   (flat vs. selective is structural, so this one is 2 methods × 2 fading
   structures = 4 compilations — still one ``run_sweep`` call.)

`PYTHONPATH=src python -m benchmarks.ablations`
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import FLConfig
from repro.core.sweep import expand_grid, run_sweep
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

RESULTS = Path(__file__).resolve().parent / "results"

NOISE_GRID = (0.0, 1e-3, 1e-2, 3e-2, 1e-1)


def _setup(seed=0):
    x, y, xt, yt = make_fmnist_like(6000, 1500, dim=128, seed=seed)
    xs, ys = sorted_label_shards(x, y, 40)
    xts, yts = sorted_label_shards(xt, yt, 40)
    fl = FLConfig(num_clients=40, clients_per_round=16, rounds=150,
                  batch_size=32, lr0=0.3, lr_decay=0.995, ascent_lr=2e-2,
                  method="ca_afl", energy_C=8.0)
    return logistic_regression(128, 10), fl, (xs, ys, xts, yts)


def noise_robustness():
    model, fl, data = _setup()
    specs = expand_grid(
        fl, variants={str(std): {"noise_std": std} for std in NOISE_GRID})
    summary = run_sweep(model, data, specs, seeds=(0,)).summary(window=10)
    out = {}
    for std in NOISE_GRID:
        row = summary[str(std)]
        out[str(std)] = {"avg_acc": row["avg_acc"],
                         "worst_acc": row["worst_acc"]}
        print(f"  noise_std={std:7.3f}: avg={row['avg_acc']:.3f} "
              f"worst={row['worst_acc']:.3f}")
    return out


def frequency_selective():
    model, fl, data = _setup()
    specs = expand_grid(
        fl,
        variants={"afl": {"method": "afl", "energy_C": 0.0},
                  "ca_afl": {"method": "ca_afl", "energy_C": 8.0}},
        scenarios=("default", "freq_selective"))
    summary = run_sweep(model, data, specs, seeds=(0,)).summary(window=10)
    out = {}
    for flat in (True, False):
        suffix = "" if flat else "@freq_selective"
        rows = {m: summary[m + suffix]["energy"] for m in ("afl", "ca_afl")}
        tag = "flat" if flat else "freq_selective"
        out[tag] = {**rows, "saving": 1 - rows["ca_afl"] / rows["afl"]}
        print(f"  {tag:15s}: "
              f"AFL={rows['afl']:.2e} J CA-AFL={rows['ca_afl']:.2e} J "
              f"saving={out[tag]['saving']:.0%}")
    return out


def main():
    print("[ablation 1] AirComp receiver-noise robustness (eq. 10 z-sweep)")
    noise = noise_robustness()
    print("[ablation 2] flat vs frequency-selective fading (eq. 6)")
    fading = frequency_selective()
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "ablations.json").write_text(json.dumps(
        {"noise_robustness": noise, "fading": fading}, indent=2))


if __name__ == "__main__":
    main()
