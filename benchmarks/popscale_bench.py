"""Population-scale control-plane bench: N up to 10^6 clients on 8 devices.

The ISSUE-7 acceptance cell: the ``control_plane="sharded"`` runner must
scale the CONTROL plane O(N/D) per device — before the fix the replicated
discipline materialized every per-round [N] draw (channels, availability,
selection scores, λ, ChanState) on every device, so a million-client round
allocated ~10^6-row buffers D times over.

Self-contained so it can force ``--xla_force_host_platform_device_count=8``
BEFORE jax initializes; ``perf_bench`` runs it as a subprocess (same policy
as ``shard_bench``). Prints one JSON object on stdout; rest to stderr.

Per N in the scaling grid it records:

  - compile seconds (AOT ``lower().compile()`` of the T-round scan)
  - execution wall seconds and rounds/sec
  - ``temp_size_in_bytes`` from XLA memory analysis — the per-program
    scratch the control plane actually allocates, and the quantity that was
    O(N·D) under replication
  - ``control_bytes_per_client`` = temp bytes / N

and asserts the ceiling: temp bytes per client at the largest N must stay
within ``CEILING_FACTOR`` of the smallest-N cell (linear O(N) total ==
O(N/D) per device — a replicated [N] buffer per device would show up as a
~D-fold step), plus an absolute per-device byte ceiling at N=10^6.

ISSUE-8 columns: ``lam_history_bytes_per_client`` (the λ history output
under the strided ``record_lambda_every`` recorder; asserted against the
exact ``ceil(T/E) * 4`` bytes/client budget — the dense recorder costs
``T * 4``) and a ``projection`` micro-bench timing the psum-bisection
``project_simplex_sharded`` at FIXED N/D over a growing device count: per-
device projection time must stay flat as N grows (the point of replacing
the gather+sort), with a CPU-oversubscription-aware ceiling since the 8
forced host devices share this container's few cores.

`PYTHONPATH=src python -m benchmarks.popscale_bench`
"""
from __future__ import annotations

import json
import os
import sys
import time

_FORCE = "--xla_force_host_platform_device_count=8"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {_FORCE}"

import jax  # noqa: E402  (env must be set before jax initializes)
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import FLConfig  # noqa: E402
from repro.core import sharding  # noqa: E402
from repro.models.logreg import logistic_regression  # noqa: E402

# tiny model + shard-size-2 synthetic rows: the point is the CONTROL plane
# (draws/selection/λ), not client compute, so N dominates every buffer
DIM, CLS, SHARD, ROUNDS, K = 16, 4, 2, 2, 32
GRID = (10_000, 100_000, 1_000_000)
CEILING_FACTOR = 1.6   # per-client temp bytes may drift, not step ~D-fold
DEVICE_CEILING_BYTES = 2 << 30   # 2 GiB/device at N=10^6
# strided λ recorder: one [N] snapshot per E rounds -> ceil(T/E) * 4 B/client
LAM_EVERY = ROUNDS
LAM_BUDGET_PER_CLIENT = -(-ROUNDS // LAM_EVERY) * 4
# psum-bisection micro-bench: fixed rows/device, growing device count
PROJ_LOCAL, PROJ_DEVS, PROJ_REPS = 1 << 17, (1, 2, 4, 8), 20


def _data(n, key):
    x = jax.random.normal(key, (n, SHARD, DIM), jnp.float32)
    y = jax.random.randint(jax.random.fold_in(key, 1), (n, SHARD), 0, CLS)
    return x, y, x, y


def bench_n(model, n):
    fl = FLConfig(num_clients=n, clients_per_round=K, rounds=ROUNDS,
                  batch_size=SHARD, local_steps=1, num_subcarriers=1,
                  method="ca_afl", lr0=0.1, ascent_lr=1e-2,
                  control_plane="sharded", eval_every=ROUNDS,
                  record_lambda_every=LAM_EVERY)
    mesh = sharding.client_mesh(jax.device_count())
    data = _data(n, jax.random.PRNGKey(0))
    fn, point, sharded = sharding.build_control_sharded_runner(
        model, fl, data, mesh)
    key = jax.random.PRNGKey(0)

    t0 = time.perf_counter()
    compiled = fn.lower(point, key, *sharded).compile()
    compile_s = time.perf_counter() - t0

    out = compiled(point, key, *sharded)
    jax.block_until_ready(out)  # warm-up
    t0 = time.perf_counter()
    jax.block_until_ready(compiled(point, key, *sharded))
    exec_s = time.perf_counter() - t0

    # the strided recorder's actual output cost (0 at record_lambda_every=0)
    lam_bytes = (0 if isinstance(out.lam, tuple)
                 else int(out.lam.size) * out.lam.dtype.itemsize)
    ma = compiled.memory_analysis()
    temp = int(ma.temp_size_in_bytes)
    row = {
        "n_clients": n,
        "devices": mesh.size,
        "compile_seconds": compile_s,
        "exec_seconds": exec_s,
        "rounds_per_second": ROUNDS / exec_s,
        "temp_bytes": temp,
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "control_bytes_per_client": temp / n,
        "temp_bytes_per_device": temp // mesh.size,
        "lam_history_bytes_per_client": lam_bytes / n,
    }
    print(f"[popscale_bench] N={n:>9,}  {row['rounds_per_second']:7.2f} "
          f"rounds/s  compile {compile_s:5.1f}s  "
          f"temp {temp:>14,} B  ({row['control_bytes_per_client']:7.1f} "
          "B/client)", file=sys.stderr)
    return row


def bench_projection():
    """Time ONE psum-bisection projection at fixed rows/device while the
    device count (and therefore N) grows: O(N/D + iters) means the per-call
    wall time must stay flat — the gather+sort it replaced grew O(N log N)
    on every device."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    rows = []
    for d in PROJ_DEVS:
        n = PROJ_LOCAL * d
        mesh = sharding.client_mesh(d)
        ax = mesh.axis_names[0]
        fn = jax.jit(shard_map(
            lambda v, ax=ax: sharding.project_simplex_sharded(
                v, axis_name=ax),
            mesh=mesh, in_specs=P(ax), out_specs=P(ax), check_rep=False))
        v = jax.device_put(
            jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32),
            NamedSharding(mesh, P(ax)))
        jax.block_until_ready(fn(v))  # compile + warm
        t0 = time.perf_counter()
        out = None
        for _ in range(PROJ_REPS):
            out = fn(v)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / PROJ_REPS
        rows.append({"devices": d, "n_clients": n, "n_local": PROJ_LOCAL,
                     "projection_seconds": dt})
        print(f"[popscale_bench] projection D={d}  N={n:>9,}  "
              f"{dt * 1e3:7.2f} ms/call", file=sys.stderr)
    return rows


def main():
    model = logistic_regression(DIM, CLS)
    cells = [bench_n(model, n) for n in GRID]
    proj = bench_projection()
    small, large = cells[0], cells[-1]
    ratio = (large["control_bytes_per_client"]
             / small["control_bytes_per_client"])
    proj_ratio = (proj[-1]["projection_seconds"]
                  / proj[0]["projection_seconds"])
    # the 8 forced host devices time-share this container's cores, so a
    # literal flat-time assertion would measure oversubscription, not the
    # algorithm; scale the ceiling by the compute deficit (the 4.0 slack
    # also covers per-iteration psum sync when device threads contend for
    # one core — a 1-CPU container measures ~3.2x over the 8x ideal)
    cpu = os.cpu_count() or 1
    proj_ceiling = 4.0 * max(1.0, PROJ_DEVS[-1] / cpu)
    payload = {
        "bench": "popscale_bench",
        "grid": f"N in {list(GRID)} x T={ROUNDS} (dim={DIM}, K={K}, "
                "ca_afl, sharded control plane)",
        "host_devices": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "cells": {f"n{c['n_clients']}": c for c in cells},
        "per_client_bytes_ratio_largest_vs_smallest": ratio,
        "ceiling_factor": CEILING_FACTOR,
        "record_lambda_every": LAM_EVERY,
        "lam_budget_bytes_per_client": LAM_BUDGET_PER_CLIENT,
        "projection": {f"d{p['devices']}": p for p in proj},
        "projection_seconds_ratio_largest_vs_smallest": proj_ratio,
        "projection_ceiling_factor": proj_ceiling,
    }
    json.dump(payload, sys.stdout)
    sys.stdout.write("\n")
    # ceilings AFTER the artifact is printed (artifact-first policy)
    if ratio > CEILING_FACTOR:
        raise SystemExit(
            f"control-plane memory regression: temp bytes/client grew "
            f"{ratio:.2f}x from N={small['n_clients']:,} to "
            f"N={large['n_clients']:,} (> {CEILING_FACTOR}x ceiling — a "
            "replicated [N] buffer would step ~devices-fold)")
    if large["temp_bytes_per_device"] > DEVICE_CEILING_BYTES:
        raise SystemExit(
            f"per-device ceiling exceeded at N={large['n_clients']:,}: "
            f"{large['temp_bytes_per_device']:,} B/device > "
            f"{DEVICE_CEILING_BYTES:,} B")
    for c in cells:
        if c["lam_history_bytes_per_client"] > LAM_BUDGET_PER_CLIENT + 1e-9:
            raise SystemExit(
                f"λ-history budget exceeded at N={c['n_clients']:,}: "
                f"{c['lam_history_bytes_per_client']:.2f} B/client > "
                f"{LAM_BUDGET_PER_CLIENT} (strided ceil(T/E)*4 budget; the "
                "dense recorder would cost T*4 = "
                f"{ROUNDS * 4} B/client)")
    if proj_ratio > proj_ceiling:
        raise SystemExit(
            f"projection wall time grew {proj_ratio:.2f}x from D=1 to "
            f"D={PROJ_DEVS[-1]} at fixed N/D (> {proj_ceiling:.1f}x "
            "oversubscription-aware ceiling) — the psum-bisection must be "
            "O(N/D + iters) per device, not O(N)")
    return payload


if __name__ == "__main__":
    main()
