"""Population-scale control-plane bench: N up to 10^6 clients on 8 devices.

The ISSUE-7 acceptance cell: the ``control_plane="sharded"`` runner must
scale the CONTROL plane O(N/D) per device — before the fix the replicated
discipline materialized every per-round [N] draw (channels, availability,
selection scores, λ, ChanState) on every device, so a million-client round
allocated ~10^6-row buffers D times over.

Self-contained so it can force ``--xla_force_host_platform_device_count=8``
BEFORE jax initializes; ``perf_bench`` runs it as a subprocess (same policy
as ``shard_bench``). Prints one JSON object on stdout; rest to stderr.

Per N in the scaling grid it records:

  - compile seconds (AOT ``lower().compile()`` of the T-round scan)
  - execution wall seconds and rounds/sec
  - ``temp_size_in_bytes`` from XLA memory analysis — the per-program
    scratch the control plane actually allocates, and the quantity that was
    O(N·D) under replication
  - ``control_bytes_per_client`` = temp bytes / N

and asserts the ceiling: temp bytes per client at the largest N must stay
within ``CEILING_FACTOR`` of the smallest-N cell (linear O(N) total ==
O(N/D) per device — a replicated [N] buffer per device would show up as a
~D-fold step), plus an absolute per-device byte ceiling at N=10^6.

`PYTHONPATH=src python -m benchmarks.popscale_bench`
"""
from __future__ import annotations

import json
import os
import sys
import time

_FORCE = "--xla_force_host_platform_device_count=8"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {_FORCE}"

import jax  # noqa: E402  (env must be set before jax initializes)
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import FLConfig  # noqa: E402
from repro.core import sharding  # noqa: E402
from repro.models.logreg import logistic_regression  # noqa: E402

# tiny model + shard-size-2 synthetic rows: the point is the CONTROL plane
# (draws/selection/λ), not client compute, so N dominates every buffer
DIM, CLS, SHARD, ROUNDS, K = 16, 4, 2, 2, 32
GRID = (10_000, 100_000, 1_000_000)
CEILING_FACTOR = 1.6   # per-client temp bytes may drift, not step ~D-fold
DEVICE_CEILING_BYTES = 2 << 30   # 2 GiB/device at N=10^6


def _data(n, key):
    x = jax.random.normal(key, (n, SHARD, DIM), jnp.float32)
    y = jax.random.randint(jax.random.fold_in(key, 1), (n, SHARD), 0, CLS)
    return x, y, x, y


def bench_n(model, n):
    fl = FLConfig(num_clients=n, clients_per_round=K, rounds=ROUNDS,
                  batch_size=SHARD, local_steps=1, num_subcarriers=1,
                  method="ca_afl", lr0=0.1, ascent_lr=1e-2,
                  control_plane="sharded", eval_every=ROUNDS)
    mesh = sharding.client_mesh(jax.device_count())
    data = _data(n, jax.random.PRNGKey(0))
    fn, point, sharded = sharding.build_control_sharded_runner(
        model, fl, data, mesh)
    key = jax.random.PRNGKey(0)

    t0 = time.perf_counter()
    compiled = fn.lower(point, key, *sharded).compile()
    compile_s = time.perf_counter() - t0

    jax.block_until_ready(compiled(point, key, *sharded))  # warm-up
    t0 = time.perf_counter()
    jax.block_until_ready(compiled(point, key, *sharded))
    exec_s = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    temp = int(ma.temp_size_in_bytes)
    row = {
        "n_clients": n,
        "devices": mesh.size,
        "compile_seconds": compile_s,
        "exec_seconds": exec_s,
        "rounds_per_second": ROUNDS / exec_s,
        "temp_bytes": temp,
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "control_bytes_per_client": temp / n,
        "temp_bytes_per_device": temp // mesh.size,
    }
    print(f"[popscale_bench] N={n:>9,}  {row['rounds_per_second']:7.2f} "
          f"rounds/s  compile {compile_s:5.1f}s  "
          f"temp {temp:>14,} B  ({row['control_bytes_per_client']:7.1f} "
          "B/client)", file=sys.stderr)
    return row


def main():
    model = logistic_regression(DIM, CLS)
    cells = [bench_n(model, n) for n in GRID]
    small, large = cells[0], cells[-1]
    ratio = (large["control_bytes_per_client"]
             / small["control_bytes_per_client"])
    payload = {
        "bench": "popscale_bench",
        "grid": f"N in {list(GRID)} x T={ROUNDS} (dim={DIM}, K={K}, "
                "ca_afl, sharded control plane)",
        "host_devices": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "cells": {f"n{c['n_clients']}": c for c in cells},
        "per_client_bytes_ratio_largest_vs_smallest": ratio,
        "ceiling_factor": CEILING_FACTOR,
    }
    json.dump(payload, sys.stdout)
    sys.stdout.write("\n")
    # ceilings AFTER the artifact is printed (artifact-first policy)
    if ratio > CEILING_FACTOR:
        raise SystemExit(
            f"control-plane memory regression: temp bytes/client grew "
            f"{ratio:.2f}x from N={small['n_clients']:,} to "
            f"N={large['n_clients']:,} (> {CEILING_FACTOR}x ceiling — a "
            "replicated [N] buffer would step ~devices-fold)")
    if large["temp_bytes_per_device"] > DEVICE_CEILING_BYTES:
        raise SystemExit(
            f"per-device ceiling exceeded at N={large['n_clients']:,}: "
            f"{large['temp_bytes_per_device']:,} B/device > "
            f"{DEVICE_CEILING_BYTES:,} B")
    return payload


if __name__ == "__main__":
    main()
