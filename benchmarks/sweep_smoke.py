"""CI benchmark smoke: a tiny sweep through the batched engine.

Runs a 2-method × 3-seed × 2-scenario grid small enough for a CI runner
(<1 min on 2 CPU cores), records wall time, compile count and the summary
table, and writes ``benchmarks/results/BENCH_sweep.json`` — the artifact CI
uploads so the performance trajectory of the sweep engine accrues per-commit.

`PYTHONPATH=src python -m benchmarks.sweep_smoke`
"""
from __future__ import annotations

import os
import platform
import time
from pathlib import Path

import jax

from repro.configs.base import FLConfig
from repro.core import sweep
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

RESULTS = Path(__file__).resolve().parent / "results"


def main():
    # SWEEP_SMOKE_DEVICES=auto|<int> shards the seed axis over a cells mesh
    # (the CI multi-device lane sets it together with
    # XLA_FLAGS=--xla_force_host_platform_device_count=8); unset = the
    # single-device program, bit-identical either way.
    devices = os.environ.get("SWEEP_SMOKE_DEVICES") or None
    if devices and devices != "auto":
        devices = int(devices)
    x, y, xt, yt = make_fmnist_like(1200, 300, dim=48, seed=0)
    xs, ys = sorted_label_shards(x, y, 16)
    xts, yts = sorted_label_shards(xt, yt, 16)
    data = (xs, ys, xts, yts)
    model = logistic_regression(48, 10)
    fl = FLConfig(num_clients=16, clients_per_round=6, rounds=40,
                  batch_size=16, lr0=0.3, lr_decay=0.995, ascent_lr=2e-2)

    specs = sweep.expand_grid(
        fl,
        variants={"afl": {"method": "afl"},
                  "ca_afl_c8": {"method": "ca_afl", "energy_C": 8.0},
                  # the sharded control plane rides the sweep too (ISSUE 8):
                  # under the multi-device lane this cell factors onto the
                  # 2-D cells × clients mesh (psum-bisection λ projection +
                  # hierarchical top-k inside the donated group jit), so the
                  # composed path can't rot; single-device it runs the
                  # unsharded reference program of the same discipline
                  "ca_afl_sharded": {"method": "ca_afl",
                                     "control_plane": "sharded"}},
        # battery_constrained exercises the temporal ChannelProcess path
        # (core/dynamics.py): one extra compilation group per method, and the
        # BENCH_sweep.json artifact gains live min_battery/avail_count columns
        scenarios=("default", "heterogeneous_pathloss", "battery_constrained"))
    seeds = (0, 1, 2)

    sweep.reset_trace_log()
    t0 = time.perf_counter()
    result = sweep.run_sweep(model, data, specs, seeds=seeds, devices=devices)
    jax.block_until_ready([h.avg_acc for h in result.histories])
    wall_s = time.perf_counter() - t0

    cells = len(specs) * len(seeds)
    print(f"[sweep_smoke] {len(specs)} configs x {len(seeds)} seeds "
          f"({cells} cells) in {wall_s:.1f}s, "
          f"{sweep.trace_count()} compilations, "
          f"devices={devices or 1}")
    summary = result.summary(window=5)
    for lbl, row in summary.items():
        print(f"  {lbl:28s} worst_acc={row['worst_acc']:.3f} "
              f"E={row['energy']:.2e} J")

    RESULTS.mkdir(parents=True, exist_ok=True)
    payload = result.save_json(
        RESULTS / "BENCH_sweep.json", window=5,
        extra={
            "bench": "sweep_smoke",
            "cells": cells,
            "wall_seconds": wall_s,
            "compilations": sweep.trace_count(),
            "cells_per_compilation": cells / max(sweep.trace_count(), 1),
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "device": jax.devices()[0].platform,
        })
    print(f"[sweep_smoke] wrote {RESULTS / 'BENCH_sweep.json'} "
          f"(pareto: {payload['pareto_energy_vs_worst_acc']})")
    return payload


if __name__ == "__main__":
    main()
