"""Assemble the §Roofline table from the dry-run JSON results."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"

COLS = ("t_compute_s", "t_memory_s", "t_collective_s")


def load(mesh: str = "16x16", step: str = "fl"):
    rows = []
    for fn in sorted(RESULTS.glob(f"*__{mesh}__{step}.json")):
        d = json.loads(fn.read_text())
        rows.append(d)
    return rows


def fmt_ms(x):
    return f"{x * 1e3:9.1f}"


def table(mesh: str = "16x16", step: str = "fl") -> str:
    rows = load(mesh, step)
    out = [f"### Mesh {mesh} (step={step})\n",
           "| arch | shape | fit | t_comp ms | t_mem ms | t_coll ms | "
           "bound | useful | roofline-MFU |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        r = d["roofline"]
        out.append(
            f"| {d['arch']} | {d['shape']} | "
            f"{'Y' if d.get('fits_hbm') else 'N'} | "
            f"{fmt_ms(r['t_compute_s'])} | {fmt_ms(r['t_memory_s'])} | "
            f"{fmt_ms(r['t_collective_s'])} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_mfu']:.3f} |")
    return "\n".join(out)


def main():
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh)
        if rows:
            print(table(mesh))
            print()
            n_fit = sum(1 for d in rows if d.get("fits_hbm"))
            print(f"{len(rows)} pairs compiled on {mesh}; {n_fit} fit HBM\n")


if __name__ == "__main__":
    main()
