"""Client-side local update (production tier).

A "client" at production scale is one slice of the mesh ``data`` axis: its
local batch lives on its devices and its local gradient is computed there.
The per-client weighting that realizes CA-AFL's selection (and AirComp's /K)
is folded into the loss as per-example weights, so the data-axis gradient
reduction GSPMD inserts *is* the over-the-air aggregation (DESIGN.md §2).
"""
from __future__ import annotations


import jax.numpy as jnp


def client_weights(mask: jnp.ndarray, clients_per_example: jnp.ndarray,
                   k: float) -> jnp.ndarray:
    """Per-example weights realizing (1/K)·Σ_{i∈D} grad_i under a global mean.

    mask: [N] 0/1 selection; clients_per_example: [B] client id of each
    example. The loss is a *mean* over B examples, so each selected client's
    contribution must be re-scaled by B/(B_i·K) where B_i = B/N examples per
    client. weights[b] = mask[client[b]] * N / K.
    """
    n = mask.shape[0]
    return mask[clients_per_example] * (n / k)


def local_loss(model, params, batch, ctx=None):
    """Weighted local loss — grads of this are the superposed update."""
    return model.loss_fn(params, batch, ctx)
