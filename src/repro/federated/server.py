"""Host-side parameter server: selection, λ bookkeeping, energy ledger.

The PS orchestrates the jit'd production round (``rounds.py``). Everything it
handles is O(N) scalars per round — channel states, selection probabilities,
λ, energy — the paper's dedicated control channel. The heavy lifting (local
grads + over-the-air aggregation) happens inside the compiled round on the
mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.channel import (draw_channels_scenario, effective_channel,
                                scenario_from_config)
from repro.core.dro import lambda_ascent
from repro.core.energy import round_energy
from repro.core.selection import gumbel_topk_mask, select_clients
from repro.federated.rounds import make_fl_round
from repro.utils.tree import tree_size


@dataclass
class ServerState:
    params: object
    opt_state: object
    lam: jnp.ndarray
    round: int = 0
    energy_joules: float = 0.0
    history: List[Dict] = field(default_factory=list)


class ParameterServer:
    """CA-AFL parameter server for the production tier."""

    def __init__(self, model, optimizer, fl: FLConfig, *, ctx=None,
                 jit_round: bool = True, seed: int = 0):
        self.model = model
        self.fl = fl
        self.key = jax.random.PRNGKey(seed)
        self.round_fn = make_fl_round(
            model, optimizer, fl.num_clients, fl.clients_per_round,
            noise_std=fl.noise_std, ctx=ctx)
        if jit_round:
            self.round_fn = jax.jit(self.round_fn)
        self.optimizer = optimizer
        # Same parameterized physical layer as the simulator/sweep tier, so
        # scenario knobs (shadowing, per-client pathloss, floor) behave
        # identically across tiers.
        self.scenario = scenario_from_config(fl)

    def init_state(self, key) -> ServerState:
        params = self.model.init(key)
        self._model_size = tree_size(params)
        return ServerState(
            params=params,
            opt_state=self.optimizer.init(params),
            lam=jnp.full((self.fl.num_clients,), 1.0 / self.fl.num_clients),
        )

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def step(self, state: ServerState, batch: Dict) -> ServerState:
        """One CA-AFL round. batch carries tokens/labels/client_ids (+modal)."""
        fl = self.fl
        k_chan, k_sel, k_noise, k_asc = jax.random.split(self._next_key(), 4)

        # --- physical layer + selection (host-side, control channel) -------
        h = effective_channel(draw_channels_scenario(
            k_chan, self.scenario, fl.num_clients, fl.num_subcarriers))
        mask = select_clients(fl.method, k_sel, state.lam, h,
                              fl.clients_per_round, C=fl.energy_C,
                              gca=fl.gca)

        # --- compiled round on the mesh ------------------------------------
        params, opt_state, metrics = self.round_fn(
            state.params, state.opt_state, batch, mask, k_noise)

        # --- energy ledger (eqs. 3-6; only the selected set transmits) -----
        e_round = float(round_energy(h, mask, self._model_size, fl.psi, fl.tau))

        # --- λ-ascent on a uniform K-subset (Alg. 1 lines 10-15) -----------
        amask = gumbel_topk_mask(k_asc, jnp.zeros((fl.num_clients,)),
                                 fl.clients_per_round)
        lam = lambda_ascent(state.lam, metrics.client_losses, amask, fl.ascent_lr)

        state.history.append({
            "round": state.round,
            "loss": float(metrics.loss),
            "energy_j": e_round,
            "num_scheduled": int(jnp.sum(mask)),
            "worst_client_loss": float(jnp.max(metrics.client_losses)),
            "grad_norm": float(metrics.grad_norm),
        })
        return ServerState(
            params=params, opt_state=opt_state, lam=lam,
            round=state.round + 1,
            energy_joules=state.energy_joules + e_round,
            history=state.history,
        )

    def run(self, state: ServerState, batches, rounds: int,
            log_every: int = 10, log_fn: Optional[Callable] = print):
        for t in range(rounds):
            state = self.step(state, next(batches))
            if log_fn and (t % log_every == 0 or t == rounds - 1):
                h = state.history[-1]
                log_fn(
                    f"round {h['round']:4d} loss={h['loss']:.4f} "
                    f"worst={h['worst_client_loss']:.4f} "
                    f"E={state.energy_joules:.3e} J "
                    f"sched={h['num_scheduled']}")
        return state
