"""Host-side parameter server: selection, λ bookkeeping, energy ledger.

The PS orchestrates the jit'd production round (``rounds.py``). Everything it
handles is O(N) scalars per round — channel states, selection probabilities,
λ, energy — the paper's dedicated control channel. The heavy lifting (local
grads + over-the-air aggregation) happens inside the compiled round on the
mesh.

Cross-tier contract: the per-round PRNG discipline is IDENTICAL to the
simulator's ``round_fn`` — one 7-way split of the server key into
``(key, k_chan, k_sel, k_batch, k_noise, k_asel, k_abatch)`` with the same
role order (the two batch keys are unused here because batches arrive from
the data pipeline). With matching keys/initial state the two tiers draw the
same channels, the same selection masks and the same ascent sets, which is
what ``tests/test_cross_tier.py`` pins so the tiers cannot drift silently.
The temporal ``ChannelProcess`` (``core/dynamics.py``) is evolved host-side
with the same fold-in streams as the simulator's scan carry.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.flatten_util import ravel_pytree

from repro.configs.base import FLConfig
from repro.core.channel import (draw_channels_scenario,
                                draw_channels_scenario_ids, effective_channel,
                                scenario_from_config)
from repro.core import dro
from repro.core.dro import lambda_ascent
from repro.core.dynamics import (commit_process, init_chan_state,
                                 init_chan_state_ids, process_from_config,
                                 step_process)
from repro.core import transport as transport_mod
from repro.core.selection import (EXACT_K_METHODS, availability_logits,
                                  gumbel_topk_mask, select_clients,
                                  select_clients_sparse)
from repro.federated.rounds import (FLRoundMetrics, add_awgn, make_fl_round,
                                    make_grad_norm_probe, per_client_losses)
from repro.kernels.aircomp.ops import aircomp_aggregate_flat
from repro.optim import apply_updates
from repro.utils.tree import tree_size


@dataclass
class ServerState:
    params: object
    opt_state: object
    lam: jnp.ndarray
    round: int = 0
    energy_joules: float = 0.0
    history: List[Dict] = field(default_factory=list)
    chan_state: Any = ()  # ChanState for temporal scenarios, () otherwise
    # strided λ snapshots on the FLConfig.record_lambda_every cadence
    # (rounds t % E == 0; empty at E=0) — the production-tier mirror of the
    # simulator's SimHistory.lam recorder
    lam_snaps: List = field(default_factory=list)
    # sparse transport only: per-client error-feedback memory [N, P] (the
    # production-tier mirror of SimState.ef_resid); () for other schemes
    ef_resid: Any = ()
    # cumulative downlink share of energy_joules (which is the TOTAL ledger)
    dl_energy_joules: float = 0.0


class ParameterServer:
    """CA-AFL parameter server for the production tier."""

    def __init__(self, model, optimizer, fl: FLConfig, *, ctx=None,
                 jit_round: bool = True, seed: int = 0,
                 reuse_probe_grads: bool = True, mesh=None):
        self.model = model
        self.fl = fl
        self.key = jax.random.PRNGKey(seed)
        # Population sharding on the production tier (core/sharding.py): a
        # 1-D clients mesh makes step() place each batch with its example
        # axis split across the devices, so the jitted round's per-client
        # block compute (and the GCA probe's [N, P] gradient stack)
        # partitions under XLA's SPMD pass. Placement metadata only — the
        # compiled program's semantics are unchanged, and mesh=None (or
        # size 1) is a no-op.
        self.mesh = mesh if (mesh is not None and mesh.size > 1) else None
        # Uplink transport (core/transport.py): validates the scheme and
        # promotes the knobs. The digital-OFDMA scheme decodes each payload
        # orthogonally — no superposition, hence NO receiver AWGN on the
        # aggregate — so its compiled rounds are built noise-free; analog and
        # quantized keep eq. (10)'s z-term.
        self.transport = transport_mod.transport_from_config(fl)
        self._round_noise = 0.0 if fl.transport == "digital" else fl.noise_std
        quantized = fl.transport == "quantized"
        sparse = fl.transport == "sparse"
        # the quantized/sparse transports' round is ALWAYS the fused
        # compressed-delta aggregate (_make_quant_apply/_make_sparse_apply
        # below) — the dense round and the selected-K gather round would be
        # dead objects, so they are not built for them (there is no dense
        # fallback: the delta probe needs the canonical
        # one-block-per-client batch layout).
        self.round_fn = None
        self._gather_round = None
        if not (quantized or sparse):
            self.round_fn = make_fl_round(
                model, optimizer, fl.num_clients, fl.clients_per_round,
                noise_std=self._round_noise, ctx=ctx)
            # the selected-K gather round (hot-path contract): used for
            # exact-K methods whenever the batch has the canonical block
            # layout (checked host-side per step; dense round_fn fallback)
            if fl.method in EXACT_K_METHODS:
                self._gather_round = make_fl_round(
                    model, optimizer, fl.num_clients, fl.clients_per_round,
                    noise_std=self._round_noise, ctx=ctx, gather_k=True)
            if jit_round:
                self.round_fn = jax.jit(self.round_fn)
                if self._gather_round is not None:
                    self._gather_round = jax.jit(self._gather_round)
        self.optimizer = optimizer
        # Same parameterized physical layer as the simulator/sweep tier, so
        # scenario knobs (shadowing, per-client pathloss, floor) behave
        # identically across tiers; ditto the temporal ChannelProcess.
        self.scenario = scenario_from_config(fl)
        self.process = process_from_config(fl)
        # control_plane="sharded": the cross-tier contract tracks the
        # simulator's per-id fold_in streams (core/channel.py) — the PS is
        # single-host, so its id vector is simply the full population
        if fl.control_plane not in ("replicated", "sharded"):
            raise ValueError(
                f"unknown control_plane {fl.control_plane!r}; "
                "pick 'replicated' or 'sharded'")
        self._ids = (jnp.arange(fl.num_clients, dtype=jnp.int32)
                     if fl.control_plane == "sharded" else None)
        self._model_size = None  # resolved lazily from the params pytree
        # GCA needs per-client gradient norms BEFORE selection: a dedicated
        # jitted probe at the current params (fixes the former ValueError).
        # With reuse_probe_grads (default) the probe also returns each
        # client's mean loss and flat mean gradient, and the round's descent
        # update is their masked flat aggregate — the probe IS the round's
        # gradient work (same batch, same params), so the former second
        # full forward+backward disappears. Costs an [N, P] f32 stack;
        # disable at true model scale.
        self._grad_probe = None
        self._reuse_probe_grads = reuse_probe_grads
        if fl.method == "gca":
            self._grad_probe = make_grad_norm_probe(
                model, fl.num_clients, ctx=ctx,
                with_grads=reuse_probe_grads or quantized or sparse)
            if not (quantized or sparse):  # else the fused delta apply runs
                self._gca_apply = self._make_gca_apply()
                if jit_round:
                    self._gca_apply = jax.jit(self._gca_apply)
            if jit_round:
                self._grad_probe = jax.jit(self._grad_probe)
        # Quantized/sparse transports: every client's payload is its SGD
        # delta −η·g_i (the simulator's w_i − w̄ at one local step), so the
        # server needs per-client gradients for ANY method — the same
        # with_grads probe GCA reuses. The masked fused aggregate of the
        # compressed deltas is applied directly (_make_quant_apply /
        # _make_sparse_apply); tests/test_cross_tier.py pins both against
        # one simulator round.
        self._delta_probe = None
        self._quant_apply = self._sparse_apply = None
        if quantized or sparse:
            import warnings
            warnings.warn(
                f"transport={fl.transport!r} applies the paper's SGD "
                "aggregation directly: per-client deltas are -eta*grad with "
                "eta = fl.lr0 * fl.lr_decay**round (matching the simulator "
                "tier); the passed optimizer's update rule is NOT used and "
                "its state passes through untouched", stacklevel=2)
            self._delta_probe = (self._grad_probe or make_grad_norm_probe(
                model, fl.num_clients, ctx=ctx, with_grads=True))
            apply_fn = (self._make_quant_apply() if quantized
                        else self._make_sparse_apply())
            if jit_round:
                if self._grad_probe is None:
                    self._delta_probe = jax.jit(self._delta_probe)
                apply_fn = jax.jit(apply_fn)
            if quantized:
                self._quant_apply = apply_fn
            else:
                self._sparse_apply = apply_fn
        # control-channel loss probe for rounds where NOBODY transmits
        # (battery/availability gating): the λ-ascent still needs f_i(w̄)
        self._loss_probe = lambda p, b: per_client_losses(
            model, p, b, fl.num_clients, ctx)
        if jit_round:
            self._loss_probe = jax.jit(self._loss_probe)

    def _make_gca_apply(self):
        """The probe-reuse descent: masked flat aggregate of the probe's
        per-client gradients (the same fused eq.-(10) shape as the
        simulator's hot path), AWGN with the dense round's key discipline,
        then the server optimizer."""
        opt, noise_std = self.optimizer, self._round_noise

        def apply_fn(params, opt_state, gflat, probe_losses, mask, key):
            k_sched = jnp.maximum(jnp.sum(mask), 1.0)
            agg = aircomp_aggregate_flat(
                gflat, mask, jnp.zeros((gflat.shape[1],), jnp.float32),
                noise_std=0.0, k=k_sched)
            grads = ravel_pytree(params)[1](agg)
            if noise_std:
                # identical per-leaf streams to the dense round's receiver
                # noise, so reuse changes nothing but the summation order
                grads = add_awgn(grads, key, noise_std / k_sched)
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)))
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            # the dense round's weighted loss == (1/K)·Σ_{i∈D} mean-loss_i,
            # which the probe already measured at w^t
            loss = jnp.sum(mask * probe_losses) / k_sched
            return params, opt_state, loss, gnorm

        return apply_fn

    def _make_quant_apply(self):
        """The quantized-transport round: each client's payload is its SGD
        delta −η·g_i reconstructed from the per-client grad probe (same
        batch, same params), stochastically rounded with the simulator's
        per-client fold_in streams, and the fused masked aggregate of the
        quantized deltas is added to the params directly — eq. (10) over
        quantized updates, numerically one simulator round at local_steps=1
        (pinned by ``tests/test_cross_tier.py``). The server optimizer is
        bypassed (its state passes through untouched): the quantized payload
        IS the applied update, as in the paper's model-averaging."""
        noise_std, tp = self._round_noise, self.transport
        n = self.fl.num_clients

        def apply_fn(params, gflat, probe_losses, mask, key, eta):
            k_sched = jnp.maximum(jnp.sum(mask), 1.0)
            flat, unravel = ravel_pytree(params)
            flat = flat.astype(jnp.float32)
            deltas = (-eta) * gflat
            z = (transport_mod.flat_awgn_like(key, params, jnp.float32)
                 if noise_std else None)
            new_flat = transport_mod.quantized_aggregate_flat_rows(
                flat, deltas, mask, jnp.arange(n), key,
                noise_std if noise_std else 0.0, tp.bits, k_sched, z=z)
            gnorm = jnp.sqrt(jnp.sum(jnp.square(new_flat - flat))) / eta
            loss = jnp.sum(mask * probe_losses) / k_sched
            return unravel(new_flat), loss, gnorm

        return apply_fn

    def _make_sparse_apply(self):
        """The sparse-transport round: each client's payload is its SGD delta
        −η·g_i plus its carried error-feedback residual, top-k compressed and
        aggregated in the fused masked eq. (10) pass
        (``transport.sparse_aggregate_flat_rows``) — numerically one
        simulator round at local_steps=1 (pinned by
        ``tests/test_cross_tier.py``). The dropped mass becomes the new
        residual for the transmitting clients; gated clients keep theirs.
        The server optimizer is bypassed exactly as in the quantized round."""
        noise_std = self._round_noise
        density = self.fl.sparse_density

        def apply_fn(params, gflat, probe_losses, mask, key, eta, resid):
            k_sched = jnp.maximum(jnp.sum(mask), 1.0)
            flat, unravel = ravel_pytree(params)
            flat = flat.astype(jnp.float32)
            deltas = (-eta) * gflat
            k_coords = transport_mod.sparse_k_coords(density, flat.shape[0])
            z = (transport_mod.flat_awgn_like(key, params, jnp.float32)
                 if noise_std else None)
            new_flat, new_resid = transport_mod.sparse_aggregate_flat_rows(
                flat, deltas, resid, mask, key,
                noise_std if noise_std else 0.0, k_coords, k_sched, z=z)
            gnorm = jnp.sqrt(jnp.sum(jnp.square(new_flat - flat))) / eta
            loss = jnp.sum(mask * probe_losses) / k_sched
            return unravel(new_flat), loss, gnorm, new_resid

        return apply_fn

    def _gather_layout_ok(self, batch) -> bool:
        """The gather round indexes block j as client j's examples: verify
        (host-side, pre-jit) the canonical ascending-contiguous layout the
        data pipeline produces. Any other layout falls back to the dense
        round — semantics first, the gather is only an optimization."""
        cids = np.asarray(batch["client_ids"])
        n = self.fl.num_clients
        if cids.shape[0] % n:
            return False
        return bool(
            (cids == np.repeat(np.arange(n), cids.shape[0] // n)).all())

    def _check_probe_layout(self, batch) -> None:
        """The grad-norm probe slices the batch into one equal-size block
        per client: verify (host-side, pre-jit) that every block is a single
        client and every client appears exactly once — a violating layout
        would silently attribute norms to the wrong clients."""
        cids = np.asarray(batch["client_ids"])
        n = self.fl.num_clients
        if cids.shape[0] % n:
            raise ValueError("GCA probe needs batch size divisible by N")
        blocks = cids.reshape(n, -1)
        if not (blocks == blocks[:, :1]).all() or \
                len(set(blocks[:, 0].tolist())) != n:
            raise ValueError(
                "GCA probe needs one contiguous equal-size block of examples "
                "per client (any client order), got mixed/missing clients")

    def init_state(self, key) -> ServerState:
        # identical key discipline to init_sim_state: model init from the
        # split child, ChanState from fold_in(k_init, 1) — so both tiers
        # seeded with the same key start from the same process state (and,
        # for a shared model, the same parameters)
        k_init, _ = jax.random.split(key)
        params = self.model.init(k_init)
        self._model_size = tree_size(params)
        chan_state = ()
        if self.process.temporal:
            k_cs = jax.random.fold_in(k_init, 1)
            if self._ids is not None:
                chan_state = init_chan_state_ids(
                    self.process, k_cs, self._ids, self.fl.num_subcarriers,
                    self.fl.flat_fading)
            else:
                chan_state = init_chan_state(
                    self.process, k_cs, self.fl.num_clients,
                    self.fl.num_subcarriers, self.fl.flat_fading)
        # sparse transport: error-feedback memory starts empty (the first
        # payload is the raw delta), same zeros-init as init_sim_state
        ef_resid = (jnp.zeros((self.fl.num_clients, self._model_size),
                              jnp.float32)
                    if self.fl.transport == "sparse" else ())
        return ServerState(
            params=params,
            opt_state=self.optimizer.init(params),
            lam=jnp.full((self.fl.num_clients,), 1.0 / self.fl.num_clients),
            chan_state=chan_state,
            ef_resid=ef_resid,
        )

    def step(self, state: ServerState, batch: Dict) -> ServerState:
        """One CA-AFL round. batch carries tokens/labels/client_ids (+modal)."""
        fl = self.fl
        if self._model_size is None:
            self._model_size = tree_size(state.params)
        if self.mesh is not None:
            # split the example axis over the clients mesh BEFORE the layout
            # checks/jit below — the device_put is lazy placement metadata,
            # the host-side np.asarray reads are unaffected
            from repro.core.sharding import shard_batch
            batch = shard_batch(batch, self.mesh)
        # identical role order to the simulator round (see module docstring);
        # k_batch/k_abatch are the simulator's data-sampling keys, unused here
        (self.key, k_chan, k_sel, _k_batch, k_noise, k_asel,
         _k_abatch) = jax.random.split(self.key, 7)

        # --- physical layer + selection (host-side, control channel);
        # step_process is the same tick the simulator's scan body runs ------
        if self.process.temporal:
            cs = state.chan_state
            pstep = step_process(k_chan, self.scenario, self.process, cs,
                                 fl.num_clients, fl.num_subcarriers,
                                 self._model_size, scheme=fl.transport,
                                 tp=self.transport, ids=self._ids,
                                 dl_num_tx=fl.clients_per_round)
            h, avail, eligible = pstep.h, pstep.avail, pstep.eligible
        elif self._ids is not None:
            h = effective_channel(draw_channels_scenario_ids(
                k_chan, self.scenario, self._ids, fl.num_subcarriers))
            avail = eligible = None
        else:
            h = effective_channel(draw_channels_scenario(
                k_chan, self.scenario, fl.num_clients, fl.num_subcarriers))
            avail = eligible = None

        idx = probe_losses = gflat = None
        if fl.method == "gca":
            self._check_probe_layout(batch)
            if self._reuse_probe_grads or self._delta_probe is not None:
                gnorms, probe_losses, gflat = self._grad_probe(
                    state.params, batch)
            else:
                gnorms = self._grad_probe(state.params, batch)
            mask = select_clients("gca", k_sel, state.lam, h,
                                  fl.clients_per_round, grad_norms=gnorms,
                                  gca=fl.gca, avail=eligible)
        else:
            # the same single top_k as the simulator tier: the mask for the
            # ledger/λ bookkeeping, the indices for the gather round
            mask, idx = select_clients_sparse(
                fl.method, k_sel, state.lam, h, fl.clients_per_round,
                C=fl.energy_C, avail=eligible, ids=self._ids)
            if self._delta_probe is not None:
                # quantized/sparse transport: per-client deltas to compress
                try:
                    self._check_probe_layout(batch)
                except ValueError as e:
                    raise ValueError(
                        f"transport={fl.transport!r} needs the canonical "
                        "one-contiguous-block-per-client batch layout for "
                        f"its per-client delta probe (no dense fallback): {e}"
                    ) from e
                _, probe_losses, gflat = self._delta_probe(
                    state.params, batch)

        # --- compiled round on the mesh ------------------------------------
        ef_resid = state.ef_resid
        if self._sparse_apply is not None and isinstance(ef_resid, tuple):
            # a hand-built ServerState (tests/tools) that skipped init_state:
            # error-feedback memory starts empty, same as init_state's zeros
            ef_resid = jnp.zeros((fl.num_clients, self._model_size),
                                 jnp.float32)
        if int(jnp.sum(mask)) == 0:
            # nothing transmits (drained batteries / empty availability):
            # the PS receives no superposition, so the global model must NOT
            # move (mirrors the simulator's empty-set guard) — only the
            # control-channel loss probe runs, for the λ-ascent below.
            # Error-feedback residuals also stay put: no payload left any
            # device, so there is no dropped mass to remember.
            params, opt_state = state.params, state.opt_state
            metrics = FLRoundMetrics(
                loss=jnp.zeros(()),
                client_losses=self._loss_probe(state.params, batch),
                grad_norm=jnp.zeros(()))
        elif self._delta_probe is not None:
            # quantized/sparse transport (any method): apply the fused
            # masked aggregate of the compressed per-client deltas; η
            # follows the simulator's decayed schedule at this round
            eta = fl.lr0 * (fl.lr_decay ** state.round)
            if self._sparse_apply is not None:
                params, loss, gnorm, ef_resid = self._sparse_apply(
                    state.params, gflat, probe_losses, mask, k_noise,
                    jnp.float32(eta), ef_resid)
            else:
                params, loss, gnorm = self._quant_apply(
                    state.params, gflat, probe_losses, mask, k_noise,
                    jnp.float32(eta))
            opt_state = state.opt_state
            metrics = FLRoundMetrics(
                loss=loss,
                client_losses=self._loss_probe(params, batch),
                grad_norm=gnorm)
        elif gflat is not None:
            # GCA probe-reuse: the probe's per-client gradients become the
            # round's descent update (same batch, same params — the former
            # second forward+backward was pure double work)
            params, opt_state, loss, gnorm = self._gca_apply(
                state.params, state.opt_state, gflat, probe_losses, mask,
                k_noise)
            metrics = FLRoundMetrics(
                loss=loss,
                client_losses=self._loss_probe(params, batch),
                grad_norm=gnorm)
        elif idx is not None and self._gather_round is not None \
                and self._gather_layout_ok(batch):
            params, opt_state, metrics = self._gather_round(
                state.params, state.opt_state, batch, mask, idx, k_noise)
        else:
            params, opt_state, metrics = self.round_fn(
                state.params, state.opt_state, batch, mask, k_noise)

        # --- energy ledger (only the selected set transmits, priced under
        # the configured uplink transport; analog is eqs. 3-6 verbatim).
        # Downlink: every receiver that can afford the listen window pays
        # for the broadcast — same recv/num_tx rule as the simulator tier,
        # an exact +0.0 at the default dl_rx_power=0 ----------------------
        e_round = float(transport_mod.round_energy(
            fl.transport, self.transport, h, mask, self._model_size,
            self.scenario))
        recv_count = (float(jnp.sum(pstep.recv)) if self.process.temporal
                      else float(fl.num_clients))
        e_dl = float(recv_count * transport_mod.downlink_energy(
            fl.transport, self.transport, self._model_size, self.scenario,
            num_tx=fl.clients_per_round))

        # --- temporal carry: battery depletion + process state -------------
        if self.process.temporal:
            chan_state = commit_process(pstep, cs, mask)
        else:
            chan_state = state.chan_state

        # --- λ-ascent on a uniform K-subset of the AVAILABLE clients -------
        amask = gumbel_topk_mask(
            k_asel, jnp.zeros((fl.num_clients,)) + availability_logits(avail),
            fl.clients_per_round, ids=self._ids)
        if avail is not None:
            amask = amask * avail
        # sharded-discipline configs project via the same psum-bisection as
        # the simulator's sharded round (local_rows), keeping the cross-tier
        # λ contract intact; replicated configs keep the sort-based path
        lam = lambda_ascent(state.lam, metrics.client_losses, amask,
                            fl.ascent_lr, local_rows=self._ids is not None)
        lam_max, lam_entropy, lam_ess = dro.lambda_summary(lam)

        row = {
            "round": state.round,
            "loss": float(metrics.loss),
            "energy_j": e_round + e_dl,
            "dl_energy_j": e_dl,
            "num_scheduled": int(jnp.sum(mask)),
            "worst_client_loss": float(jnp.max(metrics.client_losses)),
            "grad_norm": float(metrics.grad_norm),
            "lam_max": float(lam_max),
            "lam_entropy": float(lam_entropy),
            "lam_ess": float(lam_ess),
        }
        if self.process.temporal:
            row["avail_count"] = int(jnp.sum(eligible))
            row["min_battery"] = float(jnp.min(chan_state.battery))
        state.history.append(row)
        e_rec = fl.record_lambda_every
        if e_rec >= 1 and state.round % e_rec == 0:
            # the simulator's strided recorder, mirrored host-side: full λ
            # rows only every E rounds (never at E=0), O(T) summary stats in
            # every history row above
            state.lam_snaps.append(np.asarray(lam))
        return ServerState(
            params=params, opt_state=opt_state, lam=lam,
            round=state.round + 1,
            energy_joules=state.energy_joules + e_round + e_dl,
            history=state.history,
            chan_state=chan_state,
            lam_snaps=state.lam_snaps,
            ef_resid=ef_resid,
            dl_energy_joules=state.dl_energy_joules + e_dl,
        )

    def run(self, state: ServerState, batches, rounds: int,
            log_every: int = 10, log_fn: Optional[Callable] = print):
        for t in range(rounds):
            state = self.step(state, next(batches))
            if log_fn and (t % log_every == 0 or t == rounds - 1):
                h = state.history[-1]
                log_fn(
                    f"round {h['round']:4d} loss={h['loss']:.4f} "
                    f"worst={h['worst_client_loss']:.4f} "
                    f"E={state.energy_joules:.3e} J "
                    f"sched={h['num_scheduled']}")
        return state
