"""Client data partitioners.

The paper's partition (§IV-A): sort the 60000 training samples by label, split
into 100 equal shards, one shard per client — maximal label heterogeneity
(each client sees ~1 class). Dirichlet and IID partitioners are provided for
ablations.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def sorted_label_shards(
    x: np.ndarray, y: np.ndarray, num_clients: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Paper partition: sort by label, equal contiguous shards.

    Returns stacked arrays x_c [N, S, ...], y_c [N, S].
    """
    order = np.argsort(y, kind="stable")
    xs, ys = x[order], y[order]
    usable = (len(xs) // num_clients) * num_clients
    xs, ys = xs[:usable], ys[:usable]
    return (
        xs.reshape(num_clients, -1, *x.shape[1:]),
        ys.reshape(num_clients, -1),
    )


def iid_partition(x, y, num_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(x))
    xs, ys = x[order], y[order]
    usable = (len(xs) // num_clients) * num_clients
    return (
        xs[:usable].reshape(num_clients, -1, *x.shape[1:]),
        ys[:usable].reshape(num_clients, -1),
    )


def dirichlet_partition(x, y, num_clients: int, alpha: float = 0.3, seed: int = 0):
    """Dirichlet(alpha) label-skew partition with equal shard sizes.

    Each client draws a label distribution ~ Dir(alpha); samples are assigned
    greedily to match those distributions while keeping shards equal-sized
    (equal sizes keep the stacked [N, S, ...] layout jit-friendly).
    """
    rng = np.random.default_rng(seed)
    num_classes = int(y.max()) + 1
    shard = len(x) // num_clients
    by_class = [list(np.where(y == c)[0]) for c in range(num_classes)]
    for c in by_class:
        rng.shuffle(c)
    props = rng.dirichlet([alpha] * num_classes, size=num_clients)
    idx_out = np.empty((num_clients, shard), dtype=np.int64)
    ptr = [0] * num_classes
    for i in range(num_clients):
        want = (props[i] * shard).astype(int)
        want[0] += shard - want.sum()
        got = []
        for c in range(num_classes):
            take = min(want[c], len(by_class[c]) - ptr[c])
            got.extend(by_class[c][ptr[c] : ptr[c] + take])
            ptr[c] += take
        # fill any shortage from whatever classes still have samples
        c = 0
        while len(got) < shard:
            if ptr[c] < len(by_class[c]):
                got.append(by_class[c][ptr[c]])
                ptr[c] += 1
            c = (c + 1) % num_classes
        idx_out[i] = np.array(got[:shard])
    return x[idx_out], y[idx_out]
