"""The jit'd production FL round: CA-AFL at model scale.

One round = one compiled function on the mesh:

  1. every client (= slice of the ``data`` axis) computes its local gradient
     on its local batch;
  2. per-example weights (selection mask × N/K) scale each client's
     contribution, so the gradient reduction GSPMD inserts over ``data`` IS
     the over-the-air superposition of eq. (10) — AWGN z/K is injected into
     the aggregated update from a PRNG key;
  3. the server optimizer applies the aggregated update (plain SGD = the
     paper's model-averaging for one local step; AdamW is the beyond-paper
     server-optimizer option);
  4. per-client mean losses come back for the λ-ascent (the paper's "control
     channel" scalars).

Selection, λ bookkeeping, channel draws and the energy ledger are host-side
in ``server.py`` — O(N) scalars, exactly the paper's control-channel split.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.federated.client import client_weights
from repro.optim import apply_updates


class FLRoundMetrics(NamedTuple):
    loss: jnp.ndarray            # weighted global loss (selected set)
    client_losses: jnp.ndarray   # [N] per-client mean loss (control channel)
    grad_norm: jnp.ndarray


def make_fl_round(model, optimizer, num_clients: int, clients_per_round: int,
                  noise_std: float = 0.0, ctx=None, microbatches: int = 1,
                  fused_probe: bool = False, gather_k: bool = False):
    """Returns round_fn(params, opt_state, batch, mask, key) -> (params,
    opt_state, FLRoundMetrics).

    batch must carry "client_ids" [B] mapping each example to its client.
    ``microbatches`` > 1 runs gradient accumulation: the global batch is
    scanned in B/microbatches slices, dividing activation memory by the same
    factor at no recompute cost (each client's rows must be contiguous so
    every slice still covers all clients).

    ``gather_k=True`` builds the selected-K gather round instead (the
    production-tier leg of the simulator's hot-path contract):
    ``round_fn(params, opt_state, batch, mask, idx, key)`` takes the
    ``lax.top_k`` index vector [K] from
    ``selection.select_clients_sparse`` and computes the descent
    forward+backward on ONLY the K selected clients' example blocks — the
    same weighted-mean normalization, so the update equals the dense round
    to summation order, at K/N of its cost. Requires the canonical batch
    layout (block j = client j's B/N contiguous examples; the server
    verifies host-side and falls back to the dense round otherwise) and is
    exclusive with ``microbatches``/``fused_probe``. Gated slots
    (availability/battery) ride along with per-example weight 0.

    ``fused_probe`` (BEYOND-PAPER optimization, recorded in EXPERIMENTS.md
    §Perf): per-client losses for the λ-ascent come out of the *descent*
    forward (evaluated at w^t) instead of a second forward at w^{t+1} —
    Alg. 1 line 12 becomes one-round stale, removing ~1/3 of the round's
    compute and HBM traffic. The simulator validates that training curves
    are indistinguishable (tests/test_perf_variants.py).
    """
    if not 1 <= clients_per_round <= num_clients:
        raise ValueError(
            f"clients_per_round={clients_per_round} must be in "
            f"[1, num_clients={num_clients}]")
    if gather_k:
        if microbatches != 1 or fused_probe:
            raise ValueError(
                "gather_k is exclusive with microbatches/fused_probe: the "
                "gathered sub-batch covers only the selected clients")
        return _make_gather_round(model, optimizer, num_clients, noise_std,
                                  ctx)

    def weighted_loss_and_perex(p, b, mask):
        # K as the actual scheduled count: identical to the static
        # clients_per_round for exact-K selection, and the correct eq. (10)
        # normalizer when availability/battery gating (or GCA) schedules a
        # variable number of clients
        k_sched = jnp.maximum(jnp.sum(mask), 1.0)
        w = client_weights(mask, b["client_ids"], k_sched)
        if fused_probe:
            # one forward yields BOTH the weighted scalar and per-ex NLL
            per_ex = _per_example_nll(model, p, b, ctx)
            return jnp.mean(per_ex * w), per_ex
        b = dict(b)
        b["weights"] = w
        return model.loss_fn(p, b, ctx), jnp.zeros((w.shape[0],))

    def round_fn(params, opt_state, batch, mask, key):
        cids = batch["client_ids"]

        if microbatches == 1:
            (loss, per_ex), grads = jax.value_and_grad(
                lambda p: weighted_loss_and_perex(p, batch, mask),
                has_aux=True)(params)
        else:
            bsz = cids.shape[0]
            assert bsz % microbatches == 0
            mb = {k: v.reshape((microbatches, bsz // microbatches)
                               + v.shape[1:])
                  for k, v in batch.items()}

            def acc_step(carry, mslice):
                loss_a, grads_a = carry
                (l, pe), g = jax.value_and_grad(
                    lambda p: weighted_loss_and_perex(p, mslice, mask),
                    has_aux=True)(params)
                return (loss_a + l / microbatches,
                        jax.tree.map(lambda a, b_: a + b_ / microbatches,
                                     grads_a, g)), pe

            # accumulate in param dtype: an f32 accumulator would cost an
            # extra 2x params bytes per device at 235B scale (documented
            # precision trade-off; each term is pre-divided by microbatches)
            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                 params))
            (loss, grads), per_mb = jax.lax.scan(acc_step, zero, mb)
            per_ex = per_mb.reshape(-1)

        # --- AirComp receiver noise: z^(t)/K on the aggregated update, with
        # K the ACTUAL scheduled count — the same normalizer the gradient
        # weights use, mirroring the simulator's aircomp_aggregate ----------
        if noise_std:
            grads = add_awgn(grads, key,
                             noise_std / jnp.maximum(jnp.sum(mask), 1.0))

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)

        # --- control channel: per-client mean losses for the λ-ascent ------
        if fused_probe:
            # beyond-paper: stale (w^t) losses from the descent forward
            ones = jnp.ones_like(per_ex)
            sums = jnp.zeros((num_clients,), per_ex.dtype).at[cids].add(per_ex)
            cnts = jnp.zeros((num_clients,), per_ex.dtype).at[cids].add(ones)
            client_losses = sums / jnp.maximum(cnts, 1.0)
        else:
            # paper-faithful: a second forward on the NEW model — exactly
            # Alg. 1 line 12, which evaluates f_i(w̄^{t+1}) on the ascent set
            client_losses = per_client_losses(model, params, batch,
                                              num_clients, ctx,
                                              microbatches=microbatches)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        return params, opt_state, FLRoundMetrics(
            loss=loss, client_losses=client_losses, grad_norm=gnorm)

    return round_fn


def _make_gather_round(model, optimizer, num_clients: int, noise_std, ctx):
    """The selected-K production round (see ``make_fl_round(gather_k=True)``).

    The dense round's weighted mean over all B examples is
    ``(1/B)·Σ_b mask[cid_b]·(N/K)·nll_b`` — every unselected example
    contributes an exact 0 yet still pays its forward+backward. Here the K
    selected blocks are gathered first and the same sum runs over K·(B/N)
    examples with the identical ``/B`` normalizer, so loss and gradients
    match the dense round to summation order while the descent compute
    scales with the scheduled set. The λ-ascent probe
    (``per_client_losses``) stays full-population: Alg. 1's control channel
    needs every client reachable by the uniform ascent draw.
    """

    def round_fn(params, opt_state, batch, mask, idx, key):
        cids = batch["client_ids"]
        bsz = cids.shape[0]
        m = bsz // num_clients  # examples per client block
        k_sched = jnp.maximum(jnp.sum(mask), 1.0)
        rows = (idx[:, None] * m + jnp.arange(m)[None, :]).reshape(-1)
        sub = {name: v[rows] for name, v in batch.items()}
        # per-example weights of the gathered rows: the dense round's
        # mask[cid]·N/K, with gated slots (mask[idx] == 0) contributing 0
        w = jnp.repeat(mask[idx], m) * (num_clients / k_sched)

        def loss_fn(p):
            per_ex = _per_example_nll(model, p, sub, ctx)
            return jnp.sum(per_ex * w) / bsz

        loss, grads = jax.value_and_grad(loss_fn)(params)

        if noise_std:
            grads = add_awgn(grads, key, noise_std / k_sched)

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)

        client_losses = per_client_losses(model, params, batch, num_clients,
                                          ctx)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        return params, opt_state, FLRoundMetrics(
            loss=loss, client_losses=client_losses, grad_norm=gnorm)

    return round_fn


def add_awgn(grads, key, std: float):
    """z ~ N(0, std²) elementwise on every leaf (eq. 10's receiver noise).

    Leaves with a stacked leading (layer) axis generate noise one slice at a
    time via lax.scan — full-leaf threefry would otherwise hold double-
    buffered u32 bit tensors the size of the whole gradient (GiBs at 235B).
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))

    def noisy(g, k):
        if g.ndim >= 2 and g.shape[0] > 4:
            def body(i, gl):
                z = jax.random.normal(jax.random.fold_in(k, i),
                                      gl.shape, gl.dtype)
                return i + 1, gl + std * z

            _, out = jax.lax.scan(body, 0, g)
            return out
        return g + std * jax.random.normal(k, g.shape, g.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [noisy(g, k) for g, k in zip(leaves, keys, strict=True)])


def _per_example_nll(model, params, batch, ctx):
    # simulator-style models (e.g. models.logreg.logistic_regression_prod)
    # expose per_example_nll directly; architecture models go through cfg
    if hasattr(model, "per_example_nll"):
        return model.per_example_nll(params, batch)
    cfg = model.cfg
    if cfg.family == "vlm":
        logits = model.mod.forward(cfg, params, batch["tokens"], batch["images"], ctx)
    elif cfg.family == "audio":
        logits = model.mod.forward(cfg, params, batch["tokens"], batch["audio"], ctx)
    elif cfg.family == "moe":
        logits, _aux = model.mod.forward(cfg, params, batch["tokens"], ctx)
    else:
        logits = model.mod.forward(cfg, params, batch["tokens"], ctx)
    from repro.models.dense import per_token_nll
    return jnp.mean(per_token_nll(logits[:, :-1], batch["labels"][:, 1:]),
                    axis=-1)                                          # [B]


def per_client_losses(model, params, batch, num_clients: int, ctx=None,
                      microbatches: int = 1):
    """[N] mean loss per client: forward-only, per-example NLL, segment mean.

    This is Alg. 1's ascent-side evaluation f_i(w̄^{t+1}; ξ̃) for all clients
    at once (the server loop masks it down to the uniform ascent set U^(t)).
    Microbatched with the same slicing as the descent pass so the fp32 logits
    buffer stays 1/microbatches of the global batch.
    """
    cids = batch["client_ids"]
    bsz = cids.shape[0]

    if microbatches == 1:
        per_ex = _per_example_nll(model, params, batch, ctx)
        cid_flat = cids
    else:
        mb = {k: v.reshape((microbatches, bsz // microbatches) + v.shape[1:])
              for k, v in batch.items()}

        def probe(_, mslice):
            return None, _per_example_nll(model, params, mslice, ctx)

        _, per_mb = jax.lax.scan(probe, None, mb)
        per_ex = per_mb.reshape(-1)
        cid_flat = cids
    ones = jnp.ones_like(per_ex)
    sums = jnp.zeros((num_clients,), per_ex.dtype).at[cid_flat].add(per_ex)
    cnts = jnp.zeros((num_clients,), per_ex.dtype).at[cid_flat].add(ones)
    return sums / jnp.maximum(cnts, 1.0)


def make_grad_norm_probe(model, num_clients: int, ctx=None,
                         with_grads: bool = False):
    """GCA's control-channel probe: [N] per-client gradient norms at w^t.

    GCA selection needs ‖∇f_i(w^t)‖ BEFORE the round's mask exists, so this
    runs as a separate forward+backward per client — sequential via
    ``lax.scan`` (N small grads ≈ one full-batch grad in total compute,
    1/N of its activation memory). Requires the round's batch layout: each
    client's examples contiguous and equally sized (B % N == 0), as produced
    by the data pipeline — the reshape below slices clients apart.

    ``with_grads=True`` returns ``(norms [N], losses [N], grads [N, P])``
    with each client's mean gradient raveled to a flat f32 row and its mean
    loss at w^t: the probe's per-client gradients ARE the round's descent
    gradients (same batch, same params), so ``ParameterServer`` reuses them
    as the update via a masked flat aggregate instead of running a second
    full forward+backward. The price is holding the [N, P] stack the probe
    previously discarded — inherent to GCA (it computes all N gradients
    either way), but worth disabling at true model scale via the server's
    ``reuse_probe_grads=False``.
    """

    def client_loss(params, cbatch):
        return jnp.mean(_per_example_nll(model, params, cbatch, ctx))

    gfn = jax.grad(client_loss)
    vgfn = jax.value_and_grad(client_loss)

    def probe(params, batch):
        bsz = batch["client_ids"].shape[0]
        assert bsz % num_clients == 0, "probe needs equal per-client batches"
        mb = {k: v.reshape((num_clients, bsz // num_clients) + v.shape[1:])
              for k, v in batch.items()}

        def one(_, cbatch):
            g = gfn(params, cbatch)
            norm = jnp.sqrt(sum(
                jnp.sum(jnp.square(l.astype(jnp.float32)))
                for l in jax.tree_util.tree_leaves(g)))
            return None, norm

        def one_with_grads(_, cbatch):
            loss, g = vgfn(params, cbatch)
            flat = jnp.concatenate([
                l.astype(jnp.float32).reshape(-1)
                for l in jax.tree_util.tree_leaves(g)])
            return None, (jnp.sqrt(jnp.sum(jnp.square(flat))), loss, flat)

        # scatter by each block's OBSERVED client id, so contiguous-but-
        # permuted batches still attribute every norm to the right client
        obs = mb["client_ids"][:, 0]
        if not with_grads:
            _, norms = jax.lax.scan(one, None, mb)
            return jnp.zeros((num_clients,), norms.dtype).at[obs].set(norms)
        _, (norms, losses, flats) = jax.lax.scan(one_with_grads, None, mb)
        return (jnp.zeros((num_clients,), norms.dtype).at[obs].set(norms),
                jnp.zeros((num_clients,), losses.dtype).at[obs].set(losses),
                jnp.zeros(flats.shape, flats.dtype).at[obs].set(flats))

    return probe
