from repro.federated.partition import sorted_label_shards, dirichlet_partition, iid_partition
from repro.federated.client import client_weights
from repro.federated.rounds import make_fl_round, per_client_losses, FLRoundMetrics
from repro.federated.server import ParameterServer, ServerState
