"""AdamW (decoupled weight decay), fp32 moments regardless of param dtype."""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.optim.transform import GradientTransformation


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def adamw(
    learning_rate: Union[float, Callable],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> GradientTransformation:
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        lr = lr_fn(state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def _upd(m, v, p):
            u = -(lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps))
            if weight_decay and p is not None:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            upd = jax.tree.map(lambda m, v: _upd(m, v, None), mu, nu)
        else:
            upd = jax.tree.map(_upd, mu, nu, params)
        return upd, AdamWState(step=step, mu=mu, nu=nu)

    return GradientTransformation(init, update)
