from repro.optim.transform import GradientTransformation, chain, apply_updates
from repro.optim.sgd import sgd
from repro.optim.adamw import adamw
from repro.optim.clip import clip_by_global_norm
from repro.optim.schedules import exponential_decay, cosine_decay, constant

__all__ = [
    "GradientTransformation", "chain", "apply_updates",
    "sgd", "adamw", "clip_by_global_norm",
    "exponential_decay", "cosine_decay", "constant",
]
