"""SGD with optional momentum (the paper's local optimizer is plain SGD)."""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.optim.transform import GradientTransformation


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: object  # pytree or None


def sgd(
    learning_rate: Union[float, Callable],
    momentum: float = 0.0,
    nesterov: bool = False,
) -> GradientTransformation:
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)

    def init(params):
        mom = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params) if momentum else None
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def update(grads, state, params=None):
        lr = lr_fn(state.step)
        if momentum:
            new_mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state.momentum, grads
            )
            if nesterov:
                upd = jax.tree.map(
                    lambda m, g: -(lr * (momentum * m + g.astype(jnp.float32))), new_mom, grads
                )
            else:
                upd = jax.tree.map(lambda m: -(lr * m), new_mom)
        else:
            new_mom = None
            upd = jax.tree.map(lambda g: -(lr * g.astype(jnp.float32)), grads)
        return upd, SGDState(step=state.step + 1, momentum=new_mom)

    return GradientTransformation(init, update)
