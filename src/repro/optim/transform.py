"""Minimal optax-style gradient-transformation API (built from scratch)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax


class GradientTransformation(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (updates, state)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state, strict=True):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
