"""Global-norm gradient clipping."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.transform import GradientTransformation
from repro.utils.tree import tree_l2_norm


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params=None):
        norm = tree_l2_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return jax.tree.map(lambda g: g * scale, grads), state

    return GradientTransformation(init, update)
