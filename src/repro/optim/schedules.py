"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def exponential_decay(init_value: float, decay_rate: float):
    """The paper's descent schedule: eta^(t) = eta^(0) * decay^t (0.1, 0.998)."""

    def schedule(step):
        return jnp.asarray(init_value, jnp.float32) * jnp.power(
            jnp.asarray(decay_rate, jnp.float32), step
        )

    return schedule


def cosine_decay(init_value: float, decay_steps: int, alpha: float = 0.0):
    def schedule(step):
        frac = jnp.clip(step / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return init_value * ((1 - alpha) * cos + alpha)

    return schedule
