"""CLI for the contract linter.

    python -m repro.lint                  # layer-1 AST rules over src/repro
    python -m repro.lint --jaxpr          # + layer-2 jaxpr program analyzers
    python -m repro.lint --jaxpr-only     # layer 2 alone (traces compile)
    python -m repro.lint --json report.json   # machine-readable rule report

Exit status is nonzero iff any violation (or failed jaxpr check) is found,
so the CI lint lane can gate on it directly.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Contract linter: AST rules + jaxpr program analyzers.")
    parser.add_argument(
        "--root", type=Path, default=None,
        help="package root to lint (default: the installed src/repro)")
    parser.add_argument(
        "--jaxpr", action="store_true",
        help="also run the layer-2 jaxpr program analyzers (slower: traces)")
    parser.add_argument(
        "--jaxpr-only", action="store_true",
        help="run only the jaxpr analyzers, skip the AST rules")
    parser.add_argument(
        "--json", type=Path, default=None, metavar="PATH",
        help="write a JSON rule report to PATH")
    args = parser.parse_args(argv)

    from repro.lint import all_rules, default_root, run_lint

    root = args.root if args.root is not None else default_root()
    report: dict = {"root": str(root)}
    exit_code = 0

    if not args.jaxpr_only:
        t0 = time.perf_counter()
        rules = all_rules(root)
        violations = run_lint(root, rules)
        report["ast"] = {
            "seconds": round(time.perf_counter() - t0, 3),
            "rules": [{"name": r.name, "description": r.description}
                      for r in rules],
            "violations": [v.to_json() for v in violations],
        }
        for v in violations:
            print(v.format())
        if violations:
            exit_code = 1
        print(f"repro.lint: {len(violations)} violation(s) "
              f"[{report['ast']['seconds']}s AST pass]")

    if args.jaxpr or args.jaxpr_only:
        from repro.lint import jaxpr_checks
        t0 = time.perf_counter()
        results = jaxpr_checks.run_all()
        report["jaxpr"] = {
            "seconds": round(time.perf_counter() - t0, 3),
            "checks": [{"name": name, "ok": ok, "detail": detail}
                       for name, ok, detail in results],
        }
        n_bad = 0
        for name, ok, detail in results:
            status = "ok" if ok else "FAIL"
            print(f"jaxpr[{name}]: {status} — {detail}")
            if not ok:
                n_bad += 1
        if n_bad:
            exit_code = 1
        print(f"repro.lint --jaxpr: {n_bad} failed check(s) "
              f"[{report['jaxpr']['seconds']}s trace pass]")

    if args.json is not None:
        args.json.write_text(json.dumps(report, indent=2) + "\n")

    return exit_code


if __name__ == "__main__":
    sys.exit(main())
