"""Contract linter: machine-enforce the repo's sharding/randomness/compilation
contracts.

Layer 1 (``repro.lint.rules``) is AST-level — sharded-randomness,
gather-then-reduce, structural-field, single-source-literal — scoped by the
declarative ``repro.lint.registry``. Layer 2 (``repro.lint.jaxpr_checks``)
traces the actual compiled programs and asserts primitive-level invariants
(collective census, donation, compile counts).

CLI: ``python -m repro.lint`` (see ``--help``). Programmatic entry points:

    from repro.lint import run_lint, iter_source_files
    violations = run_lint()            # layer 1 over src/repro
"""
from __future__ import annotations

from pathlib import Path

from repro.lint.base import AllowReasonRule, Rule, SourceFile, Violation
from repro.lint.rules import (GatherThenReduceRule, ShardedRandomnessRule,
                              SingleSourceLiteralRule, StructuralFieldRule)

__all__ = [
    "Violation", "SourceFile", "Rule", "all_rules", "iter_source_files",
    "run_lint", "default_root",
]


def default_root() -> Path:
    """The ``src/repro`` package directory this linter ships inside."""
    return Path(__file__).resolve().parents[1]


def all_rules(root: Path) -> list[Rule]:
    return [
        ShardedRandomnessRule(),
        GatherThenReduceRule(),
        StructuralFieldRule(root),
        SingleSourceLiteralRule(root),
        AllowReasonRule(),
    ]


def iter_source_files(root: Path) -> list[SourceFile]:
    out = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        out.append(SourceFile(path, rel))
    return out


def run_lint(root: Path | None = None,
             rules: list[Rule] | None = None) -> list[Violation]:
    """Run the layer-1 AST rules over ``root`` (default: this src/repro)."""
    root = Path(root) if root is not None else default_root()
    if rules is None:
        rules = all_rules(root)
    violations: list[Violation] = []
    for src in iter_source_files(root):
        for rule in rules:
            violations.extend(rule.run(src))
    violations.sort(key=lambda v: (v.path, v.line, v.rule))
    return violations
