"""Declarative scoping for the contract rules.

The rules in ``repro.lint.rules`` are generic AST walks; everything
repo-specific — which functions live on the sharded control path, which
names denote shard-local sizes, which constants are single-sourced — is
declared here. Adding a new sharded-path function or a new single-source
constant is one entry in this file (plus, for a constant, its owner
definition).
"""
from __future__ import annotations

# ---------------------------------------------------------------------------
# Sharded-control-path functions, by repo-relative module path. Inside these
# (including their nested defs), the sharded-randomness and gather-then-reduce
# rules apply: per-client randomness must be content-addressed by global id
# (channel.client_* / fold_in streams), and no O(n_local) value may be
# all_gather'd / sorted / reduced-after-gather — psum-of-local-rows is the
# only allowed reduction shape.
# ---------------------------------------------------------------------------

SHARDED_PATH_FUNCTIONS: dict[str, frozenset[str]] = {
    "core/simulator.py": frozenset({
        "make_control_sharded_round_fn", "_batch_indices_ids",
    }),
    "core/sharding.py": frozenset({
        "hierarchical_top_k", "distributed_top_k", "project_simplex_sharded",
        "assemble_rows", "assemble_batch_rows", "global_client_ids",
        "control_sharded_cell_run",
    }),
    "core/channel.py": frozenset({
        "client_keys", "client_normals", "client_uniforms",
        "compose_channel_ids", "rayleigh_mag_ids",
        "draw_channels_scenario_ids",
    }),
    "core/dynamics.py": frozenset({
        "init_chan_state_ids", "evolve_fading_ids", "evolve_availability",
    }),
    "core/selection.py": frozenset({
        "client_gumbel", "gumbel_topk", "exact_k_scores",
    }),
    "core/dro.py": frozenset({
        "lambda_ascent", "lambda_summary",
    }),
    "core/transport.py": frozenset({
        "_client_uniforms", "quantized_aggregate_psum_tree",
        "sparse_aggregate_psum_tree",
    }),
}

# Functions whose entire purpose is a K-bounded gather — exempt from the
# bare-all_gather arm of the gather-then-reduce rule (their operands are
# [kk <= K] candidate vectors, not O(n_local) rows; the jaxpr analyzer
# additionally proves the bound on the traced program).
GATHER_EXEMPT_FUNCTIONS: frozenset[tuple[str, str]] = frozenset({
    ("core/sharding.py", "hierarchical_top_k"),
})

# Names that denote shard-local row counts. A jax.random draw whose shape
# derives from one of these inside a sharded-path function is materializing
# O(n_local) randomness NOT content-addressed by client id.
LOCAL_SIZE_NAMES: frozenset[str] = frozenset({
    "n_local", "n_rows", "n_locals", "shard_rows",
})

# Array names whose ``.shape`` is shard-local inside sharded-path functions.
LOCAL_ARRAY_NAMES: frozenset[str] = frozenset({
    "ids", "avail", "lam", "v_local", "scores_local", "logits",
    "values_local", "shards_local",
})

# jax.random draw endpoints the sharded-randomness rule watches. ``fold_in``
# is deliberately absent — it IS the content-addressing mechanism.
RANDOM_DRAW_CALLS: frozenset[str] = frozenset({
    "jax.random.normal", "jax.random.uniform", "jax.random.gumbel",
    "jax.random.split", "jax.random.randint", "jax.random.bernoulli",
    "random.normal", "random.uniform", "random.gumbel", "random.split",
    "random.randint", "random.bernoulli",
})

# Gather/sort endpoints of the gather-then-reduce rule.
GATHER_CALLS: frozenset[str] = frozenset({
    "all_gather_axis", "sharding.all_gather_axis", "jax.lax.all_gather",
    "lax.all_gather",
})
SORT_CALLS: frozenset[str] = frozenset({
    "jnp.sort", "jax.numpy.sort", "jax.lax.sort", "lax.sort", "sorted",
    "jnp.argsort", "jax.numpy.argsort", "jnp.median", "jax.numpy.median",
})
REDUCE_CALLS: frozenset[str] = frozenset({
    "jnp.sum", "jnp.mean", "jnp.max", "jnp.min", "jnp.median", "jnp.std",
    "jnp.var", "jnp.cumsum", "jnp.prod", "jnp.any", "jnp.all",
    "jax.lax.psum", "lax.psum", "jax.lax.pmax", "lax.pmax", "jax.lax.pmin",
    "lax.pmin",
})

# ---------------------------------------------------------------------------
# Jitted-code builders: functions that construct (or are) traced round/sweep
# programs. An FLConfig attribute read inside Python-level control flow here
# is a STRUCTURAL read — it must be listed in sweep.STATIC_FIELDS, or cells
# differing in it would silently share one compiled program.
# ---------------------------------------------------------------------------

JIT_BUILDER_FUNCTIONS: dict[str, frozenset[str]] = {
    "core/simulator.py": frozenset({
        "make_param_round_fn", "make_control_sharded_round_fn",
        "_record_lambda", "init_sim_state", "run_simulation",
    }),
    "core/sweep.py": frozenset({
        "_build_runner", "_build_sharded_group_runner",
    }),
    "core/sharding.py": frozenset({
        "run_simulation_sharded", "build_control_sharded_runner",
        "control_sharded_cell_run", "control_sharded_history_specs",
    }),
    "core/transport.py": frozenset({
        "transport_from_config",
    }),
}

# Names an FLConfig rides under in those functions.
FLCONFIG_NAMES: frozenset[str] = frozenset({"fl", "fl0", "fl_static"})

# Where STATIC_FIELDS and the FLConfig dataclass live (repo-relative, for
# the structural-field rule's cross-checks).
STATIC_FIELDS_MODULE = "core/sweep.py"
FLCONFIG_MODULE = "configs/base.py"

# ---------------------------------------------------------------------------
# Single-source constants: the declarative generalization of the PR 6
# tokenize hack. Each entry pins a numeric literal to exactly ONE defining
# assignment; any other occurrence of the literal inside ``scope`` (a glob
# relative to src/repro) is a violation unless allow-commented. Comments and
# docstrings citing the value are prose, not code, and never match (the scan
# is over NUMBER tokens).
# ---------------------------------------------------------------------------

SINGLE_SOURCE_LITERALS: tuple[dict, ...] = (
    {
        "name": "truncation-floor",
        "value": 0.05,
        "owner_module": "core/energy.py",
        "owner_name": "TRUNCATION_FLOOR",
        "scope": "core/*.py",
    },
)
