"""Layer-1 AST rules: the source-level contract checks.

Four rules, scoped by ``repro.lint.registry``:

  - ``sharded-randomness`` — inside sharded-control-path functions, a
    ``jax.random.*`` draw whose shape derives from a shard-local size must
    instead route through the id-addressed ``channel.client_*`` helpers
    (fold_in streams), or sharded and unsharded programs silently diverge.
  - ``gather-then-reduce`` — in the same functions, ``all_gather``/sort (or
    any reduction over a gathered/sorted value) materializes O(n_local·D)
    state; psum-of-local-rows is the only allowed reduction shape.
  - ``structural-field`` — an FLConfig field read in Python-level control
    flow inside a jitted-code builder is structural and must appear in
    ``sweep.STATIC_FIELDS`` (and every STATIC_FIELDS entry must be a real
    FLConfig field), or sweep cells differing in it share one executable.
  - ``single-source-literal`` — registered paper constants
    (``registry.SINGLE_SOURCE_LITERALS``) have exactly one defining literal.
"""
from __future__ import annotations

import ast
import io
import tokenize
from pathlib import Path

from repro.lint import registry
from repro.lint.base import (Rule, SourceFile, Violation, call_name,
                             enclosing_scopes)

# ---------------------------------------------------------------------------
# sharded-randomness
# ---------------------------------------------------------------------------

# jax.random draw -> (positional index, keyword) of its shape-like argument
_SHAPE_ARG = {
    "normal": (1, "shape"), "uniform": (1, "shape"), "gumbel": (1, "shape"),
    "randint": (1, "shape"), "bernoulli": (2, "shape"), "split": (1, "num"),
}


def _shape_expr(call: ast.Call):
    tail = (call_name(call) or "").rsplit(".", 1)[-1]
    pos, kw = _SHAPE_ARG.get(tail, (1, "shape"))
    for k in call.keywords:
        if k.arg == kw:
            return k.value
    if len(call.args) > pos:
        return call.args[pos]
    return None


def _derives_from_local(expr: ast.AST) -> str | None:
    """Name of the shard-local size this expression derives from, if any."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and node.id in registry.LOCAL_SIZE_NAMES:
            return node.id
        if (isinstance(node, ast.Attribute) and node.attr == "shape"
                and isinstance(node.value, ast.Name)
                and node.value.id in registry.LOCAL_ARRAY_NAMES):
            return f"{node.value.id}.shape"
    return None


class ShardedRandomnessRule(Rule):
    name = "sharded-randomness"
    description = ("sharded-path jax.random draws at shard-local shapes must "
                   "be content-addressed via channel.client_* fold_in streams")

    def check(self, src: SourceFile):
        funcs = registry.SHARDED_PATH_FUNCTIONS.get(src.rel)
        if not funcs:
            return
        scopes = enclosing_scopes(src.tree)
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            if scopes.get(node) not in funcs:
                continue
            cname = call_name(node)
            if cname not in registry.RANDOM_DRAW_CALLS:
                continue
            shape = _shape_expr(node)
            local = _derives_from_local(shape) if shape is not None else None
            if local is None:
                continue
            yield Violation(
                rule=self.name, path=src.rel, line=node.lineno,
                message=f"{cname} draws at shard-local shape ({local}) in "
                        f"sharded-path function {scopes[node]!r}; route "
                        "per-client randomness through the id-addressed "
                        "channel.client_* helpers (fold_in streams) so "
                        "sharded and unsharded programs agree per client")


# ---------------------------------------------------------------------------
# gather-then-reduce
# ---------------------------------------------------------------------------


class GatherThenReduceRule(Rule):
    name = "gather-then-reduce"
    description = ("no all_gather/sort (or reduction over a gathered value) "
                   "on the sharded control path — psum-of-local-rows is the "
                   "only allowed reduction shape")

    def check(self, src: SourceFile):
        funcs = registry.SHARDED_PATH_FUNCTIONS.get(src.rel)
        if not funcs:
            return
        scopes = enclosing_scopes(src.tree)
        # names assigned (anywhere in a watched scope) from a gather/sort call
        tainted: dict[str, set[str]] = {}
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Assign) or scopes.get(node) not in funcs:
                continue
            src_calls = {call_name(c) for c in ast.walk(node.value)
                         if isinstance(c, ast.Call)}
            hits = src_calls & (registry.GATHER_CALLS | registry.SORT_CALLS)
            if not hits:
                continue
            for tgt in node.targets:
                for t in ast.walk(tgt):
                    if isinstance(t, ast.Name):
                        tainted.setdefault(t.id, set()).update(
                            h for h in hits if h)

        seen: set[tuple[int, str]] = set()

        def emit(line, message):
            if (line, message) not in seen:
                seen.add((line, message))
                return [Violation(rule=self.name, path=src.rel, line=line,
                                  message=message)]
            return []

        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            scope = scopes.get(node)
            if scope not in funcs:
                continue
            cname = call_name(node)
            if cname in registry.SORT_CALLS:
                yield from emit(
                    node.lineno,
                    f"{cname} in sharded-path function {scope!r}: sorting "
                    "couples all rows — use the psum-bisection / top_k "
                    "formulation instead")
            if (cname in registry.GATHER_CALLS
                    and (src.rel, scope) not in
                    registry.GATHER_EXEMPT_FUNCTIONS):
                yield from emit(
                    node.lineno,
                    f"{cname} in sharded-path function {scope!r} "
                    "materializes O(n_local*D) rows; assemble K-bounded "
                    "slots (ownership-psum) or reduce locally then psum")
            if cname in registry.REDUCE_CALLS:
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    for sub in ast.walk(arg):
                        inner = call_name(sub) if isinstance(sub, ast.Call) \
                            else None
                        if inner in registry.GATHER_CALLS \
                                or inner in registry.SORT_CALLS:
                            yield from emit(
                                node.lineno,
                                f"{cname} reduces a {inner} result in "
                                f"{scope!r}: gather-then-reduce — compute "
                                "the local partial reduction and psum it")
                        if (isinstance(sub, ast.Name)
                                and sub.id in tainted):
                            via = ", ".join(sorted(tainted[sub.id]))
                            yield from emit(
                                node.lineno,
                                f"{cname}({sub.id}) reduces a value "
                                f"gathered/sorted via {via} in {scope!r}: "
                                "gather-then-reduce — compute the local "
                                "partial reduction and psum it")


# ---------------------------------------------------------------------------
# structural-field
# ---------------------------------------------------------------------------


def load_static_fields(root: Path) -> tuple[tuple[str, ...], int]:
    """(STATIC_FIELDS entries, definition line) parsed from sweep.py's AST."""
    tree = ast.parse((root / registry.STATIC_FIELDS_MODULE).read_text())
    for node in ast.walk(tree):
        targets = []
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        if any(isinstance(t, ast.Name) and t.id == "STATIC_FIELDS"
               for t in targets):
            return tuple(ast.literal_eval(value)), node.lineno
    raise LookupError(
        f"STATIC_FIELDS not found in {registry.STATIC_FIELDS_MODULE}")


def load_flconfig_fields(root: Path) -> frozenset[str]:
    """Field names of the FLConfig dataclass, parsed from its AST."""
    tree = ast.parse((root / registry.FLCONFIG_MODULE).read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "FLConfig":
            return frozenset(
                stmt.target.id for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name))
    raise LookupError(f"FLConfig not found in {registry.FLCONFIG_MODULE}")


def _is_none_check(node: ast.AST) -> bool:
    """``x is None`` / ``x is not None`` — argument-presence dispatch, not a
    config-field read; exempt from the structural-field rule."""
    return (isinstance(node, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops)
            and any(isinstance(c, ast.Constant) and c.value is None
                    for c in node.comparators))


class StructuralFieldRule(Rule):
    name = "structural-field"
    description = ("FLConfig fields read in Python control flow inside "
                   "jitted-code builders must be in sweep.STATIC_FIELDS "
                   "(and STATIC_FIELDS entries must be real FLConfig fields)")

    def __init__(self, root: Path):
        self.root = root
        self.static_fields, self.static_line = load_static_fields(root)
        self.fl_fields = load_flconfig_fields(root)

    def check(self, src: SourceFile):
        # converse direction: every STATIC_FIELDS entry is a real field
        if src.rel == registry.STATIC_FIELDS_MODULE:
            for f in self.static_fields:
                if f not in self.fl_fields:
                    yield Violation(
                        rule=self.name, path=src.rel, line=self.static_line,
                        message=f"STATIC_FIELDS entry {f!r} is not an "
                                "FLConfig field — stale entries make "
                                "_static_signature silently vacuous")
        funcs = registry.JIT_BUILDER_FUNCTIONS.get(src.rel)
        if not funcs:
            return
        scopes = enclosing_scopes(src.tree)

        def fl_fields_in(expr) -> set[str]:
            out = set()
            for n in ast.walk(expr):
                if (isinstance(n, ast.Attribute)
                        and isinstance(n.value, ast.Name)
                        and n.value.id in registry.FLCONFIG_NAMES):
                    out.add(n.attr)
            return out

        # alias map per enclosing scope: name -> FLConfig fields its value
        # derives from (e.g. ``scheme = fl.transport``, ``noise_free =
        # fl.noise_std == 0``)
        aliases: dict[tuple[str, str], set[str]] = {}
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Assign) or scopes.get(node) not in funcs:
                continue
            fields = fl_fields_in(node.value)
            if not fields:
                continue
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    aliases.setdefault(
                        (scopes[node], tgt.id), set()).update(fields)

        def walk_test(expr, scope):
            """Fields (direct or via alias) a branch decision reads."""
            found: set[str] = set()
            skip: set[int] = set()
            for n in ast.walk(expr):
                if id(n) in skip:
                    continue
                if _is_none_check(n):
                    skip.update(id(c) for c in ast.walk(n))
                    continue
                if (isinstance(n, ast.Attribute)
                        and isinstance(n.value, ast.Name)
                        and n.value.id in registry.FLCONFIG_NAMES):
                    found.add(n.attr)
                elif isinstance(n, ast.Name):
                    found.update(aliases.get((scope, n.id), ()))
            return found

        for node in ast.walk(src.tree):
            scope = scopes.get(node)
            if scope not in funcs:
                continue
            if isinstance(node, (ast.If, ast.IfExp, ast.While)):
                test = node.test
            else:
                continue
            for field in sorted(walk_test(test, scope)):
                if field in self.static_fields:
                    continue
                if field not in self.fl_fields:
                    continue  # attribute of some non-config object
                yield Violation(
                    rule=self.name, path=src.rel, line=test.lineno,
                    message=f"FLConfig.{field} decides a Python-level branch "
                            f"in jitted-code builder {scope!r} but is not in "
                            "sweep.STATIC_FIELDS — sweep cells differing in "
                            "it would share one compiled program")


# ---------------------------------------------------------------------------
# single-source-literal
# ---------------------------------------------------------------------------


def _owner_line(root: Path, module: str, name: str) -> int:
    tree = ast.parse((root / module).read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == name
                for t in node.targets):
            return node.lineno
    raise LookupError(f"{name} not defined in {module}")


class SingleSourceLiteralRule(Rule):
    name = "single-source-literal"
    description = ("registered paper constants have exactly ONE defining "
                   "literal (registry.SINGLE_SOURCE_LITERALS)")

    def __init__(self, root: Path):
        self.root = root
        self.owners = {
            spec["name"]: (spec, _owner_line(root, spec["owner_module"],
                                             spec["owner_name"]))
            for spec in registry.SINGLE_SOURCE_LITERALS
        }

    def check(self, src: SourceFile):
        for cname, (spec, owner_line) in self.owners.items():
            scope_files = {p.resolve()
                           for p in self.root.glob(spec["scope"])}
            if src.path.resolve() not in scope_files:
                continue
            toks = tokenize.generate_tokens(io.StringIO(src.text).readline)
            for tok in toks:
                if tok.type != tokenize.NUMBER:
                    continue
                try:
                    if float(tok.string) != spec["value"]:
                        continue
                except ValueError:
                    continue
                is_owner = (src.rel == spec["owner_module"]
                            and tok.start[0] == owner_line)
                if is_owner:
                    continue
                yield Violation(
                    rule=self.name, path=src.rel, line=tok.start[0],
                    message=f"literal {spec['value']!r} duplicates the "
                            f"single-source constant {spec['owner_name']} "
                            f"({spec['owner_module']}:{owner_line}); import "
                            "it instead — a drifted copy silently "
                            "desynchronizes the paper constant "
                            f"[{cname}]")
