"""Layer-2 program analyzers: trace the COMPILED programs and assert
primitive-level invariants the AST rules cannot see.

``jax.make_jaxpr`` traces the real round/sweep/projection programs on a
size-1 ``clients`` mesh (collective primitives appear in the jaxpr with
group size 1, so the census is mesh-size-independent) and the checks walk
every nested jaxpr (pjit/scan/cond/shard_map bodies):

  - **sharded round collective census** — for every exact-K method ×
    transport, the sharded-control-plane round contains ZERO ``sort``
    primitives and every ``all_gather`` operand is K-bounded (the
    hierarchical top-k's ≤ ``clients_per_round`` candidate vectors — never
    an O(n_local) row block). GCA is the documented dense exception (its
    population-wide median threshold sorts). ``psum`` counts are pinned per
    (method, transport) so a new hidden collective fails loudly.
  - **λ-projection psum budget** — ``project_simplex_sharded`` spends
    exactly 1 psum per bisection iteration (inside the loop body) plus
    1 pmax + 2 polish psums outside.
  - **negative control** — the replicated round DOES contain a ``sort``
    (``dro.project_simplex``), proving the census sees sorts at all.
  - **donation** — the sweep runner's lowered StableHLO carries
    input→output aliasing for the donated state stack.
  - **compile count** — ``run_sweep`` compiles once per structural group
    (traced-knob-only spec changes reuse the executable).

Traces compile nothing (abstract evaluation only); the full pass is a
benchmark cell (``cells.lint``) with a <60 s ceiling.
"""
from __future__ import annotations

import functools
from collections import Counter
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import FLConfig
from repro.core import sharding
from repro.core.sweep import sweep_point_from_config

# Tiny trace harness: big enough that n_local (8) strictly exceeds K (3), so
# an O(n_local) all_gather operand is distinguishable from a K-bounded one.
N, K, DIM, ROUNDS, BATCH = 8, 3, 8, 2, 4
AXIS = "clients"
EXACT_K_METHODS = ("fedavg", "afl", "ca_afl", "greedy")
METHODS = EXACT_K_METHODS + ("gca",)
TRANSPORTS = ("analog", "quantized", "digital", "sparse")

# Pinned collective budgets of the sharded round, per (method, transport):
# psum count in the fully-traced T-round program (loop bodies counted once).
# Derived from the real programs; a drift in either direction is a contract
# change that must be reviewed (a new hidden collective, or a lost one).
# Exact-K methods share one budget regardless of transport (aggregation rides
# the same psum-tree shape) EXCEPT sparse, whose one extra psum is the
# ownership assembly of the winners' error-feedback residual rows
# (``slot_vals(state.ef_resid, sel_idx)``); GCA's dense path differs per
# transport (sparse matches quantized: the fused partial-sum replaces the
# per-leaf aggregation psums).
PINNED_PSUMS: dict[tuple[str, str], int] = {
    **{(m, t): 14 for m in EXACT_K_METHODS for t in TRANSPORTS},
    **{(m, "sparse"): 15 for m in EXACT_K_METHODS},
    ("gca", "analog"): 11,
    ("gca", "quantized"): 10,
    ("gca", "digital"): 11,
    ("gca", "sparse"): 10,
}


def _fl(method: str, transport: str = "analog", temporal: bool = False,
        control_plane: str = "sharded") -> FLConfig:
    return FLConfig(num_clients=N, clients_per_round=K, rounds=ROUNDS,
                    batch_size=BATCH, method=method, transport=transport,
                    temporal=temporal, control_plane=control_plane)


@functools.lru_cache(maxsize=1)
def _setup():
    from repro.data.synthetic import make_fmnist_like
    from repro.federated.partition import sorted_label_shards
    from repro.models.logreg import logistic_regression
    from repro.utils.tree import tree_size

    model = logistic_regression(dim=DIM, num_classes=10)
    x, y, xt, yt = make_fmnist_like(num_train=80, num_test=40, dim=DIM,
                                    seed=0)
    xs, ys = sorted_label_shards(x, y, N)
    xts, yts = sorted_label_shards(xt, yt, N)
    model_size = tree_size(model.init(jax.random.PRNGKey(0)))
    mesh = Mesh(np.array(jax.devices()[:1]), (AXIS,))
    return model, (xs, ys, xts, yts), model_size, mesh


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _as_jaxprs(v):
    if hasattr(v, "eqns"):                                   # Jaxpr
        yield v
    elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):   # ClosedJaxpr
        yield v.jaxpr
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from _as_jaxprs(item)


def iter_eqns(jaxpr):
    """Every equation in ``jaxpr`` and all nested jaxprs (pjit / scan /
    cond branches / while bodies / shard_map / custom_jvp ...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _as_jaxprs(v):
                yield from iter_eqns(sub)


def primitive_census(closed) -> Counter:
    """Counter of primitive names over the whole (nested) program."""
    return Counter(e.primitive.name for e in iter_eqns(closed.jaxpr))


def all_gather_operand_sizes(closed) -> list[int]:
    """Element count of every ``all_gather`` operand in the program."""
    return [int(np.prod(v.aval.shape) or 1)
            for e in iter_eqns(closed.jaxpr)
            if e.primitive.name == "all_gather"
            for v in e.invars]


# ---------------------------------------------------------------------------
# Traced programs
# ---------------------------------------------------------------------------


def trace_sharded_round(method: str, transport: str = "analog",
                        temporal: bool = False):
    """Jaxpr of the full sharded-control-plane cell (T-round scan) on a
    size-1 clients mesh — the same ``control_sharded_cell_run`` body both
    the 1-D runner and the 2-D sweep mesh execute."""
    model, data, model_size, mesh = _setup()
    fl = _fl(method, transport, temporal)
    point = sweep_point_from_config(fl)
    run = sharding.control_sharded_cell_run(
        model, fl, method, AXIS, N, model_size)
    mapped = shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(), P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
        out_specs=sharding.control_sharded_history_specs(fl, AXIS),
        check_rep=False)
    return jax.make_jaxpr(mapped)(point, jax.random.PRNGKey(0), *data)


def trace_replicated_round(method: str = "ca_afl",
                           transport: str = "analog"):
    """Jaxpr of one replicated-discipline round (single device) — the
    negative control: it sorts (``dro.project_simplex``)."""
    from repro.core.simulator import init_sim_state, make_param_round_fn

    model, data, model_size, _ = _setup()
    fl = _fl(method, transport, control_plane="replicated")
    point = sweep_point_from_config(fl)
    state = init_sim_state(model, fl, jax.random.PRNGKey(0),
                           process=point.process)
    round_fn = make_param_round_fn(model, fl, data, model_size, method)
    return jax.make_jaxpr(
        lambda p, s, t: round_fn(p, s, t))(point, state, jnp.int32(0))


def trace_projection():
    """Jaxpr of ``project_simplex_sharded`` alone on the size-1 mesh."""
    _, _, _, mesh = _setup()
    mapped = shard_map(
        lambda v: sharding.project_simplex_sharded(v, AXIS), mesh=mesh,
        in_specs=(P(AXIS),), out_specs=P(AXIS), check_rep=False)
    return jax.make_jaxpr(mapped)(jnp.ones((N,), jnp.float32))


# ---------------------------------------------------------------------------
# Checks — each returns (ok, detail)
# ---------------------------------------------------------------------------


def check_sharded_round_collectives():
    """Exact-K sharded rounds: zero sorts, K-bounded gathers, pinned psums."""
    bad = []
    seen = {}
    for method in METHODS:
        for transport in TRANSPORTS:
            closed = trace_sharded_round(method, transport)
            census = primitive_census(closed)
            seen[(method, transport)] = census["psum"]
            if method in EXACT_K_METHODS:
                if census["sort"]:
                    bad.append(f"{method}/{transport}: {census['sort']} "
                               "sort primitive(s) in the sharded round")
                over = [s for s in all_gather_operand_sizes(closed) if s > K]
                if over:
                    bad.append(f"{method}/{transport}: all_gather operands "
                               f"{over} exceed the K={K} candidate bound "
                               "(an O(n_local) row block is being gathered)")
            pinned = PINNED_PSUMS.get((method, transport))
            if pinned is not None and census["psum"] != pinned:
                bad.append(f"{method}/{transport}: psum count "
                           f"{census['psum']} != pinned {pinned}")
    if bad:
        return False, "; ".join(bad)
    table = {f"{m}/{t}": c for (m, t), c in sorted(seen.items())}
    return True, (f"{len(seen)} method×transport programs sort-free "
                  f"(exact-K), gathers K-bounded; psums {table}")


def check_projection_psum_budget():
    """1 psum per bisection iteration, pmax + 2 polish psums outside."""
    closed = trace_projection()
    census = primitive_census(closed)
    if census["pmax"] != 1:
        return False, f"expected 1 pmax, got {census['pmax']}"
    if census["psum"] != 3:
        return False, (f"expected 3 psums total (1 loop + 2 polish), got "
                       f"{census['psum']}")
    loop_bodies = []
    for e in iter_eqns(closed.jaxpr):
        if e.primitive.name in ("scan", "while"):
            for v in e.params.values():
                loop_bodies.extend(_as_jaxprs(v))
    if not loop_bodies:
        return False, "no bisection loop found in the projection jaxpr"
    in_loop = sum(Counter(ee.primitive.name for ee in iter_eqns(b))["psum"]
                  for b in loop_bodies)
    if in_loop != 1:
        return False, (f"expected exactly 1 psum inside the bisection loop "
                       f"body, got {in_loop}")
    return True, "1 psum/iteration + pmax + 2 polish psums"


def check_replicated_negative_control():
    """The replicated round must contain a sort — proves the census works."""
    census = primitive_census(trace_replicated_round("ca_afl"))
    if not census["sort"]:
        return False, ("replicated round shows zero sorts — the census is "
                       "not seeing sort primitives (analyzer broken)")
    return True, (f"replicated round has {census['sort']} sort(s) "
                  "(dro.project_simplex), sharded has none")


def check_sweep_donation():
    """The sweep runner's lowered program aliases the donated state stack."""
    from repro.core import sweep as sweep_mod

    model, data, model_size, _ = _setup()
    fl = _fl("fedavg", control_plane="replicated")
    init_fn, runner = sweep_mod._build_runner(
        model, fl, data, "fedavg", noise_free=True, model_size=model_size)
    points = sweep_mod._stack_points([sweep_point_from_config(fl)])
    seeds = jnp.asarray([0], jnp.int32)
    states = init_fn(points, seeds)
    text = runner.lower(points, states).as_text()
    if "tf.aliasing_output" not in text and "jax.buffer_donor" not in text:
        return False, ("no input->output aliasing marker in the sweep "
                       "runner's StableHLO — donate_argnums lost")
    return True, "donated state stack aliased in StableHLO"


def check_compile_count():
    """run_sweep: one compile per method × structural point, not per spec."""
    from repro.core.sweep import reset_trace_log, run_sweep, trace_count

    model, data, _, _ = _setup()
    fl_a = _fl("fedavg", control_plane="replicated")
    specs = [
        ("a", fl_a),
        ("b", replace(fl_a, lr0=0.3)),       # traced knob: same group as a
        ("c", _fl("afl", control_plane="replicated")),  # new structural group
    ]
    reset_trace_log()
    run_sweep(model, data, specs, seeds=(0,))
    n = trace_count()
    if n != 2:
        return False, (f"3 specs / 2 structural groups compiled {n} "
                       "executables (expected 2) — the structural grouping "
                       "regressed")
    return True, "3 specs, 2 structural groups, 2 compiles"


ALL_CHECKS = (
    ("sharded-round-collectives", check_sharded_round_collectives),
    ("projection-psum-budget", check_projection_psum_budget),
    ("replicated-negative-control", check_replicated_negative_control),
    ("sweep-donation", check_sweep_donation),
    ("compile-count", check_compile_count),
)


def run_all() -> list[tuple[str, bool, str]]:
    """Run every jaxpr check; never raises — failures are (name, False, …)."""
    results = []
    for name, fn in ALL_CHECKS:
        try:
            ok, detail = fn()
        except Exception as e:  # noqa: BLE001 — a crashed check is a failure
            ok, detail = False, f"check crashed: {type(e).__name__}: {e}"
        results.append((name, ok, detail))
    return results
