"""Contract-linter core: violations, allow-comments, the Rule protocol.

The linter machine-enforces the prose contracts the README/ROADMAP state —
the per-client-id randomness discipline, the psum-of-local-rows rule, the
``STATIC_FIELDS`` structural discipline and single-sourced constants — as
AST rules over ``src/repro`` (layer 1; see ``repro.lint.rules``) plus
jaxpr-level program analyzers (layer 2; ``repro.lint.jaxpr_checks``).

Suppression is per line via an allow-comment **with a mandatory reason**::

    h_f = all_gather_axis(h, axis_name)  # lint: allow(gather-then-reduce): GCA median needs [N]

or, for multi-line statements, on the line directly above the flagged one::

    # lint: allow(sharded-randomness): replicated-discipline branch (ids is None)
    u = jax.random.uniform(key, avail.shape)

A reasonless allow-comment is itself a violation (rule ``allow-reason``) —
suppressions must say why, or they rot.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Optional

ALLOW_RE = re.compile(
    r"#\s*lint:\s*allow\(\s*(?P<rules>[\w,\s-]+?)\s*\)\s*(?P<sep>:)?\s*(?P<reason>.*)")


@dataclass(frozen=True)
class Violation:
    """One rule violation at a source location."""

    rule: str
    path: str       # repo-relative file path
    line: int       # 1-indexed
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}


class SourceFile:
    """One parsed source file: AST + raw lines + allow-comment index."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))
        # line -> set of rule names allowed there (reasonless ones are
        # recorded too — suppression still applies, but AllowReasonRule
        # flags the comment itself, so the debt stays visible)
        self.allows: dict[int, set[str]] = {}
        self.reasonless: list[int] = []
        for i, line in enumerate(self.lines, start=1):
            m = ALLOW_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group("rules").split(",") if r.strip()}
            self.allows.setdefault(i, set()).update(rules)
            if not (m.group("sep") and m.group("reason").strip()):
                self.reasonless.append(i)

    def allowed(self, rule: str, line: int) -> bool:
        """Is ``rule`` suppressed at ``line`` (same line or the line above)?"""
        for ln in (line, line - 1):
            if rule in self.allows.get(ln, ()):
                return True
        return False


class Rule:
    """Base class: subclasses set ``name`` and implement ``check``."""

    name: str = ""
    description: str = ""

    def check(self, src: SourceFile) -> Iterable[Violation]:
        raise NotImplementedError

    def run(self, src: SourceFile) -> list[Violation]:
        """``check`` filtered through the file's allow-comments."""
        return [v for v in self.check(src)
                if not src.allowed(v.rule, v.line)]


class AllowReasonRule(Rule):
    """Every allow-comment must carry a reason after ``):``."""

    name = "allow-reason"
    description = ("`# lint: allow(<rule>)` needs `: <reason>` — "
                   "suppressions must say why")

    def check(self, src: SourceFile):
        for ln in src.reasonless:
            yield Violation(
                rule=self.name, path=src.rel, line=ln,
                message="allow-comment without a reason; write "
                        "`# lint: allow(<rule>): <why this is legitimate>`")


def call_name(node: ast.AST) -> Optional[str]:
    """Dotted name of a Call's func: ``jax.random.normal``, ``all_gather``…"""
    if not isinstance(node, ast.Call):
        return None
    return dotted_name(node.func)


def dotted_name(node: ast.AST) -> Optional[str]:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def iter_functions(tree: ast.Module):
    """Yield every (possibly nested) function definition with its top-level
    enclosing function name (nested defs inherit the outermost scope — the
    sharded-path registry names top-level builders like
    ``make_control_sharded_round_fn``, and their inner ``round_fn`` bodies
    must inherit the discipline)."""
    for top in ast.walk(tree):
        if isinstance(top, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield top


def enclosing_scopes(tree: ast.Module) -> dict[ast.AST, str]:
    """Map every node to the name of its outermost enclosing function."""
    scope: dict[ast.AST, str] = {}

    def visit(node, current):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if current is None:
                current = node.name
        for child in ast.iter_child_nodes(node):
            scope[child] = current
            visit(child, current)

    visit(tree, None)
    return scope
