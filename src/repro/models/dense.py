"""Llama-style dense decoder (granite-34b, qwen2-0.5b/1.5b/7b).

Pure functional: params are a pytree with every per-layer leaf stacked on a
leading [L] axis and the layer loop a ``lax.scan`` (keeps the HLO one-layer
sized for the 512-device dry-run). Supports:

  - train forward + next-token loss (per-example weights for FL rounds)
  - prefill (chunked online-softmax attention)
  - single-token decode over a KV cache, full or rolling (sliding-window)
    — the rolling cache is what makes ``long_500k`` sub-quadratic & O(window).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.layers import apply_rope, dense_init, embed_init, rms_norm, swiglu
from repro.models.specs import ShardingCtx, pad_vocab


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def cst(x, spec: P, ctx: Optional[ShardingCtx]):
    """Sharding constraint that no-ops without a mesh (smoke tests)."""
    if ctx is None or ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, spec)
    )


def _seq_spec(ctx: Optional[ShardingCtx], seq: int) -> P:
    """Residual-stream spec: batch over data, seq over model when divisible."""
    if ctx is None:
        return P()
    m = ctx.axes.model if seq % max(ctx.model_size, 1) == 0 and seq > 1 else None
    return P(ctx.axes.data, m, None)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init(cfg: ModelConfig, key) -> dict:
    dt = _dt(cfg)
    L, D, F = cfg.num_layers, cfg.d_model, cfg.d_ff
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // hkv
    vp = pad_vocab(cfg.vocab_size)
    ks = jax.random.split(key, 12)

    def stacked(k, shape, scale=None):
        return dense_init(k, (L,) + shape, dt, scale)

    params = {
        "embed": embed_init(ks[0], (vp, D), dt),
        "layers": {
            "attn_norm": jnp.ones((L, D), dt),
            "wq": stacked(ks[1], (D, hkv, g, hd)),
            "wk": stacked(ks[2], (D, hkv, hd)),
            "wv": stacked(ks[3], (D, hkv, hd)),
            "wo": stacked(ks[4], (hkv, g, hd, D), scale=1.0 / jnp.sqrt(D)),
            "mlp_norm": jnp.ones((L, D), dt),
            "w_gate": stacked(ks[5], (D, F)),
            "w_up": stacked(ks[6], (D, F)),
            "w_down": stacked(ks[7], (F, D)),
        },
        "final_norm": jnp.ones((D,), dt),
        "lm_head": dense_init(ks[8], (D, vp), dt),
    }
    if cfg.qkv_bias:
        params["layers"]["bq"] = jnp.zeros((L, hkv, g, hd), dt)
        params["layers"]["bk"] = jnp.zeros((L, hkv, hd), dt)
        params["layers"]["bv"] = jnp.zeros((L, hkv, hd), dt)
    return params


def param_specs(cfg: ModelConfig, ctx: ShardingCtx) -> dict:
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // hkv
    a = ctx.axes
    vp = pad_vocab(cfg.vocab_size)

    def st(spec: P) -> P:  # prepend unsharded layer axis
        return P(None, *spec)

    specs = {
        "embed": P(ctx.model_if(vp), ctx.pdata_if(cfg.d_model)),
        "layers": {
            "attn_norm": st(P(None)),
            "wq": st(ctx.attn_q_spec(hkv, g, hd)),
            "wk": st(ctx.attn_kv_spec(hkv, hd)),
            "wv": st(ctx.attn_kv_spec(hkv, hd)),
            "wo": st(ctx.attn_o_spec(hkv, g, hd)),
            "mlp_norm": st(P(None)),
            "w_gate": st(P(ctx.pdata, a.model)),
            "w_up": st(P(ctx.pdata, a.model)),
            "w_down": st(P(a.model, ctx.pdata)),
        },
        "final_norm": P(None),
        "lm_head": P(ctx.pdata_if(cfg.d_model), ctx.model_if(vp)),
    }
    if cfg.qkv_bias:
        q = ctx.attn_q_spec(hkv, g, hd)
        k = ctx.attn_kv_spec(hkv, hd)
        specs["layers"]["bq"] = st(P(q[1], q[2], q[3]))
        specs["layers"]["bk"] = st(P(k[1], k[2]))
        specs["layers"]["bv"] = st(P(k[1], k[2]))
    return specs


# ---------------------------------------------------------------------------
# Layer
# ---------------------------------------------------------------------------


def _attention_remat(cfg, q, k, v, *, window=None, chunk=None, causal=True):
    """Attention with its chunk-scan intermediates rematerialized.

    Differentiating the chunked online-softmax scan would otherwise SAVE the
    per-chunk [B, H, G, Sq, chunk] score blocks for backward (~10 GiB/device
    at granite train_4k scale). Recomputing them is what the flash-attention
    backward does on real hardware; jax.checkpoint expresses the same policy
    here (composes with the outer per-layer remat)."""

    return attn_lib.attention(q, k, v, causal=causal, window=window,
                               chunk=chunk, remat=cfg.remat)


def _qkv(cfg, lp, x, positions, ctx=None):
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // hkv
    q = jnp.einsum("bsd,dkgh->bskgh", x, lp["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, lp["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, lp["wv"])
    if ctx is not None and ctx.mesh is not None and x.shape[1] > 1:
        # Megatron TP: head-shard the projection OUTPUTS. Without this GSPMD
        # partitions the einsum batch-wise and all-gathers the FULL (fp32-
        # upcast) weights per layer per microbatch — measured 2.7 TB/device
        # at granite train_4k (EXPERIMENTS.md §Perf granite iteration 1).
        # ONLY when a true head axis (Hkv or G) is the sharded dim: pinning
        # the head_dim axis instead forces a psum inside every attention
        # (measured 25x wire regression on qwen3-moe — §Perf, refuted).
        qs = ctx.attn_q_spec(hkv, g, hd)
        ks = ctx.attn_kv_spec(hkv, hd)
        if qs[3] is None:  # heads sharded, not head_dim
            q = cst(q, P(ctx.axes.data, None, qs[1], qs[2], None), ctx)
        if ks[1] is not None:  # kv heads sharded
            k = cst(k, P(ctx.axes.data, None, ks[1], None), ctx)
            v = cst(v, P(ctx.axes.data, None, ks[1], None), ctx)
    if cfg.qkv_bias:
        q = q + lp["bq"]
        k = k + lp["bk"]
        v = v + lp["bv"]
    b, s = x.shape[:2]
    q = apply_rope(q.reshape(b, s, hkv * g, hd), positions, cfg.rope_theta)
    q = q.reshape(b, s, hkv, g, hd)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp_tp(cfg, lp, h, ctx):
    """SwiGLU with Megatron-sharded hidden activations (see _qkv note)."""
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, lp["w_gate"]))
    u = jnp.einsum("bsd,df->bsf", h, lp["w_up"])
    if ctx is not None and ctx.mesh is not None and h.shape[1] > 1:
        spec = P(ctx.axes.data, None, ctx.model_if(g.shape[-1]))
        g = cst(g, spec, ctx)
        u = cst(u, spec, ctx)
    return jnp.einsum("bsf,fd->bsd", g * u, lp["w_down"])


def _attn_out(lp, o):
    return jnp.einsum("bskgh,kghd->bsd", o, lp["wo"])


def decoder_layer(
    cfg: ModelConfig,
    lp: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    ctx: Optional[ShardingCtx],
    *,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
):
    """One pre-norm GQA + SwiGLU block (train / prefill path)."""
    seq = x.shape[1]
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lp, h, positions, ctx)
    o = _attention_remat(cfg, q, k, v, window=window, chunk=chunk)
    x = x + _attn_out(lp, o)
    x = cst(x, _seq_spec(ctx, seq), ctx)
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + _mlp_tp(cfg, lp, h, ctx)
    return cst(x, _seq_spec(ctx, seq), ctx)


# ---------------------------------------------------------------------------
# Train forward / loss
# ---------------------------------------------------------------------------


def _embed(cfg, params, tokens, ctx):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg))
    return cst(x, _seq_spec(ctx, tokens.shape[1]), ctx)


def _logits(cfg, params, x, ctx):
    """[B, S, D] -> fp32 logits with padded-vocab mask; vocab model-sharded."""
    vp = pad_vocab(cfg.vocab_size)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)
    if ctx is not None and ctx.mesh is not None:
        logits = cst(logits, P(ctx.axes.data, None, ctx.model_if(vp)), ctx)
    if vp != cfg.vocab_size:
        mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


def forward(cfg: ModelConfig, params, tokens, ctx=None, *, chunk=None, window=None):
    """Teacher-forced forward: tokens [B, S] -> logits [B, S, Vp]."""
    s = tokens.shape[1]
    if chunk is None and s > 2048:
        chunk = 2048  # bound the attention score block (remat-safe)
    positions = jnp.arange(s)
    x = _embed(cfg, params, tokens, ctx)

    def body(xc, lp):
        return decoder_layer(cfg, lp, xc, positions, ctx, chunk=chunk, window=window), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x, ctx)


def per_token_nll(logits, labels):
    """-log p(label) per token WITHOUT a gather on the (vocab-sharded)
    logits: a gather along a sharded axis makes GSPMD all-gather the full
    [B, S, V] fp32 logits (~13 GiB/device at granite scale). The
    iota-compare + masked-sum form partitions cleanly."""
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    vp = logits.shape[-1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
              == labels[..., None])
    label_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return lse - label_logit


def token_xent(logits, labels, weights=None):
    """Mean next-token cross-entropy; weights: optional per-example [B]."""
    per_ex = jnp.mean(per_token_nll(logits, labels), axis=-1)  # [B]
    if weights is not None:
        return jnp.mean(per_ex * weights)
    return jnp.mean(per_ex)


def loss_fn(cfg: ModelConfig, params, batch, ctx=None, *, chunk=None):
    logits = forward(cfg, params, batch["tokens"], ctx, chunk=chunk)
    return token_xent(logits[:, :-1], batch["labels"][:, 1:], batch.get("weights"))


# ---------------------------------------------------------------------------
# KV cache: prefill + decode (full or rolling)
# ---------------------------------------------------------------------------


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Rolling (sliding-window) cache for long contexts, full cache otherwise.

    The rolling variant engages only beyond ``long_context_threshold`` so
    that decode_32k serves exact full attention while long_500k runs
    sub-quadratic O(window) (DESIGN.md §Shape skips)."""
    if (cfg.window is not None and seq_len > cfg.window
            and seq_len >= cfg.long_context_threshold):
        return cfg.window
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    t = cache_len(cfg, seq_len)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, t, hkv, hd)
    return {"k": jnp.zeros(shape, _dt(cfg)), "v": jnp.zeros(shape, _dt(cfg))}


def cache_specs(cfg: ModelConfig, ctx: ShardingCtx, batch: int, seq_len: int) -> dict:
    """KV-seq over model (flash-decoding split-K); batch over data if divisible."""
    t = cache_len(cfg, seq_len)
    b_ax = ctx.data_if(batch) if batch > 1 else None
    t_ax = ctx.model_if(t)
    spec = P(None, b_ax, t_ax, None, None)
    return {"k": spec, "v": spec}


def prefill(cfg: ModelConfig, params, tokens, ctx=None, *, chunk=2048):
    """tokens [B, S] -> (last-token logits [B, Vp], cache)."""
    b, s = tokens.shape
    positions = jnp.arange(s)
    x = _embed(cfg, params, tokens, ctx)
    window = cfg.window if (cfg.window and s > cfg.window) else None

    def body(xc, lp):
        h = rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lp, h, positions, ctx)
        o = attn_lib.attention(q, k, v, causal=True, window=window, chunk=chunk)
        xc = xc + _attn_out(lp, o)
        xc = cst(xc, _seq_spec(ctx, s), ctx)
        h = rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
        xc = xc + _mlp_tp(cfg, lp, h, ctx)
        return cst(xc, _seq_spec(ctx, s), ctx), (k, v)

    x, (ck, cv) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, x, ctx)[:, 0]
    return logits, {"k": ck, "v": cv}


def _rolling_kv_pos(pos: jnp.ndarray, t: int) -> jnp.ndarray:
    """Absolute positions held by each rolling-cache slot at write-time `pos`."""
    slots = jnp.arange(t)
    slot = pos % t
    return pos - ((slot - slots) % t)


def decode_step(cfg: ModelConfig, params, cache, token, pos, ctx=None):
    """One decode step. token [B] int32, pos scalar int32 (uniform batch).

    Returns (logits [B, Vp], updated cache). The cache is rolling iff it was
    allocated shorter than the position range (sliding-window serving).
    """
    b = token.shape[0]
    t = cache["k"].shape[2]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(_dt(cfg))
    x = x.reshape(b, 1, -1)
    positions = pos[None] if pos.ndim == 0 else pos
    rolling = cfg.window is not None and t == cfg.window
    slot = (pos % t) if rolling else pos
    if rolling:
        kv_pos = _rolling_kv_pos(pos, t)
        # unwritten slots (pos < window) carry negative positions: mask them
        # by pushing beyond the causal horizon.
        kv_pos = jnp.where(kv_pos < 0, 2**30, kv_pos)
    else:
        kv_pos = jnp.arange(t)

    def body(xc, scanned):
        lp, ck, cv = scanned
        h = rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lp, h, positions)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, slot, axis=1)
        o = attn_lib.attention(
            q, ck, cv,
            q_pos=positions, kv_pos=kv_pos, causal=True,
            window=cfg.window if rolling else None,
            kv_len=None if rolling else pos + 1,
        )
        xc = xc + _attn_out(lp, o)
        h = rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
        xc = xc + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
        return xc, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, x, ctx)[:, 0]
    return logits, {"k": ck, "v": cv}
