"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block
[arXiv:2411.15242].

``num_layers`` Mamba2 blocks; after every ``shared_attn_every``-th block the
*same* attention+MLP block (single parameter set, Zamba's signature trick) is
applied — each application site keeps its own KV cache. Layer loop = scan over
groups of (every Mamba blocks + shared attn); trailing Mamba layers (38 % 6 = 2)
run as a second scan.

long_500k: the Mamba backbone is O(1)-state; the shared attention block runs a
rolling sliding-window cache (cfg.window), keeping the whole model
sub-quadratic at 524k positions.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import dense, ssm
from repro.models.dense import cst, _seq_spec, token_xent
from repro.models.layers import dense_init, embed_init, rms_norm
from repro.models.specs import ShardingCtx, pad_vocab


def _struct(cfg: ModelConfig):
    g = cfg.num_layers // cfg.shared_attn_every
    tail = cfg.num_layers - g * cfg.shared_attn_every
    return g, cfg.shared_attn_every, tail  # (groups, per, tail)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _attn_block_init(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    D, F = cfg.d_model, cfg.d_ff
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // hkv
    ks = jax.random.split(key, 8)
    return {
        "attn_norm": jnp.ones((D,), dt),
        "wq": dense_init(ks[0], (D, hkv, g, hd), dt),
        "wk": dense_init(ks[1], (D, hkv, hd), dt),
        "wv": dense_init(ks[2], (D, hkv, hd), dt),
        "wo": dense_init(ks[3], (hkv, g, hd, D), dt, scale=1.0 / jnp.sqrt(D)),
        "mlp_norm": jnp.ones((D,), dt),
        "w_gate": dense_init(ks[4], (D, F), dt),
        "w_up": dense_init(ks[5], (D, F), dt),
        "w_down": dense_init(ks[6], (F, D), dt, scale=1.0 / jnp.sqrt(D)),
    }


def _attn_block_specs(cfg: ModelConfig, ctx: ShardingCtx) -> dict:
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // hkv
    a = ctx.axes
    return {
        "attn_norm": P(None),
        "wq": ctx.attn_q_spec(hkv, g, hd),
        "wk": ctx.attn_kv_spec(hkv, hd),
        "wv": ctx.attn_kv_spec(hkv, hd),
        "wo": ctx.attn_o_spec(hkv, g, hd),
        "mlp_norm": P(None),
        "w_gate": P(ctx.pdata, a.model),
        "w_up": P(ctx.pdata, a.model),
        "w_down": P(a.model, ctx.pdata),
    }


def init(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    vp = pad_vocab(cfg.vocab_size)
    L = cfg.num_layers
    ks = jax.random.split(key, 5)
    mamba = jax.vmap(lambda k: ssm.block_init(cfg, k))(jax.random.split(ks[1], L))
    return {
        "embed": embed_init(ks[0], (vp, cfg.d_model), dt),
        "mamba": mamba,                                   # [L, ...]
        "shared_attn": _attn_block_init(cfg, ks[2]),      # single param set
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense_init(ks[3], (cfg.d_model, vp), dt),
    }


def param_specs(cfg: ModelConfig, ctx: ShardingCtx) -> dict:
    vp = pad_vocab(cfg.vocab_size)
    bs = ssm.block_specs(cfg, ctx)
    return {
        "embed": P(ctx.model_if(vp), ctx.pdata_if(cfg.d_model)),
        "mamba": jax.tree.map(lambda s: P(None, *s), bs,
                              is_leaf=lambda x: isinstance(x, P)),
        "shared_attn": _attn_block_specs(cfg, ctx),
        "final_norm": P(None),
        "lm_head": P(ctx.pdata_if(cfg.d_model), ctx.model_if(vp)),
    }


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


class HybridCache(NamedTuple):
    mamba: ssm.SSMCache       # leaves stacked [L, ...]
    k: jnp.ndarray            # [sites, B, T, Hkv, hd]
    v: jnp.ndarray


def attn_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if (cfg.window is not None and seq_len > cfg.window
            and seq_len >= cfg.long_context_threshold):
        return cfg.window
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> HybridCache:
    g, per, tail = _struct(cfg)
    t = attn_cache_len(cfg, seq_len)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    mc = ssm.init_block_cache(cfg, batch)
    return HybridCache(
        mamba=jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), mc),
        k=jnp.zeros((g, batch, t, hkv, hd), jnp.dtype(cfg.dtype)),
        v=jnp.zeros((g, batch, t, hkv, hd), jnp.dtype(cfg.dtype)),
    )


def cache_specs(cfg: ModelConfig, ctx: ShardingCtx, batch: int, seq_len: int):
    t = attn_cache_len(cfg, seq_len)
    mc = ssm.block_cache_specs(cfg, ctx, batch)
    b_ax = ctx.data_if(batch) if batch > 1 else None
    kv = P(None, b_ax, ctx.model_if(t), None, None)
    return HybridCache(
        mamba=jax.tree.map(lambda s: P(None, *s), mc,
                           is_leaf=lambda x: isinstance(x, P)),
        k=kv, v=kv,
    )


# ---------------------------------------------------------------------------
# Shared attention block
# ---------------------------------------------------------------------------


def _shared_attn(cfg, ap, x, positions, ctx, *, chunk=None, window=None,
                 kv_cache=None, kv_pos=None, slot=None, kv_len=None):
    """Returns (x_out, (k, v) or updated cache)."""
    s = x.shape[1]
    h = rms_norm(x, ap["attn_norm"], cfg.norm_eps)
    q, k, v = dense._qkv(cfg, ap, h, positions, ctx)
    if kv_cache is None:
        o = dense._attention_remat(cfg, q, k, v, window=window, chunk=chunk)
        new_kv = (k, v)
    else:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, slot, axis=1)
        o = attn_lib.attention(q, ck, cv, q_pos=positions, kv_pos=kv_pos,
                               causal=True, window=window, kv_len=kv_len)
        new_kv = (ck, cv)
    x = x + dense._attn_out(ap, o)
    x = cst(x, _seq_spec(ctx, s), ctx)
    hh = rms_norm(x, ap["mlp_norm"], cfg.norm_eps)
    x = x + dense._mlp_tp(cfg, ap, hh, ctx)
    return cst(x, _seq_spec(ctx, s), ctx), new_kv


# ---------------------------------------------------------------------------
# Forward / loss / prefill / decode
# ---------------------------------------------------------------------------


def _split_groups(cfg, params):
    g, per, tail = _struct(cfg)
    grouped = jax.tree.map(
        lambda x: x[: g * per].reshape((g, per) + x.shape[1:]), params["mamba"]
    )
    tail_p = jax.tree.map(lambda x: x[g * per:], params["mamba"])
    return grouped, tail_p, g, per, tail


def forward(cfg: ModelConfig, params, tokens, ctx=None, *, chunk=None, window=None):
    b, s = tokens.shape
    if chunk is None and s > 2048:
        chunk = 2048
    positions = jnp.arange(s)
    x = dense._embed(cfg, params, tokens, ctx)
    grouped, tail_p, g, per, tail = _split_groups(cfg, params)
    ap = params["shared_attn"]

    def group_body(xc, gp):
        def inner(xc2, bp):
            y, _ = ssm.block_forward(cfg, bp, xc2)
            return cst(y, _seq_spec(ctx, s), ctx), None

        xc, _ = jax.lax.scan(inner, xc, gp)
        xc, _ = _shared_attn(cfg, ap, xc, positions, ctx, chunk=chunk, window=window)
        return xc, None

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    x, _ = jax.lax.scan(body, x, grouped)
    if tail:
        def inner_tail(xc, bp):
            y, _ = ssm.block_forward(cfg, bp, xc)
            return cst(y, _seq_spec(ctx, s), ctx), None
        x, _ = jax.lax.scan(inner_tail, x, tail_p)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return dense._logits(cfg, params, x, ctx)


def loss_fn(cfg: ModelConfig, params, batch, ctx=None, **kw):
    logits = forward(cfg, params, batch["tokens"], ctx, **kw)
    return token_xent(logits[:, :-1], batch["labels"][:, 1:], batch.get("weights"))


def prefill(cfg: ModelConfig, params, tokens, ctx=None, *, chunk=2048):
    b, s = tokens.shape
    positions = jnp.arange(s)
    x = dense._embed(cfg, params, tokens, ctx)
    grouped, tail_p, g, per, tail = _split_groups(cfg, params)
    ap = params["shared_attn"]
    window = cfg.window if (cfg.window and s > cfg.window) else None

    def group_body(xc, gp):
        def inner(xc2, bp):
            y, c = ssm.block_forward(cfg, bp, xc2)
            return cst(y, _seq_spec(ctx, s), ctx), c

        xc, mcs = jax.lax.scan(inner, xc, gp)
        xc, (k, v) = _shared_attn(cfg, ap, xc, positions, ctx, chunk=chunk,
                                  window=window)
        return xc, (mcs, k, v)

    x, (mcs, ks, vs) = jax.lax.scan(group_body, x, grouped)
    mcs = jax.tree.map(lambda t: t.reshape((g * per,) + t.shape[2:]), mcs)
    if tail:
        def inner_tail(xc, bp):
            y, c = ssm.block_forward(cfg, bp, xc)
            return cst(y, _seq_spec(ctx, s), ctx), c
        x, mct = jax.lax.scan(inner_tail, x, tail_p)
        mcs = jax.tree.map(lambda a_, b_: jnp.concatenate([a_, b_], 0), mcs, mct)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = dense._logits(cfg, params, x, ctx)[:, 0]
    return logits, HybridCache(mamba=mcs, k=ks, v=vs)


def decode_step(cfg: ModelConfig, params, cache: HybridCache, token, pos, ctx=None):
    b = token.shape[0]
    t = cache.k.shape[2]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    x = x.reshape(b, 1, -1)
    positions = pos[None] if pos.ndim == 0 else pos
    grouped_p, tail_p, g, per, tail = _struct_params(cfg, params)
    ap = params["shared_attn"]
    rolling = cfg.window is not None and t == cfg.window
    slot = (pos % t) if rolling else pos
    if rolling:
        kv_pos = dense._rolling_kv_pos(pos, t)
        kv_pos = jnp.where(kv_pos < 0, 2**30, kv_pos)
    else:
        kv_pos = jnp.arange(t)

    mamba_grouped = jax.tree.map(
        lambda x_: x_[: g * per].reshape((g, per) + x_.shape[1:]), cache.mamba
    )
    mamba_tail = jax.tree.map(lambda x_: x_[g * per:], cache.mamba)

    def group_body(xc, scanned):
        gp, mc, ck, cv = scanned

        def inner(xc2, scanned2):
            bp, c = scanned2
            y, c_new = ssm.block_step(cfg, bp, xc2, c)
            return y, c_new

        xc, mc_new = jax.lax.scan(inner, xc, (gp, mc))
        xc, (ck, cv) = _shared_attn(
            cfg, ap, xc, positions, ctx,
            window=cfg.window if rolling else None,
            kv_cache=(ck, cv), kv_pos=kv_pos, slot=slot,
            kv_len=None if rolling else pos + 1,
        )
        return xc, (mc_new, ck, cv)

    x, (mcs, ks, vs) = jax.lax.scan(
        group_body, x, (grouped_p, mamba_grouped, cache.k, cache.v)
    )
    mcs = jax.tree.map(lambda t_: t_.reshape((g * per,) + t_.shape[2:]), mcs)
    if tail:
        def inner_tail(xc, scanned2):
            bp, c = scanned2
            return ssm.block_step(cfg, bp, xc, c)
        x, mct = jax.lax.scan(inner_tail, x, (tail_p, mamba_tail))
        mcs = jax.tree.map(lambda a_, b_: jnp.concatenate([a_, b_], 0), mcs, mct)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = dense._logits(cfg, params, x, ctx)[:, 0]
    return logits, HybridCache(mamba=mcs, k=ks, v=vs)


def _struct_params(cfg, params):
    grouped, tail_p, g, per, tail = _split_groups(cfg, params)
    return grouped, tail_p, g, per, tail
