"""The paper's model: multinomial logistic regression (M = 7850 for FMNIST).

Also provides a small MLP for beyond-paper ablations. Both expose the
SimModel interface consumed by ``repro.core.simulator``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class SimModel(NamedTuple):
    init: Callable  # key -> params
    loss: Callable  # (params, x, y) -> scalar mean loss
    accuracy: Callable  # (params, x, y) -> scalar accuracy


class ProdSimModel(NamedTuple):
    """Production-tier (``federated.rounds``/``ParameterServer``) interface
    over a simulator model: batches are dicts with ``x``/``labels``/
    ``client_ids`` (+optional per-example ``weights``), and the per-example
    NLL feeds the λ-ascent control channel. This is what lets one logreg run
    through BOTH tiers for the cross-tier differential test."""

    init: Callable             # key -> params
    loss_fn: Callable          # (params, batch, ctx) -> scalar weighted loss
    per_example_nll: Callable  # (params, batch) -> [B]
    accuracy: Callable         # (params, x, y) -> scalar


def _xent(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def logistic_regression(dim: int = 784, num_classes: int = 10) -> SimModel:
    def init(key):
        return {
            "w": jnp.zeros((dim, num_classes), jnp.float32),
            "b": jnp.zeros((num_classes,), jnp.float32),
        }

    def logits(params, x):
        return x @ params["w"] + params["b"]

    def loss(params, x, y):
        return _xent(logits(params, x), y)

    def accuracy(params, x, y):
        return jnp.mean((jnp.argmax(logits(params, x), -1) == y).astype(jnp.float32))

    return SimModel(init, loss, accuracy)


def logistic_regression_prod(dim: int = 784,
                             num_classes: int = 10) -> ProdSimModel:
    """The paper's logreg wearing the production-tier model interface.

    Shares ``logistic_regression``'s init (zeros), so both tiers start from
    identical parameters without any state copying.
    """
    sim = logistic_regression(dim, num_classes)

    def per_example_nll(params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(
            logp, batch["labels"][..., None], axis=-1)[..., 0]

    def loss_fn(params, batch, ctx=None):
        per_ex = per_example_nll(params, batch)
        if "weights" in batch:
            per_ex = per_ex * batch["weights"]
        return jnp.mean(per_ex)

    return ProdSimModel(init=sim.init, loss_fn=loss_fn,
                        per_example_nll=per_example_nll,
                        accuracy=sim.accuracy)


def mlp(dim: int = 784, hidden: int = 64, num_classes: int = 10) -> SimModel:
    def init(key):
        k1, k2 = jax.random.split(key)
        s1 = 1.0 / jnp.sqrt(dim)
        s2 = 1.0 / jnp.sqrt(hidden)
        return {
            "w1": jax.random.uniform(k1, (dim, hidden), jnp.float32, -s1, s1),
            "b1": jnp.zeros((hidden,), jnp.float32),
            "w2": jax.random.uniform(k2, (hidden, num_classes), jnp.float32, -s2, s2),
            "b2": jnp.zeros((num_classes,), jnp.float32),
        }

    def logits(params, x):
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    def loss(params, x, y):
        return _xent(logits(params, x), y)

    def accuracy(params, x, y):
        return jnp.mean((jnp.argmax(logits(params, x), -1) == y).astype(jnp.float32))

    return SimModel(init, loss, accuracy)
