"""xLSTM (mLSTM + sLSTM) language model [arXiv:2405.04517].

Block layout: ``num_layers`` organized in super-blocks of ``slstm_group``
layers — (slstm_group-1) mLSTM blocks followed by 1 sLSTM block — scanned as
one homogeneous unit, so the HLO stays one-super-block sized.

mLSTM: matrix memory C in R^{dk x dv} per head with exp input gate and
sigmoid forget gate, computed *chunkwise-parallel* (same duality as SSD:
intra-chunk masked quadratic + inter-chunk recurrent state), normalizer
n with the xLSTM max(|q.n|, 1) denominator. The exp input gate is clipped at
IGATE_CLIP in log space (numerically-lightened variant of the paper's running
max stabilizer; DESIGN.md records the deviation).

sLSTM: scalar memory per head-channel with recurrent gate contributions and
the paper's exact m-stabilizer, a true sequential ``lax.scan`` over time (the
part of xLSTM that cannot be parallelized — kept on-chip).

Sharding: mLSTM value/state dv over ``model``; sLSTM is replicated over
``model`` (small params, 1/slstm_group of layers) and batch-parallel.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.dense import _embed, _logits, cst, token_xent
from repro.models.layers import dense_init, embed_init, rms_norm
from repro.models.specs import ShardingCtx, pad_vocab

IGATE_CLIP = 8.0


def mdims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    h = cfg.num_heads
    return d_inner, h, d_inner // h  # (d_inner, H, dv=dk)


def sdims(cfg: ModelConfig):
    h = cfg.num_heads
    return h, cfg.d_model // h  # (H, d)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_init(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    d_inner, H, dh = mdims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((D,), dt),
        "wq": dense_init(ks[0], (D, H, dh), dt),
        "wk": dense_init(ks[1], (D, H, dh), dt),
        "wv": dense_init(ks[2], (D, H, dh), dt),
        "w_i": dense_init(ks[3], (D, H), jnp.float32),
        "w_f": dense_init(ks[4], (D, H), jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),  # open forget gate at init
        "w_og": dense_init(ks[5], (D, d_inner), dt),
        "out_norm": jnp.ones((d_inner,), dt),
        "w_out": dense_init(ks[6], (d_inner, D), dt, scale=1.0 / jnp.sqrt(D)),
    }


def mlstm_specs(cfg: ModelConfig, ctx: ShardingCtx) -> dict:
    a = ctx.axes
    d_inner, H, dh = mdims(cfg)
    m_v = ctx.model_if(dh)
    return {
        "norm": P(None),
        "wq": P(ctx.pdata, None, None),
        "wk": P(ctx.pdata, None, None),
        "wv": P(ctx.pdata, None, m_v),
        "w_i": P(ctx.pdata, None),
        "w_f": P(ctx.pdata, None),
        "b_i": P(None),
        "b_f": P(None),
        "w_og": P(ctx.pdata, ctx.model_if(d_inner)),
        "out_norm": P(ctx.model_if(d_inner)),
        "w_out": P(ctx.model_if(d_inner), ctx.pdata),
    }


class MLSTMCache(NamedTuple):
    C: jnp.ndarray  # [B, H, dk, dv] fp32
    n: jnp.ndarray  # [B, H, dk]    fp32


def mlstm_cache(cfg: ModelConfig, batch: int) -> MLSTMCache:
    _, H, dh = mdims(cfg)
    return MLSTMCache(
        C=jnp.zeros((batch, H, dh, dh), jnp.float32),
        n=jnp.zeros((batch, H, dh), jnp.float32),
    )


def mlstm_cache_specs(cfg: ModelConfig, ctx: ShardingCtx, batch: int) -> MLSTMCache:
    _, H, dh = mdims(cfg)
    b_ax = ctx.data_if(batch) if batch > 1 else None
    return MLSTMCache(C=P(b_ax, None, None, ctx.model_if(dh)), n=P(b_ax, None, None))


def _mlstm_gates(bp, u):
    li = jnp.einsum("bsd,dh->bsh", u.astype(jnp.float32), bp["w_i"]) + bp["b_i"]
    lf = jnp.einsum("bsd,dh->bsh", u.astype(jnp.float32), bp["w_f"]) + bp["b_f"]
    li = jnp.clip(li, a_max=IGATE_CLIP)
    return li, jax.nn.log_sigmoid(lf)


def mlstm_scan(q, k, v, log_i, log_f, chunk: int, cache: Optional[MLSTMCache],
               remat: bool = False, ctx=None):
    """Chunkwise mLSTM. q/k/v [B,S,H,dh]; log_i/log_f [B,S,H]. fp32 inside.

    The [B, H, dk, dv] matrix state is explicitly constrained to dv-over-
    ``model`` sharding inside the scan — without it GSPMD reshards the 268MB
    (at 1.3B-scale) state every chunk, turning the scan collective-bound."""
    b, s, h, dh = q.shape
    scale = 1.0 / jnp.sqrt(dh)
    qc = min(chunk, s)
    nc = -(-s // qc)
    pad = nc * qc - s
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, z4) for t in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))

    def rc(t):
        return t.reshape((b, nc, qc) + t.shape[2:]).swapaxes(0, 1)

    # keep chunk inputs in model dtype; cast to fp32 INSIDE the step so the
    # scan's saved xs are bf16 (2x smaller) — the math still runs fp32
    qcs, kcs, vcs = (rc(t) for t in (q, k, v))
    lic, lfc = rc(log_i), rc(log_f)

    C0 = jnp.zeros((b, h, dh, dh), jnp.float32) if cache is None else cache.C
    n0 = jnp.zeros((b, h, dh), jnp.float32) if cache is None else cache.n

    def _cst_state(C, n):
        if ctx is None or ctx.mesh is None:
            return C, n
        from repro.models.dense import cst
        C = cst(C, P(ctx.axes.data if C.shape[0] > 1 else None, None, None,
                     ctx.model_if(C.shape[-1])), ctx)
        n = cst(n, P(ctx.axes.data if n.shape[0] > 1 else None, None, None),
                ctx)
        return C, n

    def step(carry, inp):
        C, n = carry
        C, n = _cst_state(C, n)
        qq, kk, vv, li, lf = inp
        qq, kk, vv = (t.astype(jnp.float32) for t in (qq, kk, vv))
        cum = jnp.cumsum(lf, axis=1)                       # [B, q, H]
        total = cum[:, -1]
        dec_in = jnp.exp(cum)                              # decay applied to carry-in
        y_prev = jnp.einsum("bqhk,bhkv->bqhv", qq * dec_in[..., None], C) * scale
        n_prev = jnp.einsum("bqhk,bhk->bqh", qq * dec_in[..., None], n) * scale
        rel = cum[:, :, None, :] - cum[:, None, :, :]      # [B, q, t, H]
        g = rel + li[:, None, :, :]                        # + log i_t
        mask = jnp.tril(jnp.ones((qc, qc), bool))
        gate = jnp.where(mask[None, :, :, None], jnp.exp(g), 0.0)
        scores = jnp.einsum("bqhk,bthk->bqth", qq, kk) * scale * gate
        y_intra = jnp.einsum("bqth,bthv->bqhv", scores, vv)
        # normalizer: n_q = dec_in*n0 + sum_{t<=q} exp(cum_q-cum_t+li_t) k_t
        kgate = jnp.einsum("bqth,bthk->bqhk", gate, kk)
        dec_out = jnp.exp(total[:, None, :] - cum) * jnp.exp(li)   # [B, q, H]
        C_new = jnp.exp(total)[:, :, None, None] * C + jnp.einsum(
            "bqhk,bqhv->bhkv", kk * dec_out[..., None], vv
        )
        n_new = jnp.exp(total)[:, :, None] * n + jnp.einsum(
            "bqh,bqhk->bhk", dec_out, kk
        )
        n_q = dec_in[..., None] * n[:, None] + kgate
        qn = jnp.einsum("bqhk,bqhk->bqh", qq, n_q) * scale
        denom = jnp.maximum(jnp.abs(qn), 1.0)
        y = (y_prev + y_intra) / denom[..., None]
        C_new, n_new = _cst_state(C_new, n_new)
        return (C_new, n_new), y

    if remat:
        step = jax.checkpoint(step)  # see dense._attention_remat
    (C, n), yc = jax.lax.scan(step, (C0, n0), (qcs, kcs, vcs, lic, lfc))
    y = yc.swapaxes(0, 1).reshape(b, nc * qc, h, dh)[:, :s]
    return y, MLSTMCache(C, n)


def mlstm_step(cache: MLSTMCache, q, k, v, log_i, log_f):
    """Single token. q/k/v [B,H,dh]; log_i/log_f [B,H]."""
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(dh)
    f = jnp.exp(log_f)[..., None]
    i = jnp.exp(log_i)[..., None]
    k32, v32, q32 = (t.astype(jnp.float32) for t in (k, v, q))
    C = f[..., None] * cache.C + i[..., None] * k32[..., :, None] * v32[..., None, :]
    n = f * cache.n + i * k32
    num = jnp.einsum("bhk,bhkv->bhv", q32, C) * scale
    qn = jnp.einsum("bhk,bhk->bh", q32, n) * scale
    y = num / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    return MLSTMCache(C, n), y


def mlstm_block(cfg, bp, x, chunk, cache: Optional[MLSTMCache],
                single: bool = False, ctx=None):
    b, s, D = x.shape
    d_inner, H, dh = mdims(cfg)
    u = rms_norm(x, bp["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", u, bp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", u, bp["wk"])
    v = jnp.einsum("bsd,dhv->bshv", u, bp["wv"])
    li, lf = _mlstm_gates(bp, u)
    if single:
        new_cache, y = mlstm_step(cache, q[:, 0], k[:, 0], v[:, 0], li[:, 0], lf[:, 0])
        y = y[:, None]
    else:
        y, new_cache = mlstm_scan(q, k, v, li, lf, chunk, cache,
                                  remat=cache is None, ctx=ctx)
    og = jax.nn.sigmoid(jnp.einsum("bsd,di->bsi", u, bp["w_og"]).astype(jnp.float32))
    y = y.reshape(b, s, d_inner) * og
    y = rms_norm(y.astype(x.dtype), bp["out_norm"], cfg.norm_eps)
    return x + jnp.einsum("bsi,id->bsd", y, bp["w_out"]), new_cache


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def slstm_init(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    H, d = sdims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((D,), dt),
        "w_gates": dense_init(ks[0], (D, 4, H, d), jnp.float32),   # i, f, z, o
        "r_gates": dense_init(ks[1], (H, d, 4, d), jnp.float32,
                              scale=1.0 / jnp.sqrt(d)),
        "b_gates": jnp.zeros((4, H, d), jnp.float32),
        "out_norm": jnp.ones((D,), dt),
        "w_out": dense_init(ks[2], (D, D), dt, scale=1.0 / jnp.sqrt(D)),
        "w_up": dense_init(ks[3], (D, 2 * D), dt),
        "w_down": dense_init(jax.random.fold_in(key, 5), (2 * D, D), dt,
                             scale=1.0 / jnp.sqrt(D)),
    }


def slstm_specs(cfg: ModelConfig, ctx: ShardingCtx) -> dict:
    a = ctx.axes
    return {
        "norm": P(None),
        "w_gates": P(ctx.pdata, None, None, None),
        "r_gates": P(None, None, None, None),
        "b_gates": P(None, None, None),
        "out_norm": P(None),
        "w_out": P(ctx.pdata, None),
        "w_up": P(ctx.pdata, ctx.model_if(2 * cfg.d_model)),
        "w_down": P(ctx.model_if(2 * cfg.d_model), ctx.pdata),
    }


class SLSTMCache(NamedTuple):
    h: jnp.ndarray  # [B, H, d]
    c: jnp.ndarray
    n: jnp.ndarray
    m: jnp.ndarray


def slstm_cache(cfg: ModelConfig, batch: int) -> SLSTMCache:
    H, d = sdims(cfg)
    z = jnp.zeros((batch, H, d), jnp.float32)
    return SLSTMCache(h=z, c=z, n=z, m=jnp.full((batch, H, d), -1e30, jnp.float32))


def slstm_cache_specs(cfg: ModelConfig, ctx: ShardingCtx, batch: int) -> SLSTMCache:
    b_ax = ctx.data_if(batch) if batch > 1 else None
    s = P(b_ax, None, None)
    return SLSTMCache(h=s, c=s, n=s, m=s)


def _slstm_cell(carry: SLSTMCache, gx, r, b):
    """One timestep. gx [B,4,H,d] pre-activations from the input."""
    h, c, n, m = carry
    rec = jnp.einsum("bhd,hdge->bghe", h.astype(r.dtype), r,
                     preferred_element_type=jnp.float32)
    pre = gx + rec + b
    it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    m_new = jnp.maximum(ft + m, it)
    i = jnp.exp(it - m_new)
    f = jnp.exp(ft + m - m_new)
    c_new = f * c + i * jnp.tanh(zt)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMCache(h_new, c_new, n_new, m_new), h_new


@jax.custom_vjp
def _slstm_core(gx, r, b_gates, h0, c0, n0, m0):
    """Time scan over _slstm_cell. gx [S, B, 4, H, d] pre-activations.

    Custom VJP: jax's scan autodiff accumulates the recurrent wgrad dR as a
    loop carry, which under SPMD inserts an all-reduce PER TIMESTEP (measured
    3.3 TB/device/step at 1.3B train_4k). This hand-written BPTT saves the
    per-step gate activations, runs the sequential dh recurrence, and forms
    dR with ONE einsum over (time x batch) outside the loop — a single
    deferred reduction. The m-stabilizer is treated as constant, which is
    EXACT: h is invariant to m (c~, n~ are reparametrizations)."""
    carry, hs = jax.lax.scan(
        lambda cr, g: _slstm_cell(cr, g, r, b_gates),
        SLSTMCache(h0, c0, n0, m0), gx)
    return hs, carry.h, carry.c, carry.n, carry.m


def _slstm_core_fwd(gx, r, b_gates, h0, c0, n0, m0):
    def step(cr, g):
        rec = jnp.einsum("bhd,hdge->bghe", cr.h.astype(r.dtype), r,
                         preferred_element_type=jnp.float32)
        pre = g + rec + b_gates
        it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        m_new = jnp.maximum(ft + cr.m, it)
        i = jnp.exp(it - m_new)
        f = jnp.exp(ft + cr.m - m_new)
        tz = jnp.tanh(zt)
        so = jax.nn.sigmoid(ot)
        c_new = f * cr.c + i * tz
        n_new = f * cr.n + i
        h_new = so * c_new / jnp.maximum(n_new, 1e-6)
        saved = (cr.h, cr.c, cr.n, i, f, tz, so, c_new, n_new)
        return SLSTMCache(h_new, c_new, n_new, m_new), (h_new, saved)

    carry, (hs, saved) = jax.lax.scan(step, SLSTMCache(h0, c0, n0, m0), gx)
    return (hs, carry.h, carry.c, carry.n, carry.m), (saved, r)


def _slstm_core_bwd(res, cts):
    saved, r = res
    d_hs, d_hT, d_cT, d_nT, _d_mT = cts
    (hprev, cprev, nprev, i, f, tz, so, c, n) = saved

    def back(carry, inp):
        dh_next, dc_next, dn_next = carry
        d_h_t, hp, cp, np_, i_t, f_t, tz_t, so_t, c_t, n_t = inp
        dh = d_h_t + dh_next
        nn = jnp.maximum(n_t, 1e-6)
        do_pre = dh * (c_t / nn) * so_t * (1 - so_t)
        dc = dh * so_t / nn + dc_next
        dn = -dh * so_t * c_t / (nn * nn) + dn_next
        dz_pre = dc * i_t * (1 - tz_t * tz_t)
        di_pre = (dc * tz_t + dn) * i_t
        df_pre = (dc * cp + dn * np_) * f_t
        dpre = jnp.stack([di_pre, df_pre, dz_pre, do_pre], axis=1)  # [B,4,H,d]
        dh_prev = jnp.einsum("bghe,hdge->bhd", dpre.astype(r.dtype), r,
                             preferred_element_type=jnp.float32)
        return (dh_prev, dc * f_t, dn * f_t), dpre

    (dh0, dc0, dn0), dpre = jax.lax.scan(
        back, (d_hT, d_cT, d_nT),
        (d_hs, hprev, cprev, nprev, i, f, tz, so, c, n),
        reverse=True)
    # ONE deferred wgrad reduction instead of one per timestep:
    dr = jnp.einsum("sbhd,sbghe->hdge", hprev, dpre)
    db = jnp.sum(dpre, axis=(0, 1))
    return dpre, dr, db, dh0, dc0, dn0, jnp.zeros_like(dh0)


_slstm_core.defvjp(_slstm_core_fwd, _slstm_core_bwd)


def slstm_block(cfg, bp, x, cache: Optional[SLSTMCache]):
    """Sequential sLSTM over the full sequence. x [B, S, D]."""
    b, s, D = x.shape
    H, d = sdims(cfg)
    u = rms_norm(x, bp["norm"], cfg.norm_eps)
    gx = jnp.einsum("bsd,dghe->bsghe", u.astype(jnp.float32), bp["w_gates"])
    carry = slstm_cache(cfg, b) if cache is None else cache

    # bf16 recurrent matvec: R is read once per TIMESTEP from HBM — casting
    # it to the model dtype halves the dominant byte stream (EXPERIMENTS.md
    # §Perf xlstm iteration 4); accumulation stays fp32.
    r_cast = bp["r_gates"].astype(jnp.dtype(cfg.dtype))
    hs, hT, cT, nT, mT = _slstm_core(
        gx.swapaxes(0, 1), r_cast, bp["b_gates"],
        carry.h, carry.c, carry.n, carry.m)
    carry = SLSTMCache(hT, cT, nT, mT)
    y = hs.swapaxes(0, 1).reshape(b, s, D).astype(x.dtype)
    y = rms_norm(y, bp["out_norm"], cfg.norm_eps)
    x = x + jnp.einsum("bsd,de->bse", y, bp["w_out"])
    # post-block GELU MLP (paper's projection block, factor 2)
    hmlp = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, bp["w_up"]))
    return x + jnp.einsum("bsf,fd->bsd", hmlp, bp["w_down"]), carry


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------


def _group_struct(cfg: ModelConfig):
    per = cfg.slstm_group
    assert cfg.num_layers % per == 0, "num_layers must divide slstm_group"
    return cfg.num_layers // per, per - 1  # (groups, mlstm per group)


def init(cfg: ModelConfig, key) -> dict:
    G, M = _group_struct(cfg)
    dt = jnp.dtype(cfg.dtype)
    vp = pad_vocab(cfg.vocab_size)
    ks = jax.random.split(key, 4)

    def stack(fn, k, n):
        return jax.vmap(lambda kk: fn(cfg, kk))(jax.random.split(k, n))

    def stack2(fn, k):
        return jax.vmap(lambda kr: jax.vmap(lambda kk: fn(cfg, kk))(
            jax.random.split(kr, M)))(jax.random.split(k, G))

    return {
        "embed": embed_init(ks[0], (vp, cfg.d_model), dt),
        "mlstm": stack2(mlstm_init, ks[1]),          # [G, M, ...]
        "slstm": stack(slstm_init, ks[2], G),        # [G, ...]
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense_init(ks[3], (cfg.d_model, vp), dt),
    }


def param_specs(cfg: ModelConfig, ctx: ShardingCtx) -> dict:
    vp = pad_vocab(cfg.vocab_size)
    mspec = mlstm_specs(cfg, ctx)
    sspec = slstm_specs(cfg, ctx)
    return {
        "embed": P(ctx.model_if(vp), ctx.pdata_if(cfg.d_model)),
        "mlstm": jax.tree.map(lambda s: P(None, None, *s), mspec,
                              is_leaf=lambda x: isinstance(x, P)),
        "slstm": jax.tree.map(lambda s: P(None, *s), sspec,
                              is_leaf=lambda x: isinstance(x, P)),
        "final_norm": P(None),
        "lm_head": P(ctx.pdata_if(cfg.d_model), ctx.model_if(vp)),
    }


class XLSTMCache(NamedTuple):
    mlstm: MLSTMCache    # leaves stacked [G, M, ...]
    slstm: SLSTMCache    # leaves stacked [G, ...]


def init_cache(cfg: ModelConfig, batch: int, seq_len: int = 0) -> XLSTMCache:
    G, M = _group_struct(cfg)
    mc = mlstm_cache(cfg, batch)
    sc = slstm_cache(cfg, batch)
    return XLSTMCache(
        mlstm=jax.tree.map(lambda x: jnp.broadcast_to(x, (G, M) + x.shape), mc),
        slstm=jax.tree.map(lambda x: jnp.broadcast_to(x, (G,) + x.shape), sc),
    )


def cache_specs(cfg: ModelConfig, ctx: ShardingCtx, batch: int, seq_len: int = 0):
    mc = mlstm_cache_specs(cfg, ctx, batch)
    sc = slstm_cache_specs(cfg, ctx, batch)
    return XLSTMCache(
        mlstm=jax.tree.map(lambda s: P(None, None, *s), mc,
                           is_leaf=lambda x: isinstance(x, P)),
        slstm=jax.tree.map(lambda s: P(None, *s), sc,
                           is_leaf=lambda x: isinstance(x, P)),
    )


def _x_spec(ctx):
    """Residual spec for xLSTM: batch over data, sequence REPLICATED.

    Both mixers are scans (chunk scan / time scan); sequence sharding over
    ``model`` forces an all-gather per chunk reshape and — far worse — turns
    the sLSTM recurrent wgrad into a per-TIMESTEP all-reduce (measured
    3.3 TB/device/step at 1.3B train_4k). Activations are small (no d_ff),
    so replicating the sequence dim costs ~16 MB/layer-save and removes the
    pathological wire traffic."""
    if ctx is None:
        return P()
    return P(ctx.axes.data, None, None)


def _stack_forward(cfg, params, x, ctx, cache: Optional[XLSTMCache], single: bool):
    """Scan over super-blocks; inner scan over the M mLSTM layers."""
    s = x.shape[1]
    chunk = cfg.ssm_chunk or 256

    def super_block(xc, scanned):
        gp_m, gp_s, cm, cs = scanned

        def inner(xc2, scanned2):
            lp, cl = scanned2
            xc2, cl_new = mlstm_block(cfg, lp, xc2, chunk, cl, single=single,
                                      ctx=ctx)
            return xc2, cl_new

        xc, cm_new = jax.lax.scan(inner, xc, (gp_m, cm))
        xc = cst(xc, _x_spec(ctx), ctx)
        xc, cs_new = slstm_block(cfg, gp_s, xc, cs)
        return cst(xc, _x_spec(ctx), ctx), (cm_new, cs_new)

    # per-super-block remat: without it the backward saves every mLSTM
    # chunk input across all L layers (~30 GiB/device at 1.3B train_4k)
    body_fn = (jax.checkpoint(super_block)
               if cfg.remat and not single else super_block)
    if cache is None:
        b = x.shape[0]
        cache = init_cache(cfg, b)
    x, (cm, cs) = jax.lax.scan(
        body_fn, x, (params["mlstm"], params["slstm"], cache.mlstm, cache.slstm)
    )
    return x, XLSTMCache(cm, cs)


def forward(cfg: ModelConfig, params, tokens, ctx=None, **_):
    x = _embed(cfg, params, tokens, None)
    x = cst(x, _x_spec(ctx), ctx)
    x, _cache = _stack_forward(cfg, params, x, ctx, None, single=False)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x, ctx)


def loss_fn(cfg: ModelConfig, params, batch, ctx=None, **_):
    logits = forward(cfg, params, batch["tokens"], ctx)
    return token_xent(logits[:, :-1], batch["labels"][:, 1:], batch.get("weights"))


def prefill(cfg: ModelConfig, params, tokens, ctx=None, **_):
    x = _embed(cfg, params, tokens, None)
    x = cst(x, _x_spec(ctx), ctx)
    x, cache = _stack_forward(cfg, params, x, ctx, None, single=False)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x, ctx)[:, 0], cache


def decode_step(cfg: ModelConfig, params, cache: XLSTMCache, token, pos, ctx=None):
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    x = x.reshape(b, 1, -1)
    x, cache = _stack_forward(cfg, params, x, ctx, cache, single=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x, ctx)[:, 0], cache
