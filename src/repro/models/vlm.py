"""Llama-3.2-Vision-style VLM decoder [hf:meta-llama/Llama-3.2-11B-Vision].

The language backbone: groups of (cross_attn_every-1) self-attention layers
followed by one gated cross-attention layer over precomputed image patch
embeddings. The ViT vision encoder + projector frontend is STUBBED per the
assignment carve-out — ``images`` in every batch are [B, num_image_tokens,
d_model] embeddings (``input_specs()`` supplies the ShapeDtypeStruct).

Cross-attention layers are tanh-gated (zero-init gates, as in Llama-3.2) so
the model starts as a pure LM.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import dense
from repro.models.dense import cst, _seq_spec, token_xent
from repro.models.layers import dense_init, embed_init, rms_norm, swiglu
from repro.models.specs import ShardingCtx, pad_vocab


def _struct(cfg: ModelConfig):
    per = cfg.cross_attn_every
    assert cfg.num_layers % per == 0
    return cfg.num_layers // per, per - 1  # (groups, self-layers per group)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _self_layer_init(cfg, key):
    p = dense.init(cfg.with_(num_layers=1), key)["layers"]
    return jax.tree.map(lambda x: x[0], p)


def _cross_layer_init(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    D, F = cfg.d_model, cfg.d_ff
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // hkv
    ks = jax.random.split(key, 8)
    return {
        "attn_norm": jnp.ones((D,), dt),
        "kv_norm": jnp.ones((D,), dt),
        "wq": dense_init(ks[0], (D, hkv, g, hd), dt),
        "wk": dense_init(ks[1], (D, hkv, hd), dt),
        "wv": dense_init(ks[2], (D, hkv, hd), dt),
        "wo": dense_init(ks[3], (hkv, g, hd, D), dt, scale=1.0 / jnp.sqrt(D)),
        "gate_attn": jnp.zeros((), jnp.float32),
        "mlp_norm": jnp.ones((D,), dt),
        "w_gate": dense_init(ks[4], (D, F), dt),
        "w_up": dense_init(ks[5], (D, F), dt),
        "w_down": dense_init(ks[6], (F, D), dt, scale=1.0 / jnp.sqrt(D)),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def init(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    vp = pad_vocab(cfg.vocab_size)
    G, M = _struct(cfg)
    ks = jax.random.split(key, 5)
    self_layers = jax.vmap(
        lambda kr: jax.vmap(lambda kk: _self_layer_init(cfg, kk))(
            jax.random.split(kr, M))
    )(jax.random.split(ks[1], G))
    cross_layers = jax.vmap(lambda k: _cross_layer_init(cfg, k))(
        jax.random.split(ks[2], G))
    return {
        "embed": embed_init(ks[0], (vp, cfg.d_model), dt),
        "self_layers": self_layers,     # [G, M, ...]
        "cross_layers": cross_layers,   # [G, ...]
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense_init(ks[3], (cfg.d_model, vp), dt),
    }


def param_specs(cfg: ModelConfig, ctx: ShardingCtx) -> dict:
    vp = pad_vocab(cfg.vocab_size)
    lyr = dense.param_specs(cfg, ctx)["layers"]
    lyr = {k: P(*s[1:]) for k, s in lyr.items()}  # drop the stacked-L axis
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // hkv
    a = ctx.axes
    cross = {
        "attn_norm": P(None),
        "kv_norm": P(None),
        "wq": ctx.attn_q_spec(hkv, g, hd),
        "wk": ctx.attn_kv_spec(hkv, hd),
        "wv": ctx.attn_kv_spec(hkv, hd),
        "wo": ctx.attn_o_spec(hkv, g, hd),
        "gate_attn": P(),
        "mlp_norm": P(None),
        "w_gate": P(ctx.pdata, a.model),
        "w_up": P(ctx.pdata, a.model),
        "w_down": P(a.model, ctx.pdata),
        "gate_mlp": P(),
    }
    return {
        "embed": P(ctx.model_if(vp), ctx.pdata_if(cfg.d_model)),
        "self_layers": jax.tree.map(lambda s: P(None, None, *s), lyr,
                                    is_leaf=lambda x: isinstance(x, P)),
        "cross_layers": jax.tree.map(lambda s: P(None, *s), cross,
                                     is_leaf=lambda x: isinstance(x, P)),
        "final_norm": P(None),
        "lm_head": P(ctx.pdata_if(cfg.d_model), ctx.model_if(vp)),
    }


# ---------------------------------------------------------------------------
# Cross-attention layer
# ---------------------------------------------------------------------------


def _cross_kv(cfg, cp, images):
    """Image embeddings [B, I, D] -> (k, v) [B, I, Hkv, hd]."""
    img = rms_norm(images, cp["kv_norm"], cfg.norm_eps)
    k = jnp.einsum("bid,dkh->bikh", img, cp["wk"])
    v = jnp.einsum("bid,dkh->bikh", img, cp["wv"])
    return k, v


def _cross_layer(cfg, cp, x, kv, ctx):
    s = x.shape[1]
    h = rms_norm(x, cp["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dkgh->bskgh", h, cp["wq"])
    k, v = kv
    o = attn_lib.attention(q, k, v, causal=False)
    g_attn = jnp.tanh(cp["gate_attn"]).astype(x.dtype)  # keep bf16 residual
    x = x + g_attn * dense._attn_out(cp, o)
    x = cst(x, _seq_spec(ctx, s), ctx)
    h = rms_norm(x, cp["mlp_norm"], cfg.norm_eps)
    g_mlp = jnp.tanh(cp["gate_mlp"]).astype(x.dtype)
    x = x + g_mlp * swiglu(h, cp["w_gate"], cp["w_up"], cp["w_down"])
    return cst(x, _seq_spec(ctx, s), ctx)


# ---------------------------------------------------------------------------
# Forward / loss / prefill / decode
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params, tokens, images, ctx=None, *, chunk=None,
            window=None):
    b, s = tokens.shape
    if chunk is None and s > 2048:
        chunk = 2048
    positions = jnp.arange(s)
    x = dense._embed(cfg, params, tokens, ctx)
    images = images.astype(jnp.dtype(cfg.dtype))

    def group_body(xc, scanned):
        gp_self, gp_cross = scanned

        def inner(xc2, lp):
            return dense.decoder_layer(cfg, lp, xc2, positions, ctx,
                                       window=window, chunk=chunk), None

        xc, _ = jax.lax.scan(inner, xc, gp_self)
        kv = _cross_kv(cfg, gp_cross, images)
        xc = _cross_layer(cfg, gp_cross, xc, kv, ctx)
        return xc, None

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    x, _ = jax.lax.scan(body, x, (params["self_layers"], params["cross_layers"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return dense._logits(cfg, params, x, ctx)


def loss_fn(cfg: ModelConfig, params, batch, ctx=None, **kw):
    logits = forward(cfg, params, batch["tokens"], batch["images"], ctx, **kw)
    return token_xent(logits[:, :-1], batch["labels"][:, 1:], batch.get("weights"))


class VLMCache(NamedTuple):
    k: jnp.ndarray        # self-attn [L_self_total=G*M, B, T, Hkv, hd]
    v: jnp.ndarray
    xk: jnp.ndarray       # cross-attn (static) [G, B, I, Hkv, hd]
    xv: jnp.ndarray


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> VLMCache:
    G, M = _struct(cfg)
    t = dense.cache_len(cfg, seq_len)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return VLMCache(
        k=jnp.zeros((G, M, batch, t, hkv, hd), dt),
        v=jnp.zeros((G, M, batch, t, hkv, hd), dt),
        xk=jnp.zeros((G, batch, cfg.num_image_tokens, hkv, hd), dt),
        xv=jnp.zeros((G, batch, cfg.num_image_tokens, hkv, hd), dt),
    )


def cache_specs(cfg: ModelConfig, ctx: ShardingCtx, batch: int, seq_len: int):
    t = dense.cache_len(cfg, seq_len)
    b_ax = ctx.data_if(batch) if batch > 1 else None
    kv = P(None, None, b_ax, ctx.model_if(t), None, None)
    xkv = P(None, b_ax, None, None, None)
    return VLMCache(k=kv, v=kv, xk=xkv, xv=xkv)


def prefill(cfg: ModelConfig, params, tokens, images, ctx=None, *, chunk=2048):
    b, s = tokens.shape
    positions = jnp.arange(s)
    x = dense._embed(cfg, params, tokens, ctx)
    images = images.astype(jnp.dtype(cfg.dtype))
    window = cfg.window if (cfg.window and s > cfg.window) else None

    def group_body(xc, scanned):
        gp_self, gp_cross = scanned

        def inner(xc2, lp):
            h = rms_norm(xc2, lp["attn_norm"], cfg.norm_eps)
            q, k, v = dense._qkv(cfg, lp, h, positions)
            o = attn_lib.attention(q, k, v, causal=True, window=window, chunk=chunk)
            xc2 = xc2 + dense._attn_out(lp, o)
            xc2 = cst(xc2, _seq_spec(ctx, s), ctx)
            h = rms_norm(xc2, lp["mlp_norm"], cfg.norm_eps)
            xc2 = xc2 + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
            return cst(xc2, _seq_spec(ctx, s), ctx), (k, v)

        xc, (ks, vs) = jax.lax.scan(inner, xc, gp_self)
        kv = _cross_kv(cfg, gp_cross, images)
        xc = _cross_layer(cfg, gp_cross, xc, kv, ctx)
        return xc, (ks, vs, kv[0], kv[1])

    x, (ks, vs, xks, xvs) = jax.lax.scan(
        group_body, x, (params["self_layers"], params["cross_layers"]))
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = dense._logits(cfg, params, x, ctx)[:, 0]
    return logits, VLMCache(k=ks, v=vs, xk=xks, xv=xvs)


def decode_step(cfg: ModelConfig, params, cache: VLMCache, token, pos, ctx=None):
    b = token.shape[0]
    t = cache.k.shape[3]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    x = x.reshape(b, 1, -1)
    positions = pos[None] if pos.ndim == 0 else pos
    rolling = cfg.window is not None and t == cfg.window
    slot = (pos % t) if rolling else pos
    if rolling:
        kv_pos = dense._rolling_kv_pos(pos, t)
        kv_pos = jnp.where(kv_pos < 0, 2**30, kv_pos)
    else:
        kv_pos = jnp.arange(t)

    def group_body(xc, scanned):
        gp_self, gp_cross, ck, cv, xk, xv = scanned

        def inner(xc2, scanned2):
            lp, ckl, cvl = scanned2
            h = rms_norm(xc2, lp["attn_norm"], cfg.norm_eps)
            q, k, v = dense._qkv(cfg, lp, h, positions)
            ckl = jax.lax.dynamic_update_slice_in_dim(ckl, k, slot, axis=1)
            cvl = jax.lax.dynamic_update_slice_in_dim(cvl, v, slot, axis=1)
            o = attn_lib.attention(
                q, ckl, cvl, q_pos=positions, kv_pos=kv_pos, causal=True,
                window=cfg.window if rolling else None,
                kv_len=None if rolling else pos + 1)
            xc2 = xc2 + dense._attn_out(lp, o)
            h = rms_norm(xc2, lp["mlp_norm"], cfg.norm_eps)
            xc2 = xc2 + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
            return xc2, (ckl, cvl)

        xc, (ck, cv) = jax.lax.scan(inner, xc, (gp_self, ck, cv))
        xc = _cross_layer(cfg, gp_cross, xc, (xk, xv), ctx)
        return xc, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        group_body, x,
        (params["self_layers"], params["cross_layers"],
         cache.k, cache.v, cache.xk, cache.xv))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = dense._logits(cfg, params, x, ctx)[:, 0]
    return logits, VLMCache(k=ks, v=vs, xk=cache.xk, xv=cache.xv)
