"""Mesh-axis abstraction + PartitionSpec helpers.

Axis roles:
  - ``data``  (optionally combined with ``pod``): FL-client / batch parallelism
    AND FSDP-style parameter/optimizer sharding.
  - ``model``: Megatron-style tensor parallelism (heads / d_ff / vocab /
    experts / KV-sequence for decode split-K).

Every model module builds its params and a *matching* PartitionSpec tree from
these helpers, so pjit in/out shardings are derived mechanically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple, Union

from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class MeshAxes:
    """Logical -> physical axis mapping."""

    data: Union[str, Tuple[str, ...]] = "data"   # ("pod","data") when multi-pod
    model: str = "model"

    @property
    def batch(self):
        return self.data

    @classmethod
    def for_mesh(cls, mesh) -> "MeshAxes":
        names = mesh.axis_names
        if "pod" in names:
            return cls(data=("pod", "data"), model="model")
        return cls(data="data", model="model")


SINGLE_POD = MeshAxes(data="data", model="model")
MULTI_POD = MeshAxes(data=("pod", "data"), model="model")


def replicated() -> P:
    return P()


def row_parallel(axes: MeshAxes) -> P:
    """[in_dim, out_dim] with in_dim sharded on model (output needs psum)."""
    return P(axes.model, None)


def col_parallel(axes: MeshAxes) -> P:
    """[in_dim, out_dim] with out_dim sharded on model."""
    return P(None, axes.model)


def fsdp_col(axes: MeshAxes) -> P:
    """[in_dim, out_dim]: in_dim FSDP-sharded over data, out_dim over model."""
    return P(axes.data, axes.model)


def fsdp_row(axes: MeshAxes) -> P:
    """[in_dim, out_dim]: in_dim over model, out_dim FSDP-sharded over data."""
    return P(axes.model, axes.data)


def stack(spec: P) -> P:
    """Prepend the scanned-layer axis (unsharded)."""
    return P(None, *spec)


def batch_spec(axes: MeshAxes, ndim: int = 2) -> P:
    """Activations/tokens [batch, ...] sharded over the data axes."""
    return P(axes.batch, *([None] * (ndim - 1)))
