"""Mamba2 (SSD) block — chunked scan formulation, TPU-native.

The SSD recurrence per head h (state S in R^{N x P}):

    S_t = exp(dt_t * a_h) * S_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . S_t + D_h * x_t

is computed chunk-parallel: within a chunk the contribution is a masked
quadratic form (the "attention-like" term of the SSD duality); across chunks
a ``lax.scan`` carries the [B, H, N, P] state. The chunk length bounds the
materialized score block (the same trick as online-softmax attention) and the
sequential dependency stays on-chip.

Sharding: d_inner (and hence heads) over ``model``; B/C projections (small,
N=64-128) replicated; out_proj row-parallel with a psum folded by GSPMD.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.specs import ShardingCtx


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_headdim
    return d_inner, heads, cfg.ssm_headdim, cfg.ssm_state


# ---------------------------------------------------------------------------
# Params (one block; stacking over layers is done by the caller)
# ---------------------------------------------------------------------------


def block_init(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    d_inner, H, Pd, N = dims(cfg)
    W = cfg.conv_width
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((D,), dt),
        "w_x": dense_init(ks[0], (D, d_inner), dt),
        "w_z": dense_init(ks[1], (D, d_inner), dt),
        "w_B": dense_init(ks[2], (D, N), dt),
        "w_C": dense_init(ks[3], (D, N), dt),
        "w_dt": dense_init(ks[4], (D, H), dt),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),  # a = -exp(A_log) = -1
        "D_skip": jnp.ones((H,), jnp.float32),
        "conv_x": dense_init(ks[5], (W, d_inner), dt, scale=1.0 / W),
        "conv_B": dense_init(ks[6], (W, N), dt, scale=1.0 / W),
        "conv_C": dense_init(ks[7], (W, N), dt, scale=1.0 / W),
        "out_norm": jnp.ones((d_inner,), dt),
        "w_out": dense_init(jax.random.fold_in(key, 9), (d_inner, D), dt,
                            scale=1.0 / jnp.sqrt(D)),
    }


def block_specs(cfg: ModelConfig, ctx: ShardingCtx) -> dict:
    a = ctx.axes
    d_inner, H, Pd, N = dims(cfg)
    m_in = ctx.model_if(d_inner)
    m_h = ctx.model_if(H)
    return {
        "norm": P(None),
        "w_x": P(ctx.pdata, m_in),
        "w_z": P(ctx.pdata, m_in),
        "w_B": P(ctx.pdata, None),
        "w_C": P(ctx.pdata, None),
        "w_dt": P(ctx.pdata, m_h),
        "dt_bias": P(m_h),
        "A_log": P(m_h),
        "D_skip": P(m_h),
        "conv_x": P(None, m_in),
        "conv_B": P(None, None),
        "conv_C": P(None, None),
        "out_norm": P(m_in),
        "w_out": P(m_in, ctx.pdata),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------


def causal_conv(x: jnp.ndarray, w: jnp.ndarray, tail: Optional[jnp.ndarray] = None):
    """x [B, S, C], w [W, C] depthwise causal conv; ``tail`` [B, W-1, C] is the
    carry-in from previous tokens (decode). Returns (y [B, S, C], new tail)."""
    width = w.shape[0]
    b = x.shape[0]
    if tail is None:
        tail = jnp.zeros((b, width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i]
        for i in range(width)
    )
    new_tail = xp[:, -(width - 1):, :]
    return jax.nn.silu(y), new_tail


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------


def ssd_scan(xh, dt, a, Bm, Cm, chunk: int, state0=None, remat: bool = False):
    """Chunk-parallel SSD.

    xh: [B, S, H, P] inputs; dt: [B, S, H] (post-softplus); a: [H] (negative);
    Bm, Cm: [B, S, N] (single group shared across heads).
    Returns (y [B, S, H, P], final state [B, H, N, P]).
    """
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    # fold dt into the input; per-step log decay
    xdt = (xh * dt[..., None]).astype(jnp.float32)
    la = (dt * a).astype(jnp.float32)                       # [B, S', H] (<= 0)

    def reshape_c(t):
        return t.reshape((b, nc, q) + t.shape[2:]).swapaxes(0, 1)

    xc, lac, Bc, Cc = map(reshape_c, (xdt, la, Bm.astype(jnp.float32),
                                      Cm.astype(jnp.float32)))
    # xc: [nc, B, q, H, P]; lac: [nc, B, q, H]; Bc/Cc: [nc, B, q, N]

    if state0 is None:
        state0 = jnp.zeros((b, h, n, p), jnp.float32)

    def chunk_step(state, inp):
        xq, laq, Bq, Cq = inp
        cum = jnp.cumsum(laq, axis=1)                       # [B, q, H]
        total = cum[:, -1]                                  # [B, H]
        # --- inter-chunk: y_prev[t] = C_t . (decay_to_t * S_in)
        decay_in = jnp.exp(cum)                             # [B, q, H]
        y_prev = jnp.einsum("bqn,bhnp->bqhp", Cq, state) * decay_in[..., None]
        # --- intra-chunk quadratic term
        rel = cum[:, :, None, :] - cum[:, None, :, :]       # [B, q, t, H]
        mask = jnp.tril(jnp.ones((q, q), bool))
        gate = jnp.where(mask[None, :, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("bqn,btn->bqt", Cq, Bq)[..., None] * gate
        y_intra = jnp.einsum("bqth,bthp->bqhp", scores, xq)
        # --- state passing
        decay_out = jnp.exp(total[:, None, :] - cum)        # [B, q, H]
        s_new = jnp.exp(total)[:, :, None, None] * state + jnp.einsum(
            "bqn,bqhp->bhnp", Bq, xq * decay_out[..., None]
        )
        return s_new, y_prev + y_intra

    if remat:
        # save only the [B,H,N,P] state per chunk; recompute the quadratic
        # block in backward (see dense._attention_remat)
        chunk_step = jax.checkpoint(chunk_step)
    state, yc = jax.lax.scan(chunk_step, state0, (xc, lac, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(b, nc * q, h, p)[:, :s]
    return y, state


def ssd_step(state, x1, dt1, a, B1, C1):
    """Single-token recurrence (decode). x1 [B, H, P]; dt1 [B, H]; B1/C1 [B, N]."""
    decay = jnp.exp(dt1 * a)                                # [B, H]
    upd = jnp.einsum("bn,bhp->bhnp", B1.astype(jnp.float32),
                     (x1 * dt1[..., None]).astype(jnp.float32))
    state = decay[..., None, None] * state + upd
    y = jnp.einsum("bn,bhnp->bhp", C1.astype(jnp.float32), state)
    return state, y


# ---------------------------------------------------------------------------
# Full block forward / step
# ---------------------------------------------------------------------------


class SSMCache(NamedTuple):
    state: jnp.ndarray   # [B, H, N, P] fp32
    conv_x: jnp.ndarray  # [B, W-1, d_inner]
    conv_B: jnp.ndarray  # [B, W-1, N]
    conv_C: jnp.ndarray  # [B, W-1, N]


def init_block_cache(cfg: ModelConfig, batch: int) -> SSMCache:
    d_inner, H, Pd, N = dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    W = cfg.conv_width
    return SSMCache(
        state=jnp.zeros((batch, H, N, Pd), jnp.float32),
        conv_x=jnp.zeros((batch, W - 1, d_inner), dt),
        conv_B=jnp.zeros((batch, W - 1, N), dt),
        conv_C=jnp.zeros((batch, W - 1, N), dt),
    )


def block_cache_specs(cfg: ModelConfig, ctx: ShardingCtx, batch: int) -> SSMCache:
    d_inner, H, Pd, N = dims(cfg)
    b_ax = ctx.data_if(batch) if batch > 1 else None
    return SSMCache(
        state=P(b_ax, ctx.model_if(H), None, None),
        conv_x=P(b_ax, None, ctx.model_if(d_inner)),
        conv_B=P(b_ax, None, None),
        conv_C=P(b_ax, None, None),
    )


def _proj(cfg, bp, u):
    """Shared projection head: u is the normed input [B, S, D]."""
    d_inner, H, Pd, N = dims(cfg)
    xin = jnp.einsum("bsd,di->bsi", u, bp["w_x"])
    z = jnp.einsum("bsd,di->bsi", u, bp["w_z"])
    Bm = jnp.einsum("bsd,dn->bsn", u, bp["w_B"])
    Cm = jnp.einsum("bsd,dn->bsn", u, bp["w_C"])
    dtv = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, bp["w_dt"]).astype(jnp.float32) + bp["dt_bias"]
    )
    return xin, z, Bm, Cm, dtv


def block_forward(cfg: ModelConfig, bp: dict, x: jnp.ndarray,
                  cache: Optional[SSMCache] = None):
    """One Mamba2 block (pre-norm residual). x [B, S, D].

    Returns (x_out, new_cache) — cache is threaded for chunked prefill and
    carried into decode.
    """
    b, s, _ = x.shape
    d_inner, H, Pd, N = dims(cfg)
    u = rms_norm(x, bp["norm"], cfg.norm_eps)
    xin, z, Bm, Cm, dtv = _proj(cfg, bp, u)
    tails = (None, None, None) if cache is None else (cache.conv_x, cache.conv_B, cache.conv_C)
    xin, t_x = causal_conv(xin, bp["conv_x"], tails[0])
    Bm, t_B = causal_conv(Bm, bp["conv_B"], tails[1])
    Cm, t_C = causal_conv(Cm, bp["conv_C"], tails[2])
    xh = xin.reshape(b, s, H, Pd)
    a = -jnp.exp(bp["A_log"])
    state0 = None if cache is None else cache.state
    y, state = ssd_scan(xh, dtv, a, Bm, Cm, cfg.ssm_chunk, state0,
                        remat=cfg.remat and cache is None)
    y = y + bp["D_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, bp["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, bp["w_out"])
    return x + out, SSMCache(state, t_x, t_B, t_C)


def block_step(cfg: ModelConfig, bp: dict, x: jnp.ndarray, cache: SSMCache):
    """Single-token decode. x [B, 1, D] -> (y [B, 1, D], cache)."""
    b = x.shape[0]
    d_inner, H, Pd, N = dims(cfg)
    u = rms_norm(x, bp["norm"], cfg.norm_eps)
    xin, z, Bm, Cm, dtv = _proj(cfg, bp, u)
    xin, t_x = causal_conv(xin, bp["conv_x"], cache.conv_x)
    Bm, t_B = causal_conv(Bm, bp["conv_B"], cache.conv_B)
    Cm, t_C = causal_conv(Cm, bp["conv_C"], cache.conv_C)
    xh = xin.reshape(b, H, Pd)
    a = -jnp.exp(bp["A_log"])
    state, y = ssd_step(cache.state, xh, dtv[:, 0], a, Bm[:, 0], Cm[:, 0])
    y = y + bp["D_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, bp["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, bp["w_out"])
    return x + out, SSMCache(state, t_x, t_B, t_C)
