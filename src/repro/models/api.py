"""Unified model API: one facade over the six architecture families.

``build_model(cfg)`` returns a ``Model`` whose methods have uniform
signatures across dense / moe / ssm(xlstm) / hybrid(zamba2) / vlm / audio:

    init(key) -> params
    param_specs(ctx) -> PartitionSpec pytree (matches params)
    loss_fn(params, batch, ctx) -> scalar          batch: tokens/labels[/images
                                                   /audio][/weights]
    prefill(params, batch, ctx) -> (logits, cache)
    decode_step(params, cache, token, pos, ctx) -> (logits, cache)
    init_cache(batch, seq_len) / cache_specs(ctx, batch, seq_len)
    input_specs(shape, ctx) -> (kwargs of ShapeDtypeStruct, shardings) for the
                               step function that `shape.kind` exercises.

``input_specs`` is the dry-run entry point: weak-type-correct stand-ins, no
allocation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import dense, encdec, hybrid, moe, vlm, xlstm
from repro.models.specs import ShardingCtx

_FAMILY = {
    "dense": dense,
    "moe": moe,
    "ssm": xlstm,
    "hybrid": hybrid,
    "vlm": vlm,
    "audio": encdec,
}


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    mod: Any

    # --- params ------------------------------------------------------------

    def init(self, key):
        return self.mod.init(self.cfg, key)

    def abstract_params(self):
        return jax.eval_shape(lambda k: self.mod.init(self.cfg, k),
                              jax.random.PRNGKey(0))

    def param_specs(self, ctx: ShardingCtx):
        return self.mod.param_specs(self.cfg, ctx)

    # --- train -------------------------------------------------------------

    def loss_fn(self, params, batch, ctx=None):
        return self.mod.loss_fn(self.cfg, params, batch, ctx)

    # --- serve -------------------------------------------------------------

    def prefill(self, params, batch, ctx=None, chunk: int = 2048):
        cfg = self.cfg
        if cfg.family == "vlm":
            return vlm.prefill(cfg, params, batch["tokens"], batch["images"],
                               ctx, chunk=chunk)
        if cfg.family == "audio":
            return encdec.prefill(cfg, params, batch["tokens"], batch["audio"],
                                  ctx, chunk=chunk)
        if cfg.family == "ssm":
            return xlstm.prefill(cfg, params, batch["tokens"], ctx)
        return self.mod.prefill(cfg, params, batch["tokens"], ctx, chunk=chunk)

    def decode_step(self, params, cache, token, pos, ctx=None):
        return self.mod.decode_step(self.cfg, params, cache, token, pos, ctx)

    def init_cache(self, batch: int, seq_len: int):
        return self.mod.init_cache(self.cfg, batch, seq_len)

    def abstract_cache(self, batch: int, seq_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, seq_len))

    def cache_specs(self, ctx: ShardingCtx, batch: int, seq_len: int):
        return self.mod.cache_specs(self.cfg, ctx, batch, seq_len)

    def grow_cache(self, cache, cur_len: int, new_len: int):
        """Extend the KV sequence axis from cur_len to new_len (serving:
        prefill cache -> decode cache). State caches (SSM/xLSTM) pass
        through unchanged."""
        extra = new_len - cur_len
        if extra <= 0 or self.cfg.family == "ssm":
            return cache
        fam = self.cfg.family

        def pad_axis(x, axis):
            pad = [(0, 0)] * x.ndim
            pad[axis] = (0, extra)
            return jnp.pad(x, pad)

        if fam in ("dense", "moe"):
            return {"k": pad_axis(cache["k"], 2), "v": pad_axis(cache["v"], 2)}
        if fam == "hybrid":
            return cache._replace(k=pad_axis(cache.k, 2),
                                  v=pad_axis(cache.v, 2))
        if fam == "vlm":
            return cache._replace(k=pad_axis(cache.k, 3),
                                  v=pad_axis(cache.v, 3))
        if fam == "audio":
            return cache._replace(k=pad_axis(cache.k, 2),
                                  v=pad_axis(cache.v, 2))
        return cache

    # --- dry-run input specs ------------------------------------------------

    def extra_inputs(self, batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
        """Stubbed modality-frontend embeddings (the assignment carve-out)."""
        cfg = self.cfg
        out: Dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.family == "vlm":
            out["images"] = jax.ShapeDtypeStruct(
                (batch, cfg.num_image_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            out["audio"] = jax.ShapeDtypeStruct(
                (batch, cfg.num_audio_frames, cfg.d_model), jnp.float32)
        return out

    def extra_input_specs(self, ctx: ShardingCtx, batch: int) -> Dict[str, P]:
        b_ax = ctx.data_if(batch) if batch > 1 else None
        return {k: P(b_ax, None, None) for k in self.extra_inputs(batch)}

    def train_batch_specs(self, shape: InputShape, ctx: ShardingCtx):
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
            "weights": jax.ShapeDtypeStruct((b,), jnp.float32),
            **self.extra_inputs(b),
        }
        b_ax = ctx.data_if(b) if b > 1 else None
        specs = {
            "tokens": P(b_ax, None),
            "labels": P(b_ax, None),
            "weights": P(b_ax),
            **self.extra_input_specs(ctx, b),
        }
        return batch, specs

    def decode_input_specs(self, shape: InputShape, ctx: ShardingCtx):
        """(cache, token, pos) ShapeDtypeStructs + matching specs."""
        b, s = shape.global_batch, shape.seq_len
        cache = self.abstract_cache(b, s)
        cspecs = self.cache_specs(ctx, b, s)
        b_ax = ctx.data_if(b) if b > 1 else None
        token = jax.ShapeDtypeStruct((b,), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return (cache, token, pos), (cspecs, P(b_ax), P())


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILY:
        raise ValueError(f"no production model for family {cfg.family!r}")
    return Model(cfg=cfg, mod=_FAMILY[cfg.family])


# ---------------------------------------------------------------------------
# Step factories (shared by the launcher, dry-run and smoke tests)
# ---------------------------------------------------------------------------


def make_train_step(model: Model, optimizer, ctx: Optional[ShardingCtx] = None):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, ctx))(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        from repro.optim import apply_updates
        params = apply_updates(params, updates)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_decode_step(model: Model, ctx: Optional[ShardingCtx] = None):
    """(params, cache, token, pos) -> (next_token, logits, cache) — greedy."""

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos, ctx)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return serve_step


def make_prefill(model: Model, ctx: Optional[ShardingCtx] = None,
                 chunk: int = 2048):
    def prefill_step(params, batch):
        return model.prefill(params, batch, ctx, chunk=chunk)

    return prefill_step
