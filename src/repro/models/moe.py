"""Qwen3-style MoE decoder (qwen3-moe-30b-a3b, qwen3-moe-235b-a22b).

Attention is identical to the dense stack; the MLP is a 128-expert top-8
mixture with a softmax router. Expert dispatch is *sort-based* (MegaBlocks
style adapted to TPU/SPMD): per token-group, assignments are sorted by expert
id and gathered into a fixed-capacity [E, C, D] buffer — no [tokens, E, C]
one-hot dispatch einsum (which would be quadratic in sequence length; see
DESIGN.md). Tokens beyond capacity are dropped (standard capacity-factor
semantics); the router aux loss balances load so drops stay rare.

Sharding: experts over the ``model`` axis (128/16 = 8 per shard), token groups
over ``data`` — GSPMD inserts the all-to-all at the group<->expert boundary.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import dense
from repro.models.dense import cst, _seq_spec
from repro.models.layers import dense_init, rms_norm
from repro.models.specs import ShardingCtx

def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = (tokens_per_group * cfg.experts_per_token * cfg.moe_capacity_factor
         / cfg.num_experts)
    return max(int(-(-c // 1)), 1)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    L, D, F, E = cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.num_experts
    params = dense.init(cfg, key)
    lyr = params["layers"]
    for k in ("w_gate", "w_up", "w_down"):
        del lyr[k]
    ks = jax.random.split(jax.random.fold_in(key, 7), 4)
    lyr["router"] = dense_init(ks[0], (L, D, E), jnp.float32)
    lyr["we_gate"] = dense_init(ks[1], (L, E, D, F), dt)
    lyr["we_up"] = dense_init(ks[2], (L, E, D, F), dt)
    lyr["we_down"] = dense_init(ks[3], (L, E, F, D), dt, scale=1.0 / jnp.sqrt(D))
    return params


def param_specs(cfg: ModelConfig, ctx: ShardingCtx) -> dict:
    specs = dense.param_specs(cfg, ctx)
    lyr = specs["layers"]
    for k in ("w_gate", "w_up", "w_down"):
        del lyr[k]
    e_ax = ctx.model_if(cfg.num_experts)
    a = ctx.axes
    lyr["router"] = P(None, None, None)
    lyr["we_gate"] = P(None, e_ax, ctx.pdata, None)
    lyr["we_up"] = P(None, e_ax, ctx.pdata, None)
    lyr["we_down"] = P(None, e_ax, None, ctx.pdata)
    return specs


# ---------------------------------------------------------------------------
# Sort-based expert dispatch
# ---------------------------------------------------------------------------


def _route(cfg: ModelConfig, router_w, x):
    """x [G, S, D] -> (gates [G, S, k], idx [G, S, k], aux_loss scalar)."""
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.clip(jnp.sum(gates, -1, keepdims=True), 1e-9)  # renorm
    # Switch-style load-balance aux: E * sum_e (frac_tokens_e * mean_prob_e)
    e = cfg.num_experts
    me = jnp.mean(probs, axis=(0, 1))                       # [E]
    one_hot_top1 = jax.nn.one_hot(idx[..., 0], e)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))                # [E]
    aux = e * jnp.sum(me * ce)
    return gates.astype(x.dtype), idx, aux


def _dispatch_indices(cfg: ModelConfig, idx, cap: int):
    """Per-group sort-based dispatch.

    idx: [S, k] expert ids. Returns (token_slot [E, C] indices into the S*k
    flat assignment list, valid [E, C] mask) — pure integer ops, no one-hot.
    """
    s, k = idx.shape
    e = cfg.num_experts
    flat = idx.reshape(-1)                                   # [S*k]
    order = jnp.argsort(flat)                                # stable: token order kept
    sorted_e = flat[order]
    counts = jnp.bincount(flat, length=e)                    # [E]
    starts = jnp.cumsum(counts) - counts                     # [E]
    slots = starts[:, None] + jnp.arange(cap)[None, :]       # [E, C]
    valid = jnp.arange(cap)[None, :] < jnp.minimum(counts, cap)[:, None]
    slots = jnp.clip(slots, 0, s * k - 1)
    token_slot = order[slots]                                # flat assignment ids
    return token_slot, valid


def _ec_spec(ctx: Optional[ShardingCtx], cfg: ModelConfig):
    """[G, E, C, D] dispatch-buffer spec: groups over data, experts over model."""
    if ctx is None:
        return None
    return jax.sharding.PartitionSpec(
        ctx.axes.data, ctx.model_if(cfg.num_experts), None, None)


def moe_mlp(cfg: ModelConfig, lp, x, ctx: Optional[ShardingCtx]):
    """x [B, S, D] -> (y [B, S, D], aux scalar). Groups = batch rows.

    Written with explicit [G, E, C, D] axes (no vmap over the expert compute)
    so the dispatch buffers carry sharding constraints — groups over ``data``,
    experts over ``model`` — and GSPMD inserts the group<->expert all-to-all
    instead of replicating tokens.
    """
    b, s, d = x.shape
    k = cfg.experts_per_token
    e = cfg.num_experts
    cap = capacity(cfg, s)
    gates, idx, aux = _route(cfg, lp["router"], x)

    # integer routing per group (cheap, local per data shard)
    token_slot, valid = jax.vmap(
        lambda idxg: _dispatch_indices(cfg, idxg, cap))(idx)   # [G, E, C]
    tok = token_slot // k                                      # [G, E, C]

    xe = jnp.take_along_axis(
        x, tok.reshape(b, e * cap)[..., None], axis=1).reshape(b, e, cap, d)
    xe = jnp.where(valid[..., None], xe, 0.0)
    if ctx is not None and ctx.mesh is not None:
        xe = cst(xe, _ec_spec(ctx, cfg), ctx)
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, lp["we_gate"]))
    u = jnp.einsum("gecd,edf->gecf", xe, lp["we_up"])
    ye = jnp.einsum("gecf,efd->gecd", g * u, lp["we_down"])    # [G, E, C, D]
    gate_per_slot = jnp.take_along_axis(
        gates.reshape(b, s * k), token_slot.reshape(b, e * cap), axis=1
    ).reshape(b, e, cap)
    ye = ye * (gate_per_slot * valid)[..., None]
    if ctx is not None and ctx.mesh is not None:
        ye = cst(ye, _ec_spec(ctx, cfg), ctx)

    # combine: scatter-add back to token order (per group)
    yg = jax.vmap(
        lambda tokg, yeg: jnp.zeros((s, d), ye.dtype).at[tokg.reshape(-1)].add(
            yeg.reshape(-1, d), mode="drop"))(tok, ye)
    return cst(yg, _seq_spec(ctx, s), ctx), aux


# ---------------------------------------------------------------------------
# Forward / loss / decode
# ---------------------------------------------------------------------------


def _block(cfg, lp, x, positions, ctx, window, chunk):
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, kk, v = dense._qkv(cfg, lp, h, positions, ctx)
    o = dense._attention_remat(cfg, q, kk, v, window=window, chunk=chunk)
    x = x + dense._attn_out(lp, o)
    x = cst(x, _seq_spec(ctx, x.shape[1]), ctx)
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    y, aux = moe_mlp(cfg, lp, h, ctx)
    return x + y, aux


def forward(cfg: ModelConfig, params, tokens, ctx=None, *, chunk=None, window=None):
    s = tokens.shape[1]
    if chunk is None and s > 2048:
        chunk = 2048
    positions = jnp.arange(s)
    x = dense._embed(cfg, params, tokens, ctx)

    def body(carry, lp):
        xc, aux = carry
        xc, a = _block(cfg, lp, xc, positions, ctx, window, chunk)
        return (xc, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return dense._logits(cfg, params, x, ctx), aux / cfg.num_layers


def loss_fn(cfg: ModelConfig, params, batch, ctx=None, *, chunk=None):
    logits, aux = forward(cfg, params, batch["tokens"], ctx, chunk=chunk)
    ce = dense.token_xent(logits[:, :-1], batch["labels"][:, 1:], batch.get("weights"))
    return ce + cfg.moe_aux_coef * aux


init_cache = dense.init_cache
cache_specs = dense.cache_specs


def prefill(cfg: ModelConfig, params, tokens, ctx=None, *, chunk=2048):
    b, s = tokens.shape
    positions = jnp.arange(s)
    x = dense._embed(cfg, params, tokens, ctx)
    window = cfg.window if (cfg.window and s > cfg.window) else None
    from repro.models import attention as attn_lib

    def body(xc, lp):
        h = rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
        q, k, v = dense._qkv(cfg, lp, h, positions, ctx)
        o = attn_lib.attention(q, k, v, causal=True, window=window, chunk=chunk)
        xc = xc + dense._attn_out(lp, o)
        xc = cst(xc, _seq_spec(ctx, s), ctx)
        h = rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
        y, _ = moe_mlp(cfg, lp, h, ctx)
        return cst(xc + y, _seq_spec(ctx, s), ctx), (k, v)

    x, (ck, cv) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return dense._logits(cfg, params, x, ctx)[:, 0], {"k": ck, "v": cv}


def decode_step(cfg: ModelConfig, params, cache, token, pos, ctx=None):
    from repro.models import attention as attn_lib
    b = token.shape[0]
    t = cache["k"].shape[2]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    x = x.reshape(b, 1, -1)
    positions = pos[None] if pos.ndim == 0 else pos
    rolling = cfg.window is not None and t == cfg.window
    slot = (pos % t) if rolling else pos
    if rolling:
        kv_pos = dense._rolling_kv_pos(pos, t)
        kv_pos = jnp.where(kv_pos < 0, 2**30, kv_pos)
    else:
        kv_pos = jnp.arange(t)

    def body(xc, scanned):
        lp, ck, cv = scanned
        h = rms_norm(xc, lp["attn_norm"], cfg.norm_eps)
        q, k, v = dense._qkv(cfg, lp, h, positions)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, slot, axis=1)
        o = attn_lib.attention(
            q, ck, cv, q_pos=positions, kv_pos=kv_pos, causal=True,
            window=cfg.window if rolling else None,
            kv_len=None if rolling else pos + 1,
        )
        xc = xc + dense._attn_out(lp, o)
        h = rms_norm(xc, lp["mlp_norm"], cfg.norm_eps)
        y, _ = moe_mlp(cfg, lp, h, ctx)
        return xc + y, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = dense._logits(cfg, params, x, ctx)[:, 0]
    return logits, {"k": ck, "v": cv}
