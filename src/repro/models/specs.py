"""Per-architecture PartitionSpec selection.

The assigned architectures have head counts (14, 28, 48, ...) that are not all
divisible by the 16-way ``model`` axis, so the TP layout is chosen *per
tensor*: shard KV heads when they divide the axis, else query groups, else
head_dim (which is a multiple of 16 for every assigned arch). This mirrors
what production frameworks do — the TP layout is a per-model decision, not a
constant.

The residual stream between scanned layers is sequence-sharded over ``model``
(Megatron-style sequence parallelism) so that remat-saved activations fit HBM
at train_4k; GSPMD inserts the all-gather/reduce-scatter pair per layer.
"""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.models.sharding import MeshAxes

VOCAB_PAD = 512  # LCM of every mesh axis product we deploy (16*16, 2*16*16)


def pad_vocab(v: int, multiple: int = VOCAB_PAD) -> int:
    return -(-v // multiple) * multiple


class ShardingCtx:
    """Axes + sizes of the target mesh; ``None`` means run unsharded (smoke)."""

    def __init__(self, mesh=None, fsdp: bool = True):
        """fsdp=False: parameters replicate over ``data`` (TP-only layout) —
        kills the per-layer FSDP all-gather/reduce-scatter wire traffic at
        the cost of params+grads being held once per data shard."""
        self.mesh = mesh
        self.fsdp = fsdp
        if mesh is None:
            self.axes = MeshAxes()
            self.model_size = 1
            self.data_size = 1
        else:
            self.axes = MeshAxes.for_mesh(mesh)
            shape = dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))
            self.model_size = shape.get("model", 1)
            d = shape.get("data", 1)
            if "pod" in shape:
                d *= shape["pod"]
            self.data_size = d

    # --- axis pickers ------------------------------------------------------

    @property
    def pdata(self):
        """The data axis for PARAMETER sharding (None in TP-only mode)."""
        return self.axes.data if self.fsdp else None

    def pdata_if(self, dim: int):
        return self.data_if(dim) if self.fsdp else None

    def model_if(self, dim: int):
        """Return the model axis name iff dim divides by it."""
        return self.axes.model if dim % max(self.model_size, 1) == 0 else None

    def data_if(self, dim: int):
        return self.axes.data if dim % max(self.data_size, 1) == 0 else None

    def attn_q_spec(self, hkv: int, group: int, hd: int) -> P:
        """wq [D, Hkv, G, hd]: shard exactly one head-ish dim over model."""
        d_ax = self.pdata
        if hkv % max(self.model_size, 1) == 0 and hkv >= self.model_size:
            return P(d_ax, self.axes.model, None, None)
        if group % max(self.model_size, 1) == 0 and group >= self.model_size:
            return P(d_ax, None, self.axes.model, None)
        return P(d_ax, None, None, self.axes.model)  # head_dim sharding

    def attn_kv_spec(self, hkv: int, hd: int) -> P:
        """wk/wv [D, Hkv, hd]."""
        d_ax = self.pdata
        if hkv % max(self.model_size, 1) == 0 and hkv >= self.model_size:
            return P(d_ax, self.axes.model, None)
        return P(d_ax, None, self.axes.model)

    def attn_o_spec(self, hkv: int, group: int, hd: int) -> P:
        """wo [Hkv, G, hd, D]: mirror the q sharding, D over data."""
        q = self.attn_q_spec(hkv, group, hd)
        return P(q[1], q[2], q[3], self.pdata)
