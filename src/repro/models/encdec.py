"""Seamless-M4T-medium-style encoder-decoder transformer [arXiv:2308.11596].

Speech-to-text backbone: a bidirectional encoder over precomputed audio frame
embeddings (the mel-spectrogram + conv feature extractor is STUBBED per the
assignment carve-out — ``audio`` inputs are [B, num_audio_frames, d_model])
and a causal text decoder with cross-attention to the encoder memory.

long_500k is skipped for this architecture (DESIGN.md §Shape skips).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import dense
from repro.models.dense import cst, _seq_spec, token_xent
from repro.models.layers import dense_init, embed_init, gelu_mlp, rms_norm
from repro.models.specs import ShardingCtx, pad_vocab


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _attn_init(cfg, key, prefix=""):
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // hkv
    ks = jax.random.split(key, 4)
    return {
        prefix + "norm": jnp.ones((D,), dt),
        prefix + "wq": dense_init(ks[0], (D, hkv, g, hd), dt),
        prefix + "wk": dense_init(ks[1], (D, hkv, hd), dt),
        prefix + "wv": dense_init(ks[2], (D, hkv, hd), dt),
        prefix + "wo": dense_init(ks[3], (hkv, g, hd, D), dt,
                                  scale=1.0 / jnp.sqrt(D)),
    }


def _mlp_init(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    D, F = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {
        "mlp_norm": jnp.ones((D,), dt),
        "w_in": dense_init(k1, (D, F), dt),
        "b_in": jnp.zeros((F,), dt),
        "w_out": dense_init(k2, (F, D), dt, scale=1.0 / jnp.sqrt(D)),
        "b_out": jnp.zeros((D,), dt),
    }


def _enc_layer_init(cfg, key):
    k1, k2 = jax.random.split(key)
    return {**_attn_init(cfg, k1, "self_"), **_mlp_init(cfg, k2)}


def _dec_layer_init(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        **_attn_init(cfg, k1, "self_"),
        **_attn_init(cfg, k2, "cross_"),
        **_mlp_init(cfg, k3),
    }


def init(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    vp = pad_vocab(cfg.vocab_size)
    ks = jax.random.split(key, 5)
    enc = jax.vmap(lambda k: _enc_layer_init(cfg, k))(
        jax.random.split(ks[1], cfg.encoder_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(cfg, k))(
        jax.random.split(ks[2], cfg.decoder_layers))
    return {
        "embed": embed_init(ks[0], (vp, cfg.d_model), dt),
        "encoder": enc,
        "decoder": dec,
        "enc_norm": jnp.ones((cfg.d_model,), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense_init(ks[3], (cfg.d_model, vp), dt),
    }


def _attn_specs(cfg, ctx, prefix=""):
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // hkv
    return {
        prefix + "norm": P(None),
        prefix + "wq": ctx.attn_q_spec(hkv, g, hd),
        prefix + "wk": ctx.attn_kv_spec(hkv, hd),
        prefix + "wv": ctx.attn_kv_spec(hkv, hd),
        prefix + "wo": ctx.attn_o_spec(hkv, g, hd),
    }


def _mlp_specs(cfg, ctx):
    a = ctx.axes
    return {
        "mlp_norm": P(None),
        "w_in": P(ctx.pdata, a.model),
        "b_in": P(a.model),
        "w_out": P(a.model, ctx.pdata),
        "b_out": P(None),
    }


def param_specs(cfg: ModelConfig, ctx: ShardingCtx) -> dict:
    vp = pad_vocab(cfg.vocab_size)
    enc = {**_attn_specs(cfg, ctx, "self_"), **_mlp_specs(cfg, ctx)}
    decd = {**_attn_specs(cfg, ctx, "self_"), **_attn_specs(cfg, ctx, "cross_"),
            **_mlp_specs(cfg, ctx)}
    st = lambda tree: jax.tree.map(lambda s: P(None, *s), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    return {
        "embed": P(ctx.model_if(vp), ctx.pdata_if(cfg.d_model)),
        "encoder": st(enc),
        "decoder": st(decd),
        "enc_norm": P(None),
        "final_norm": P(None),
        "lm_head": P(ctx.pdata_if(cfg.d_model), ctx.model_if(vp)),
    }


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _self_attn(cfg, lp, x, positions, causal, ctx, chunk=None, prefix="self_",
               kv_override=None, kv_pos=None, kv_len=None, slot=None):
    s = x.shape[1]
    h = rms_norm(x, lp[prefix + "norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dkgh->bskgh", h, lp[prefix + "wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dkh->bskh", h, lp[prefix + "wk"])
        v = jnp.einsum("bsd,dkh->bskh", h, lp[prefix + "wv"])
        if positions is not None:
            from repro.models.layers import apply_rope
            b, ss = h.shape[:2]
            hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            g = cfg.num_heads // hkv
            q = apply_rope(q.reshape(b, ss, hkv * g, hd), positions, cfg.rope_theta)
            q = q.reshape(b, ss, hkv, g, hd)
            k = apply_rope(k, positions, cfg.rope_theta)
        new_kv = (k, v)
        o = dense._attention_remat(cfg, q, k, v, causal=causal, chunk=chunk)
    else:
        k, v = kv_override
        if slot is not None:
            from repro.models.layers import apply_rope
            b, ss = h.shape[:2]
            hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            g = cfg.num_heads // hkv
            kn = jnp.einsum("bsd,dkh->bskh", h, lp[prefix + "wk"])
            vn = jnp.einsum("bsd,dkh->bskh", h, lp[prefix + "wv"])
            q = apply_rope(q.reshape(b, ss, hkv * g, hd), positions, cfg.rope_theta)
            q = q.reshape(b, ss, hkv, g, hd)
            kn = apply_rope(kn, positions, cfg.rope_theta)
            k = jax.lax.dynamic_update_slice_in_dim(k, kn, slot, axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(v, vn, slot, axis=1)
        new_kv = (k, v)
        o = attn_lib.attention(q, k, v, q_pos=positions, kv_pos=kv_pos,
                               causal=causal, kv_len=kv_len)
    x = x + jnp.einsum("bskgh,kghd->bsd", o, lp[prefix + "wo"])
    return cst(x, _seq_spec(ctx, s), ctx), new_kv


def _mlp(cfg, lp, x, ctx):
    s = x.shape[1]
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + gelu_mlp(h, lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"])
    return cst(x, _seq_spec(ctx, s), ctx)


def encode(cfg: ModelConfig, params, audio, ctx=None, chunk=None):
    """audio [B, F, D] (stub embeddings) -> encoder memory [B, F, D]."""
    x = audio.astype(jnp.dtype(cfg.dtype))
    f = x.shape[1]
    positions = jnp.arange(f)

    def body(xc, lp):
        xc, _ = _self_attn(cfg, lp, xc, positions, causal=False, ctx=ctx,
                           chunk=chunk)
        return _mlp(cfg, lp, xc, ctx), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _decoder_stack(cfg, params, x, memory, positions, ctx, chunk=None,
                   collect_kv=False):
    def body(xc, lp):
        xc, kv = _self_attn(cfg, lp, xc, positions, causal=True, ctx=ctx,
                            chunk=chunk, prefix="self_")
        # cross-attention: memory is position-free (no RoPE)
        h = rms_norm(xc, lp["cross_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dkgh->bskgh", h, lp["cross_wq"])
        mk = jnp.einsum("bfd,dkh->bfkh", memory, lp["cross_wk"])
        mv = jnp.einsum("bfd,dkh->bfkh", memory, lp["cross_wv"])
        o = attn_lib.attention(q, mk, mv, causal=False)
        xc = xc + jnp.einsum("bskgh,kghd->bsd", o, lp["cross_wo"])
        xc = _mlp(cfg, lp, xc, ctx)
        ys = (kv[0], kv[1], mk, mv) if collect_kv else None
        return xc, ys

    body_fn = jax.checkpoint(body) if (cfg.remat and not collect_kv) else body
    return jax.lax.scan(body_fn, x, params["decoder"])


def forward(cfg: ModelConfig, params, tokens, audio, ctx=None, *, chunk=None,
            **_):
    if chunk is None and tokens.shape[1] > 2048:
        chunk = 2048
    memory = encode(cfg, params, audio, ctx, chunk)
    s = tokens.shape[1]
    positions = jnp.arange(s)
    x = dense._embed(cfg, params, tokens, ctx)
    x, _ = _decoder_stack(cfg, params, x, memory, positions, ctx, chunk)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return dense._logits(cfg, params, x, ctx)


def loss_fn(cfg: ModelConfig, params, batch, ctx=None, **kw):
    logits = forward(cfg, params, batch["tokens"], batch["audio"], ctx, **kw)
    return token_xent(logits[:, :-1], batch["labels"][:, 1:], batch.get("weights"))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


class EncDecCache(NamedTuple):
    k: jnp.ndarray   # decoder self [Ld, B, T, Hkv, hd]
    v: jnp.ndarray
    mk: jnp.ndarray  # cross (static) [Ld, B, F, Hkv, hd]
    mv: jnp.ndarray


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> EncDecCache:
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    ld = cfg.decoder_layers
    return EncDecCache(
        k=jnp.zeros((ld, batch, seq_len, hkv, hd), dt),
        v=jnp.zeros((ld, batch, seq_len, hkv, hd), dt),
        mk=jnp.zeros((ld, batch, cfg.num_audio_frames, hkv, hd), dt),
        mv=jnp.zeros((ld, batch, cfg.num_audio_frames, hkv, hd), dt),
    )


def cache_specs(cfg: ModelConfig, ctx: ShardingCtx, batch: int, seq_len: int):
    b_ax = ctx.data_if(batch) if batch > 1 else None
    kv = P(None, b_ax, ctx.model_if(seq_len), None, None)
    mkv = P(None, b_ax, ctx.model_if(cfg.num_audio_frames), None, None)
    return EncDecCache(k=kv, v=kv, mk=mkv, mv=mkv)


def prefill(cfg: ModelConfig, params, tokens, audio, ctx=None, *, chunk=2048):
    memory = encode(cfg, params, audio, ctx, chunk)
    s = tokens.shape[1]
    positions = jnp.arange(s)
    x = dense._embed(cfg, params, tokens, ctx)
    x, (ks, vs, mks, mvs) = _decoder_stack(cfg, params, x, memory, positions,
                                           ctx, chunk, collect_kv=True)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = dense._logits(cfg, params, x, ctx)[:, 0]
    return logits, EncDecCache(k=ks, v=vs, mk=mks, mv=mvs)


def decode_step(cfg: ModelConfig, params, cache: EncDecCache, token, pos,
                ctx=None):
    b = token.shape[0]
    t = cache.k.shape[2]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    x = x.reshape(b, 1, -1)
    positions = pos[None] if pos.ndim == 0 else pos
    kv_pos = jnp.arange(t)

    def body(xc, scanned):
        lp, ck, cv, mk, mv = scanned
        xc, (ck, cv) = _self_attn(
            cfg, lp, xc, positions, causal=True, ctx=ctx, prefix="self_",
            kv_override=(ck, cv), kv_pos=kv_pos, kv_len=pos + 1, slot=pos)
        h = rms_norm(xc, lp["cross_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dkgh->bskgh", h, lp["cross_wq"])
        o = attn_lib.attention(q, mk, mv, causal=False)
        xc = xc + jnp.einsum("bskgh,kghd->bsd", o, lp["cross_wo"])
        xc = _mlp(cfg, lp, xc, ctx)
        return xc, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["decoder"], cache.k, cache.v, cache.mk, cache.mv))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = dense._logits(cfg, params, x, ctx)[:, 0]
    return logits, EncDecCache(k=ks, v=vs, mk=cache.mk, mv=cache.mv)
