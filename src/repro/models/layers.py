"""Common transformer building blocks (pure functional JAX)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm in fp32 accumulation, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(
        jnp.float32
    )
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in) + b_in)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype):
    return (0.02 * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)
