"""GQA attention: full, causal, sliding-window, chunked (online-softmax), and
KV-cache decode.

The chunked path scans over KV blocks with a running (max, denom, acc) online
softmax — the pure-JAX twin of the Pallas flash-attention kernel in
``repro.kernels.flash_attention`` (which is the TPU-target implementation of
the same math). Chunking bounds the materialized score block to
[B, Hkv, G, Sq, chunk] which is what makes `prefill_32k` fit.

Layouts: q [B, Sq, Hkv, G, hd]; k, v [B, T, Hkv, hd]. GQA never materializes
repeated KV heads — the group axis G lives on Q only.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def split_heads(x: jnp.ndarray, num_kv: int, group: int, head_dim: int) -> jnp.ndarray:
    """[B, S, H*hd] -> [B, S, Hkv, G, hd]."""
    b, s, _ = x.shape
    return x.reshape(b, s, num_kv, group, head_dim)


def merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    """[B, S, Hkv, G, hd] -> [B, S, H*hd]."""
    b, s, k, g, d = x.shape
    return x.reshape(b, s, k * g * d)


def _mask(q_pos, kv_pos, causal: bool, window: Optional[int]):
    """[Sq, T] boolean mask of *allowed* positions."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= kv_pos[None, :] > (q_pos[:, None] - window)
    return m


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_pos: Optional[jnp.ndarray] = None,
    kv_pos: Optional[jnp.ndarray] = None,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
    kv_len: Optional[jnp.ndarray] = None,
    remat: bool = False,
) -> jnp.ndarray:
    """Grouped-query attention with optional KV chunking.

    q: [B, Sq, Hkv, G, hd]; k, v: [B, T, Hkv, hd]. Returns [B, Sq, Hkv, G, hd].
    kv_len: optional dynamic valid-length (decode: positions >= kv_len masked).
    """
    b, sq, hkv, g, hd = q.shape
    t = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    if q_pos is None:
        q_pos = jnp.arange(sq)
    if kv_pos is None:
        kv_pos = jnp.arange(t)

    if chunk is None or chunk >= t:
        s = jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32)
        s *= scale
        allowed = _mask(q_pos, kv_pos, causal, window)
        if kv_len is not None:
            allowed &= kv_pos[None, :] < kv_len
        s = jnp.where(allowed, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
        return out

    # --- chunked online-softmax over KV blocks -----------------------------
    num_chunks = -(-t // chunk)
    pad = num_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=2**30)  # masked out

    def body(carry, idx):
        m, l, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, axis=1)
        pc = jax.lax.dynamic_slice_in_dim(kv_pos, idx * chunk, chunk, axis=0)
        s = jnp.einsum("bskgd,btkd->bkgst", q, kc, preferred_element_type=jnp.float32)
        s *= scale
        allowed = _mask(q_pos, pc, causal, window)
        if kv_len is not None:
            allowed &= pc[None, :] < kv_len
        s = jnp.where(allowed, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), vc).astype(jnp.float32)
        # acc is [B, Sq, Hkv, G, hd]; corr is [B, Hkv, G, Sq]
        corr_b = jnp.moveaxis(corr, -1, 1)[..., None]  # [B, Sq, Hkv, G, 1]
        acc_new = acc * corr_b + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, hkv, g, hd), jnp.float32)
    if remat:
        # rematerialize the [.., Sq, chunk] score block in backward: the scan
        # then saves only the (m, l, acc) carry per chunk, not the scores —
        # the flash-attention backward policy, expressed in pure JAX.
        body = jax.checkpoint(body)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(num_chunks))
    denom = jnp.moveaxis(l, -1, 1)[..., None]  # [B, Sq, Hkv, G, 1]
    out = jnp.where(denom > 0, acc / jnp.maximum(denom, 1e-30), 0.0)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,
    cache_k: jnp.ndarray,
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,
    *,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Single-token decode: q [B, 1, Hkv, G, hd] over cache [B, T, Hkv, hd].

    Positions > pos are masked (cache beyond the write point); the T
    contraction is left unchunked so GSPMD can shard it over the `model`
    axis (flash-decoding split-K — the partial-softmax combine is inserted
    by SPMD partitioning of the reduction).
    """
    t = cache_k.shape[1]
    return attention(
        q, cache_k, cache_v,
        q_pos=pos[None] if pos.ndim == 0 else pos,
        kv_pos=jnp.arange(t),
        causal=True,
        window=window,
        chunk=None,
    )
