"""Federated training launcher (production tier).

Runs CA-AFL rounds of a (possibly reduced) assigned architecture on whatever
mesh the host provides — the same code path the dry-run lowers for the
production mesh. Each mesh ``data`` slice hosts one client; batches are
assembled from a synthetic heterogeneous LM corpus (offline container).

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2-0.5b --reduced --rounds 50 --method ca_afl --C 8
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.configs.base import FLConfig
from repro.data.synthetic import make_lm_tokens
from repro.federated.server import ParameterServer
from repro.models.api import build_model
from repro.optim import sgd, adamw


def lm_batches(corpus: np.ndarray, batch_per_client: int, seq: int,
               cfg, seed: int = 0):
    """Infinite batches: every client contributes batch_per_client rows."""
    n, tlen = corpus.shape
    rng = np.random.default_rng(seed)
    while True:
        toks, cids = [], []
        for c in range(n):
            for _ in range(batch_per_client):
                off = rng.integers(0, tlen - seq - 1)
                toks.append(corpus[c, off:off + seq])
                cids.append(c)
        batch = {
            "tokens": jnp.asarray(np.stack(toks)),
            "labels": jnp.asarray(np.stack(toks)),
            "client_ids": jnp.asarray(np.array(cids, np.int32)),
        }
        b = len(toks)
        if cfg.family == "vlm":
            batch["images"] = jnp.zeros(
                (b, cfg.num_image_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            batch["audio"] = jnp.zeros(
                (b, cfg.num_audio_frames, cfg.d_model), jnp.float32)
        yield batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch-per-client", type=int, default=2)
    ap.add_argument("--method", default="ca_afl",
                    choices=["ca_afl", "afl", "fedavg", "greedy"])
    ap.add_argument("--C", type=float, default=8.0)
    ap.add_argument("--noise-std", type=float, default=1e-3)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--server-opt", default="sgd", choices=["sgd", "adamw"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.with_(dtype="float32", remat=False)
    model = build_model(cfg)
    fl = FLConfig(num_clients=args.clients, clients_per_round=args.k,
                  rounds=args.rounds, method=args.method, energy_C=args.C,
                  noise_std=args.noise_std, seed=args.seed)
    opt = adamw(args.lr) if args.server_opt == "adamw" else sgd(args.lr)

    print(f"arch={cfg.name} reduced={args.reduced} method={fl.method} "
          f"C={fl.energy_C} N={fl.num_clients} K={fl.clients_per_round}")
    ps = ParameterServer(model, opt, fl, seed=args.seed)
    state = ps.init_state(jax.random.PRNGKey(args.seed))
    n_params = sum(int(x.size) for x in jax.tree_util.tree_leaves(state.params))
    print(f"params: {n_params:,}")

    corpus = make_lm_tokens(args.clients, max(8 * args.seq, 4096),
                            cfg.vocab_size, seed=args.seed)
    t0 = time.time()
    state = ps.run(state, lm_batches(corpus, args.batch_per_client, args.seq,
                                     cfg, args.seed),
                   rounds=args.rounds, log_every=max(args.rounds // 10, 1))
    dt = time.time() - t0
    print(f"{args.rounds} rounds in {dt:.1f}s "
          f"({dt / args.rounds:.2f} s/round); total E = "
          f"{state.energy_joules:.3e} J")
    if args.out:
        Path(args.out).write_text(json.dumps(state.history, indent=2))
        print(f"history -> {args.out}")


if __name__ == "__main__":
    main()
