"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (smoke tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
