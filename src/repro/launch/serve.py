"""Batched serving launcher: prefill + greedy decode of the global model.

Serves the model CA-AFL trained (or a fresh init) with a simple static-batch
scheduler: requests are padded to a common prompt length, prefilled once, and
decoded step-by-step with one compiled serve_step. This is the code path the
decode_* dry-run shapes lower at production scale.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2-0.5b --reduced --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models.api import build_model, make_decode_step, make_prefill


def pad_cache_for_decode(model, cache, prompt_len: int, total_len: int):
    """Grow attention caches from prefill length to serving length."""
    return model.grow_cache(cache, prompt_len, total_len)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.with_(dtype="float32", remat=False)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    total = args.prompt_len + args.gen

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(
            key, (args.batch, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["audio"] = jax.random.normal(
            key, (args.batch, cfg.num_audio_frames, cfg.d_model))

    prefill = jax.jit(make_prefill(model, chunk=max(args.prompt_len, 16)))
    serve_step = jax.jit(make_decode_step(model))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    cache = pad_cache_for_decode(model, cache, args.prompt_len, total)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(args.gen - 1):
        tok, logits, cache = serve_step(
            params, cache, tok, jnp.asarray(args.prompt_len + i, jnp.int32))
        out.append(tok)
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"generated ids[0]: {gen[0][:16]} ...")
    print(f"{args.batch * args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
