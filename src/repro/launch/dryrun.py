import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

For each pair this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs the step the shape's kind dictates —
       train_*   -> the CA-AFL federated round (paper Alg. 1 on the mesh;
                    ``--step plain`` lowers a bare LM step instead),
       prefill_* -> chunked prefill,
       decode_*  -> single-token serve step over the sharded KV/state cache,
  3. ``jit(...).lower(**ShapeDtypeStructs).compile()`` — success proves the
     sharding config is coherent; ``memory_analysis()`` proves it fits,
  4. derives the three roofline terms from the compiled HLO text via
     ``utils.hlo_cost.analyze_hlo`` — XLA's built-in ``cost_analysis()``
     counts ``while`` bodies ONCE (verified empirically), so the analyzer
     multiplies loop bodies by their parsed trip counts instead.

Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, SHAPE_SKIPS, get_config, get_shape, INPUT_SHAPES
from repro.configs.base import ModelConfig
from repro.federated.rounds import make_fl_round
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model, make_decode_step, make_prefill
from repro.models.specs import ShardingCtx
from repro.optim import sgd
from repro.utils.hlo_cost import analyze_hlo
from repro.utils.roofline import Roofline, model_flops
from repro.utils.tree import tree_size

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

HBM_PER_CHIP = 16 * 2**30  # v5e

# per-arch gradient-accumulation defaults (activation memory / HBM fit)
MICROBATCH_DEFAULT = {"qwen3-moe-235b-a22b": 8, "xlstm-1.3b": 8}


# ---------------------------------------------------------------------------
# Per-family scan-unit surgery (for the L=1/L=2 cost calibration)
# ---------------------------------------------------------------------------


def with_units(cfg: ModelConfig, n: int) -> ModelConfig:
    if cfg.family in ("dense", "moe"):
        return cfg.with_(num_layers=n)
    if cfg.family == "ssm":
        return cfg.with_(num_layers=n * cfg.slstm_group)
    if cfg.family == "hybrid":
        return cfg.with_(num_layers=n * cfg.shared_attn_every)
    if cfg.family == "vlm":
        return cfg.with_(num_layers=n * cfg.cross_attn_every)
    if cfg.family == "audio":
        return cfg.with_(num_layers=n, encoder_layers=n, decoder_layers=n)
    raise ValueError(cfg.family)


def num_units(cfg: ModelConfig) -> float:
    if cfg.family in ("dense", "moe"):
        return cfg.num_layers
    if cfg.family == "ssm":
        return cfg.num_layers / cfg.slstm_group
    if cfg.family == "hybrid":
        return cfg.num_layers / cfg.shared_attn_every  # fractional tail ok
    if cfg.family == "vlm":
        return cfg.num_layers / cfg.cross_attn_every
    if cfg.family == "audio":
        return cfg.encoder_layers
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(opt_state_abs, param_specs):
    """Spec tree for optimizer state: param-shaped subtrees reuse param
    specs; scalars replicate."""
    def one(sub):
        # sub is either a scalar leaf, None, or a params-shaped pytree
        if sub is None:
            return None
        if hasattr(sub, "ndim") and sub.ndim == 0:
            return P()
        return param_specs

    if hasattr(opt_state_abs, "_fields"):  # NamedTuple state
        return type(opt_state_abs)(*(one(getattr(opt_state_abs, f))
                                     for f in opt_state_abs._fields))
    if isinstance(opt_state_abs, tuple):
        return tuple(opt_state_specs(s, param_specs) for s in opt_state_abs)
    return one(opt_state_abs)


# ---------------------------------------------------------------------------
# Step construction + lowering
# ---------------------------------------------------------------------------


def lower_pair(arch: str, shape_name: str, mesh, *, step_kind: str = "fl",
               cfg_override: ModelConfig = None, chunk: int = 2048,
               microbatches: int = 4, fsdp: bool = True,
               fused_probe: bool = False):
    """Lower + compile one (arch, shape) on ``mesh``. Returns (compiled,
    lowered, model, meta dict)."""
    cfg = cfg_override or get_config(arch)
    shape = get_shape(shape_name)
    ctx = ShardingCtx(mesh, fsdp=fsdp)
    model = build_model(cfg)
    pspecs = model.param_specs(ctx)
    params_abs = model.abstract_params()
    n_params = tree_size(params_abs)

    if shape.kind == "train":
        num_clients = ctx.data_size
        opt = sgd(0.1)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        ospecs = opt_state_specs(opt_abs, pspecs)
        batch_abs, bspecs = model.train_batch_specs(shape, ctx)
        batch_abs["client_ids"] = jax.ShapeDtypeStruct(
            (shape.global_batch,), jnp.int32)
        bspecs["client_ids"] = P(bspecs["tokens"][0])
        mask_abs = jax.ShapeDtypeStruct((num_clients,), jnp.float32)
        key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)

        if step_kind == "fl":
            step = make_fl_round(model, opt, num_clients,
                                 max(num_clients // 2, 1),
                                 noise_std=1e-3, ctx=ctx,
                                 microbatches=microbatches,
                                 fused_probe=fused_probe)
            args = (params_abs, opt_abs, batch_abs, mask_abs, key_abs)
            in_sh = (named(mesh, pspecs), named(mesh, ospecs),
                     named(mesh, bspecs), named(mesh, P()), named(mesh, P()))
        else:  # plain LM step
            from repro.models.api import make_train_step
            step = make_train_step(model, opt, ctx)
            batch_abs.pop("client_ids")
            bspecs.pop("client_ids")
            args = (params_abs, opt_abs, batch_abs)
            in_sh = (named(mesh, pspecs), named(mesh, ospecs),
                     named(mesh, bspecs))
        with mesh:
            # donate params+opt_state: the update aliases their buffers
            lowered = jax.jit(step, in_shardings=in_sh,
                              donate_argnums=(0, 1)).lower(*args)

    elif shape.kind == "prefill":
        batch_abs = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        b_ax = ctx.data_if(shape.global_batch) if shape.global_batch > 1 else None
        bspecs = {"tokens": P(b_ax, None)}
        batch_abs.update(model.extra_inputs(shape.global_batch))
        bspecs.update(model.extra_input_specs(ctx, shape.global_batch))
        step = make_prefill(model, ctx, chunk=chunk)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(named(mesh, pspecs), named(mesh, bspecs)),
            ).lower(params_abs, batch_abs)

    else:  # decode
        (cache_abs, token_abs, pos_abs), (cspecs, tspec, pspec) = \
            model.decode_input_specs(shape, ctx)
        step = make_decode_step(model, ctx)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                              named(mesh, tspec), named(mesh, pspec)),
            ).lower(params_abs, cache_abs, token_abs, pos_abs)

    compiled = lowered.compile()
    return compiled, lowered, model, {"n_params": n_params, "shape": shape}


def analyze(compiled):
    """Per-device cost from the compiled HLO text (while-trip-aware; see
    utils/hlo_cost.py) + XLA's own [loop-body-once] numbers as cross-check."""
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    hc = analyze_hlo(text)
    return {
        "flops": hc.flops,
        "bytes": hc.bytes,
        "collectives": {**hc.wire_by_kind, "total": hc.wire},
        "xla_cost_analysis": {  # NOT trip-count aware — reference only
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
        },
    }


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             step_kind: str = "fl", microbatches: int = 4,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    t0 = time.time()

    compiled, lowered, model, meta = lower_pair(
        arch, shape_name, mesh, step_kind=step_kind, microbatches=microbatches)
    full = analyze(compiled)
    t_full = time.time() - t0

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "step": step_kind, "microbatches": microbatches,
        "n_params": meta["n_params"],
        "compile_s": round(t_full, 1),
        "raw": full,
        "fits_hbm": full["memory"]["peak_bytes"] < HBM_PER_CHIP,
    }

    flops, bytes_ = full["flops"], full["bytes"]
    wire = full["collectives"].get("total", 0.0)

    mf = model_flops(cfg, shape, meta["n_params"])
    roof = Roofline(flops=flops, bytes_hbm=bytes_, bytes_wire=wire,
                    chips=chips, model_flops=mf,
                    collectives=full["collectives"])
    result["roofline"] = {
        "flops_per_dev": flops, "bytes_per_dev": bytes_,
        "wire_per_dev": wire, "model_flops_global": mf,
        **roof.row(),
    }

    if verbose:
        mem = full["memory"]
        print(f"[{arch} x {shape_name} x {mesh_name} x {step_kind}] "
              f"compile={t_full:.0f}s peak={mem['peak_bytes']/2**30:.2f}GiB "
              f"fit={result['fits_hbm']} "
              f"t_c={roof.t_compute*1e3:.1f}ms t_m={roof.t_memory*1e3:.1f}ms "
              f"t_w={roof.t_collective*1e3:.1f}ms "
              f"bound={roof.bottleneck} useful={roof.useful_ratio:.2f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--step", default="fl", choices=["fl", "plain"])
    ap.add_argument("--microbatches", type=int, default=None,
                    help="grad-accumulation slices (default: per-arch)")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        pairs = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES
                 if (a, s) not in SHAPE_SKIPS]
    else:
        pairs = [(args.arch, args.shape)]

    failures = []
    for arch, shape in pairs:
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        fn = outdir / f"{arch}__{shape}__{mesh_tag}__{args.step}.json"
        try:
            mb = args.microbatches or MICROBATCH_DEFAULT.get(arch, 4)
            res = run_pair(arch, shape, multi_pod=args.multi_pod,
                           step_kind=args.step, microbatches=mb)
            fn.write_text(json.dumps(res, indent=2, default=str))
        except Exception as e:  # noqa: BLE001 — record and continue the matrix
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nall {len(pairs)} pairs lowered+compiled OK on "
          f"{'2x16x16' if args.multi_pod else '16x16'}")


if __name__ == "__main__":
    main()
