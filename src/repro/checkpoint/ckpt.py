"""msgpack + numpy pytree checkpointing (no external ckpt deps).

Layout: <dir>/step_<n>.msgpack, each a msgpack map {flat_key: {dtype, shape,
raw bytes}} plus the treedef recovered from a template at restore time.
Keeps `keep` most recent checkpoints.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import msgpack
import numpy as np

_KEY_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _KEY_SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        flat[key] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return flat


def save_checkpoint(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:010d}.msgpack")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(_flatten(tree)))
    os.replace(tmp, path)  # atomic
    # retention
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        os.remove(os.path.join(directory, f"step_{s:010d}.msgpack"))
    return path


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for fn in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)\.msgpack", fn)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, template: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of `template` (shapes/dtypes must match).

    Every leaf comes back as a fresh *writeable* array: ``np.frombuffer``
    views the read-only msgpack bytes, so without the ``.copy()`` a restored
    leaf could neither be mutated in place nor safely donated to a jitted
    update step (XLA would alias a buffer whose storage it must not reuse).
    Dtypes are validated against the template — a silently reinterpreted
    leaf (f32 bytes viewed as f64, or a truncating cast) corrupts training
    state, so mismatches raise instead.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:010d}.msgpack")
    with open(path, "rb") as f:
        flat = msgpack.unpackb(f.read())
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in leaves_with_path:
        key = _KEY_SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        rec = flat[key]
        # dtype without materializing the leaf (device arrays stay on
        # device); a dtype-less template leaf (plain Python scalar) carries
        # no intent about width, so it keeps the old un-validated behavior
        # instead of failing against NumPy's int64/float64 inference
        if hasattr(leaf, "dtype") and np.dtype(rec["dtype"]) != np.dtype(leaf.dtype):
            raise ValueError(
                f"dtype mismatch for {key}: checkpoint has {rec['dtype']}, "
                f"template wants {np.dtype(leaf.dtype)}")
        arr = (np.frombuffer(rec["data"], dtype=rec["dtype"])
               .reshape(rec["shape"]).copy())
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {np.shape(leaf)}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
