"""Pallas TPU kernel: sLSTM time scan with VMEM-resident recurrent weights.

The sLSTM recurrence is a per-timestep matvec against R [H, d, 4, d]. At
production batch sizes (B_local ~ 2-16) the XLA lowering re-reads R from HBM
EVERY step — ~20 TB/device/step at xlstm-1.3b train_4k, the dominant roofline
term (EXPERIMENTS.md §Perf). This kernel processes TIME_BLOCK steps per grid
step with R (and the running state) pinned in VMEM scratch:

    HBM traffic for R:  S reads  ->  S / TIME_BLOCK reads   (128x here)

Grid is 1-D over time blocks (TPU grids run sequentially per core, so the
state scratch carries across blocks). Per block: load gx [T, B, 4, H*d],
fori_loop the recurrence in fp32, write hs [T, B, H*d].

VMEM budget at xlstm-1.3b scale: R bf16 [4,512,4,512] = 8.4 MiB + states
4 x B x 2048 x 4B ~ 0.5 MiB + gx/hs blocks ~ 4 MiB at T=64, B=2 — fits the
~16 MiB VMEM of a v5e core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TIME_BLOCK = 64


def _slstm_kernel(gx_ref, r_ref, b_ref, h0_ref, c0_ref, n0_ref, m0_ref,
                  hs_ref, hT_ref, cT_ref, nT_ref, mT_ref,
                  h_scr, c_scr, n_scr, m_scr, *,
                  tb: int, num_blocks: int, heads: int, dim: int):
    blk = pl.program_id(0)

    @pl.when(blk == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)
        c_scr[...] = c0_ref[...].astype(jnp.float32)
        n_scr[...] = n0_ref[...].astype(jnp.float32)
        m_scr[...] = m0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)        # [H*d, 4*H*d] (kept in VMEM)
    bias = b_ref[...].astype(jnp.float32)     # [1, 4*H*d]

    def step(t, _):
        h = h_scr[...]                         # [B, H*d] fp32
        c = c_scr[...]
        n = n_scr[...]
        m = m_scr[...]
        g = gx_ref[t].astype(jnp.float32)      # [B, 4*H*d]
        rec = jax.lax.dot_general(h, r, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        pre = g + rec + bias                   # [B, 4*H*d]
        b_sz = pre.shape[0]
        pre = pre.reshape(b_sz, 4, heads * dim)
        it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        m_new = jnp.maximum(ft + m, it)
        i = jnp.exp(it - m_new)
        f = jnp.exp(ft + m - m_new)
        c_new = f * c + i * jnp.tanh(zt)
        n_new = f * n + i
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        h_scr[...] = h_new
        c_scr[...] = c_new
        n_scr[...] = n_new
        m_scr[...] = m_new
        hs_ref[t] = h_new.astype(hs_ref.dtype)
        return ()

    jax.lax.fori_loop(0, tb, step, ())

    @pl.when(blk == num_blocks - 1)
    def _final():
        hT_ref[...] = h_scr[...]
        cT_ref[...] = c_scr[...]
        nT_ref[...] = n_scr[...]
        mT_ref[...] = m_scr[...]


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def slstm_pallas(gx, r, b, h0, c0, n0, m0, *, tb: int = TIME_BLOCK,
                 interpret: bool = False):
    """gx [S, B, 4, H, d]; r [H, d, 4, d]; b [4, H, d]; states [B, H, d]."""
    from jax.experimental.pallas import tpu as pltpu

    s, bsz, _, heads, dim = gx.shape
    hd = heads * dim
    tb = min(tb, s)
    assert s % tb == 0, "pad sequence to TIME_BLOCK multiples"
    num_blocks = s // tb
    # layouts: gates flattened so the recurrence is one [B,Hd]x[Hd,4Hd] matmul
    gx2 = gx.reshape(s, bsz, 4 * hd)
    # r [H, d, 4, d] -> [H*d, 4*H*d] block-diagonal over heads
    r_full = jnp.zeros((hd, 4, hd), r.dtype)
    for h in range(heads):
        r_full = r_full.at[h * dim:(h + 1) * dim, :,
                           h * dim:(h + 1) * dim].set(r[h])  # [d, 4, d]
    r2 = r_full.reshape(hd, 4 * hd)
    b2 = b.reshape(1, 4 * hd)
    st = lambda x: x.reshape(bsz, hd).astype(jnp.float32)

    kernel = functools.partial(_slstm_kernel, tb=tb, num_blocks=num_blocks,
                               heads=heads, dim=dim)
    out_shapes = (
        jax.ShapeDtypeStruct((s, bsz, hd), gx.dtype),
        *(jax.ShapeDtypeStruct((bsz, hd), jnp.float32),) * 4,
    )
    outs = pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((tb, bsz, 4 * hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((hd, 4 * hd), lambda i: (0, 0)),   # R: VMEM-resident
            pl.BlockSpec((1, 4 * hd), lambda i: (0, 0)),
            *(pl.BlockSpec((bsz, hd), lambda i: (0, 0)),) * 4,
        ],
        out_specs=(
            pl.BlockSpec((tb, bsz, hd), lambda i: (i, 0, 0)),
            *(pl.BlockSpec((bsz, hd), lambda i: (0, 0)),) * 4,
        ),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((bsz, hd), jnp.float32)] * 4,
        interpret=interpret,
    )(gx2, r2, b2, st(h0), st(c0), st(n0), st(m0))
    hs, hT, cT, nT, mT = outs
    unst = lambda x: x.reshape(bsz, heads, dim)
    return (hs.reshape(s, bsz, heads, dim), (unst(hT), unst(cT), unst(nT),
                                             unst(mT)))
