from repro.kernels.slstm.ops import slstm_scan
