"""Pure-jnp oracle for the sLSTM time-scan kernel (the xLSTM sLSTM cell)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slstm_ref(gx, r, b, h0, c0, n0, m0):
    """gx [S, B, 4, H, d]; r [H, d, 4, d]; b [4, H, d]; states [B, H, d].

    Returns (hs [S, B, H, d], (h, c, n, m) final states). fp32 math with the
    xLSTM m-stabilizer.
    """

    def cell(carry, g):
        h, c, n, m = carry
        rec = jnp.einsum("bhd,hdge->bghe", h, r)
        pre = g + rec + b
        it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        m_new = jnp.maximum(ft + m, it)
        i = jnp.exp(it - m_new)
        f = jnp.exp(ft + m - m_new)
        c_new = f * c + i * jnp.tanh(zt)
        n_new = f * n + i
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    (h, c, n, m), hs = jax.lax.scan(cell, (h0, c0, n0, m0), gx)
    return hs, (h, c, n, m)
