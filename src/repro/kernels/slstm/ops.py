"""Dispatching wrapper for the sLSTM time-scan kernel."""
from __future__ import annotations

import jax

from repro.kernels.slstm.kernel import slstm_pallas
from repro.kernels.slstm.ref import slstm_ref


def slstm_scan(gx, r, b, h0, c0, n0, m0, use_pallas: bool = None):
    """gx [S, B, 4, H, d] -> (hs [S, B, H, d], final (h, c, n, m))."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return slstm_pallas(gx, r, b, h0, c0, n0, m0,
                            interpret=jax.default_backend() != "tpu")
    return slstm_ref(gx, r, b, h0, c0, n0, m0)
