"""Pallas TPU kernel: fused RMSNorm.

One row-block per grid step: mean-square reduction + rsqrt + scale in a
single VMEM-resident pass (fp32 accumulation), eliminating the separate
variance round-trip of the composed jnp version.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                   # [TR, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_pallas(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-5,
                   interpret: bool = False) -> jnp.ndarray:
    """x [R, D]; scale [D]. Rows padded to TILE_R blocks."""
    r, d = x.shape
    tile = min(TILE_R, r)
    pad = (-r) % tile
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    rp = r + pad
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rp // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, d), x.dtype),
        interpret=interpret,
    )(x, scale[None, :])
    return out[:r]
