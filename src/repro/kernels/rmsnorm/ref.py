"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    """x [R, D]; scale [D] -> [R, D] (same dtype as x, fp32 accumulation)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
