"""Dispatching wrapper for the fused RMSNorm kernel (shape-polymorphic)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5,
            use_pallas: bool = None) -> jnp.ndarray:
    """RMSNorm over the last dim for any leading shape."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_pallas:
        out = rmsnorm_pallas(x2, scale, eps=eps,
                             interpret=jax.default_backend() != "tpu")
    else:
        out = rmsnorm_ref(x2, scale, eps)
    return out.reshape(*lead, x.shape[-1])
