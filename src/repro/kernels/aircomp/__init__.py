from repro.kernels.aircomp.ops import (aircomp_aggregate_flat,
                                       quant_aircomp_flat)

__all__ = ["aircomp_aggregate_flat", "quant_aircomp_flat"]
