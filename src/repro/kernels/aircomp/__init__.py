from repro.kernels.aircomp.ops import aircomp_aggregate_flat
