"""Pure-jnp oracle for the AirComp aggregation kernel (eq. 1 + 10).

y[m] = ( sum_i w_i * x[i, m] + noise_std * z[m] ) / k

w_i folds the selection mask and any per-client gain (perfect channel
inversion => gain 1; imperfect-inversion ablations pass |h_i|/h_hat_i).
"""
from __future__ import annotations

import jax.numpy as jnp


def aircomp_ref(x: jnp.ndarray, w: jnp.ndarray, z: jnp.ndarray,
                noise_std: float, k: float) -> jnp.ndarray:
    """x [N, M]; w [N]; z [M] -> [M] in fp32."""
    acc = jnp.einsum("nm,n->m", x.astype(jnp.float32), w.astype(jnp.float32))
    return (acc + noise_std * z.astype(jnp.float32)) / k
