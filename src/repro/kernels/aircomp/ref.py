"""Pure-jnp oracle for the AirComp aggregation kernel (eq. 1 + 10).

y[m] = ( sum_i w_i * x[i, m] + noise_std * z[m] ) / k

w_i folds the selection mask and any per-client gain (perfect channel
inversion => gain 1; imperfect-inversion ablations pass |h_i|/h_hat_i).
Accumulation runs at the input buffer's dtype, never narrower than f32 —
float64 stacks aggregate at full precision instead of being squeezed
through f32 (the per-leaf reference path never did that, and the fused
path must match it).
"""
from __future__ import annotations

import jax.numpy as jnp


def aircomp_ref(x: jnp.ndarray, w: jnp.ndarray, z: jnp.ndarray,
                noise_std: float, k: float) -> jnp.ndarray:
    """x [N, M]; w [N]; z [M] -> [M] at max(x.dtype, f32) precision."""
    acc_t = jnp.result_type(x.dtype, jnp.float32)
    acc = jnp.einsum("nm,n->m", x.astype(acc_t), w.astype(acc_t))
    return (acc + noise_std * z.astype(acc_t)) / k


def quant_aircomp_ref(x: jnp.ndarray, w: jnp.ndarray, d: jnp.ndarray,
                      u: jnp.ndarray, z: jnp.ndarray,
                      noise_std: float, k: float) -> jnp.ndarray:
    """Quantize-aggregate oracle: y = (Σ_c w_c·Q_c(x_c) + σz)/k.

    Q_c is unbiased stochastic rounding on client c's grid:
    Q(x) = ⌊x/d_c + u⌋·d_c with u ~ U[0,1) (``transport.sround``); d_c = 0
    rows pass through unquantized (an all-zero payload). x/u [C, M]; w/d
    [C]; z [M] -> [M] at max(x.dtype, f32) precision.
    """
    acc_t = jnp.result_type(x.dtype, jnp.float32)
    d_ = d[:, None].astype(acc_t)
    safe = jnp.where(d_ > 0, d_, 1.0)
    q = jnp.where(d_ > 0,
                  jnp.floor(x.astype(acc_t) / safe + u.astype(acc_t)) * d_,
                  x.astype(acc_t))
    acc = jnp.einsum("cm,c->m", q, w.astype(acc_t))
    return (acc + noise_std * z.astype(acc_t)) / k


def sparse_aircomp_ref(x: jnp.ndarray, w: jnp.ndarray, thr: jnp.ndarray,
                       z: jnp.ndarray, noise_std: float,
                       k: float) -> jnp.ndarray:
    """Compress-aggregate oracle: y = (Σ_c w_c·x_c·1{|x_c| ≥ thr_c} + σz)/k.

    The sparse transport's eq. (10): each client keeps only its
    above-threshold coordinates (``thr_c`` = the k-th largest |x_c|, drawn
    by ``transport.sparse_thresholds`` OUTSIDE the kernel — compression is
    deterministic). x [C, M]; w/thr [C]; z [M] -> [M] at max(x.dtype, f32)
    precision. The mask compare runs at the accumulation dtype, bit-equal
    to the residual update's recomputation in ``core/transport.py``.
    """
    acc_t = jnp.result_type(x.dtype, jnp.float32)
    x_ = x.astype(acc_t)
    c = jnp.where(jnp.abs(x_) >= thr[:, None].astype(acc_t), x_, 0.0)
    acc = jnp.einsum("cm,c->m", c, w.astype(acc_t))
    return (acc + noise_std * z.astype(acc_t)) / k
