"""Pallas TPU kernel: fused AirComp aggregation (the paper's hot-spot).

Fuses the per-client gain/mask scale, the superposition sum over the client
axis, the AWGN injection and the 1/K normalization into one pass over the
model dimension — one HBM read of the [N, M] stacked updates, one HBM write
of the [M] aggregate. Blocked over M with VMEM tiles of [N, TILE_M]; the
weighted reduction over N runs on the VPU as an fp32 accumulation.

``noise_std`` and ``k`` ride in as (1, 1) SMEM scalars, NOT static compile
args: the simulator traces both (the receiver noise is a sweepable scenario
knob and K is the *actual* scheduled count under availability/battery
gating), so baking them into the executable would force one recompile per
sweep cell — exactly what the batched sweep engine exists to avoid.

TPU adaptation note (DESIGN.md §2): the paper's multiple-access channel does
this sum "for free" in the air; on TPU the sum is explicit, so fusing
scale+sum+noise+normalize removes three extra HBM round-trips a naive
composition would pay.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_M = 1024  # lane-dim tile; multiple of 128


def _aircomp_kernel(ns_ref, ik_ref, x_ref, w_ref, z_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # [N, TM]
    w = w_ref[...].astype(jnp.float32)          # [N, 1]
    acc = jnp.sum(x * w, axis=0)                # [TM]
    acc = acc + ns_ref[0, 0] * z_ref[...].astype(jnp.float32)
    o_ref[...] = acc * ik_ref[0, 0]


def _quant_aircomp_kernel(ns_ref, ik_ref, x_ref, w_ref, d_ref, u_ref, z_ref,
                          o_ref):
    """Fused quantize-aggregate tile (the quantized transport's hot pass).

    SMEM scalar layout (both (1, 1) f32, in argument order):
      ``ns_ref`` — receiver-noise std σ of eq. (10); traced, NOT a compile
      arg (a noise sweep must not recompile the kernel);
      ``ik_ref`` — 1/K with K the round's ACTUAL scheduled count (traced:
      availability/battery gating makes it data-dependent).
    Per-client VMEM operands ride like the gains: ``w_ref`` [C, 1] mask/gain
    entries, ``d_ref`` [C, 1] stochastic-rounding grid steps Δ_c (0 ⇒ the
    row passes through unquantized). ``u_ref`` [C, TM] pre-drawn U[0,1)
    rounding uniforms tile with ``x_ref`` — the PRNG stays outside the
    kernel (per-client fold_in streams, see ``core/transport.py``), the
    kernel fuses round + scale + superposition-sum + AWGN + normalize into
    one pass over the model dimension.
    """
    x = x_ref[...].astype(jnp.float32)          # [C, TM]
    u = u_ref[...].astype(jnp.float32)          # [C, TM]
    w = w_ref[...].astype(jnp.float32)          # [C, 1]
    d = d_ref[...].astype(jnp.float32)          # [C, 1]
    safe = jnp.where(d > 0, d, 1.0)
    q = jnp.where(d > 0, jnp.floor(x / safe + u) * d, x)
    acc = jnp.sum(q * w, axis=0)                # [TM]
    acc = acc + ns_ref[0, 0] * z_ref[...].astype(jnp.float32)
    o_ref[...] = acc * ik_ref[0, 0]


def _sparse_aircomp_kernel(ns_ref, ik_ref, x_ref, w_ref, t_ref, z_ref,
                           o_ref):
    """Fused compress-aggregate tile (the sparse transport's hot pass).

    Same SMEM scalar layout as the quantized kernel (``ns_ref``/``ik_ref``
    both (1, 1) f32, traced). Per-client VMEM operands: ``w_ref`` [C, 1]
    mask/gain entries, ``t_ref`` [C, 1] per-client magnitude thresholds
    (the k-th largest |payload| coordinate — computed OUTSIDE the kernel by
    ``transport.sparse_thresholds``, the top-k does not tile over M). The
    kernel fuses threshold-compress + scale + superposition-sum + AWGN +
    normalize into one pass over the model dimension.
    """
    x = x_ref[...].astype(jnp.float32)          # [C, TM]
    w = w_ref[...].astype(jnp.float32)          # [C, 1]
    t = t_ref[...].astype(jnp.float32)          # [C, 1]
    c = jnp.where(jnp.abs(x) >= t, x, 0.0)
    acc = jnp.sum(c * w, axis=0)                # [TM]
    acc = acc + ns_ref[0, 0] * z_ref[...].astype(jnp.float32)
    o_ref[...] = acc * ik_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_aircomp_pallas(x: jnp.ndarray, w: jnp.ndarray, thr: jnp.ndarray,
                          z: jnp.ndarray, *, noise_std, k,
                          interpret: bool = False) -> jnp.ndarray:
    """x [C, M]; w/thr [C]; z [M] -> sparse-compressed aggregate [M] fp32.

    Same blocking as :func:`quant_aircomp_pallas` (M padded to TILE_M, C
    whole in VMEM); ``noise_std``/``k`` ride as (1, 1) SMEM scalars. A
    zero-padded column passes the mask only when thr_c = 0 (an all-zero
    payload row) and then contributes w·0 = 0, so padding never leaks.
    """
    c, m = x.shape
    tile = min(TILE_M, m) if m % 128 == 0 else m
    pad = (-m) % tile
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        z = jnp.pad(z, (0, pad))
    mp = m + pad
    grid = (mp // tile,)
    ns = jnp.asarray(noise_std, jnp.float32).reshape(1, 1)
    inv_k = (1.0 / jnp.asarray(k, jnp.float32)).reshape(1, 1)
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                               memory_space=pltpu.SMEM)
    out = pl.pallas_call(
        _sparse_aircomp_kernel,
        grid=grid,
        in_specs=[
            scalar_spec,
            scalar_spec,
            pl.BlockSpec((c, tile), lambda i: (0, i)),
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=interpret,
    )(ns, inv_k, x, w[:, None], thr[:, None], z)
    return out[:m]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant_aircomp_pallas(x: jnp.ndarray, w: jnp.ndarray, d: jnp.ndarray,
                         u: jnp.ndarray, z: jnp.ndarray,
                         *, noise_std, k, interpret: bool = False
                         ) -> jnp.ndarray:
    """x/u [C, M]; w/d [C]; z [M] -> quantized aggregate [M] fp32.

    Same blocking as :func:`aircomp_pallas` (M padded to TILE_M, C whole in
    VMEM); ``noise_std``/``k`` ride as (1, 1) SMEM scalars per the kernel
    docstring. The zero-padded columns quantize to exact zeros (⌊0 + u⌋ = 0
    for u < 1), so padding never leaks into the output.
    """
    c, m = x.shape
    tile = min(TILE_M, m) if m % 128 == 0 else m
    pad = (-m) % tile
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        u = jnp.pad(u, ((0, 0), (0, pad)))
        z = jnp.pad(z, (0, pad))
    mp = m + pad
    grid = (mp // tile,)
    ns = jnp.asarray(noise_std, jnp.float32).reshape(1, 1)
    inv_k = (1.0 / jnp.asarray(k, jnp.float32)).reshape(1, 1)
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                               memory_space=pltpu.SMEM)
    out = pl.pallas_call(
        _quant_aircomp_kernel,
        grid=grid,
        in_specs=[
            scalar_spec,
            scalar_spec,
            pl.BlockSpec((c, tile), lambda i: (0, i)),
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, tile), lambda i: (0, i)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=interpret,
    )(ns, inv_k, x, w[:, None], d[:, None], u, z)
    return out[:m]


@functools.partial(jax.jit, static_argnames=("interpret",))
def aircomp_pallas(x: jnp.ndarray, w: jnp.ndarray, z: jnp.ndarray,
                   *, noise_std, k, interpret: bool = False) -> jnp.ndarray:
    """x [N, M]; w [N]; z [M] -> aggregated [M] fp32.

    ``noise_std`` and ``k`` may be Python floats or traced jnp scalars. M is
    padded to TILE_M internally; N rides whole in VMEM (N=100 clients x
    1024 lanes x 4B = 400 KiB << 16 MiB VMEM).
    """
    n, m = x.shape
    tile = min(TILE_M, m) if m % 128 == 0 else m
    pad = (-m) % tile
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        z = jnp.pad(z, (0, pad))
    mp = m + pad
    grid = (mp // tile,)
    ns = jnp.asarray(noise_std, jnp.float32).reshape(1, 1)
    inv_k = (1.0 / jnp.asarray(k, jnp.float32)).reshape(1, 1)
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0),
                               memory_space=pltpu.SMEM)
    out = pl.pallas_call(
        _aircomp_kernel,
        grid=grid,
        in_specs=[
            scalar_spec,
            scalar_spec,
            pl.BlockSpec((n, tile), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=interpret,
    )(ns, inv_k, x, w[:, None], z)
    return out[:m]
