"""Pallas TPU kernel: fused AirComp aggregation (the paper's hot-spot).

Fuses the per-client gain/mask scale, the superposition sum over the client
axis, the AWGN injection and the 1/K normalization into one pass over the
model dimension — one HBM read of the [N, M] stacked updates, one HBM write
of the [M] aggregate. Blocked over M with VMEM tiles of [N, TILE_M]; the
weighted reduction over N runs on the VPU as an fp32 accumulation.

TPU adaptation note (DESIGN.md §2): the paper's multiple-access channel does
this sum "for free" in the air; on TPU the sum is explicit, so fusing
scale+sum+noise+normalize removes three extra HBM round-trips a naive
composition would pay.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 1024  # lane-dim tile; multiple of 128


def _aircomp_kernel(x_ref, w_ref, z_ref, o_ref, *, noise_std: float, inv_k: float):
    x = x_ref[...].astype(jnp.float32)          # [N, TM]
    w = w_ref[...].astype(jnp.float32)          # [N, 1]
    acc = jnp.sum(x * w, axis=0)                # [TM]
    acc = acc + noise_std * z_ref[...].astype(jnp.float32)
    o_ref[...] = acc * inv_k


@functools.partial(jax.jit, static_argnames=("noise_std", "k", "interpret"))
def aircomp_pallas(x: jnp.ndarray, w: jnp.ndarray, z: jnp.ndarray,
                   *, noise_std: float, k: float,
                   interpret: bool = False) -> jnp.ndarray:
    """x [N, M]; w [N]; z [M] -> aggregated [M] fp32.

    M is padded to TILE_M internally; N rides whole in VMEM (N=100 clients x
    1024 lanes x 4B = 400 KiB << 16 MiB VMEM).
    """
    n, m = x.shape
    tile = min(TILE_M, m) if m % 128 == 0 else m
    pad = (-m) % tile
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        z = jnp.pad(z, (0, pad))
    mp = m + pad
    grid = (mp // tile,)
    out = pl.pallas_call(
        functools.partial(_aircomp_kernel, noise_std=noise_std, inv_k=1.0 / k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, tile), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=interpret,
    )(x, w[:, None], z)
    return out[:m]
