"""Dispatching wrapper for the AirComp aggregation kernel.

On TPU the Pallas kernel runs compiled; everywhere else (this CPU container)
it runs in interpret mode for correctness work, falling back to the jnp
oracle for speed when ``interpret=False`` is requested off-TPU. The Pallas
kernel accumulates in f32 only: buffers wider than f32 (float64 models) are
routed to the dtype-preserving jnp oracle regardless of backend, so enabling
x64 never silently truncates through the kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.aircomp.kernel import (aircomp_pallas,
                                          quant_aircomp_pallas,
                                          sparse_aircomp_pallas)
from repro.kernels.aircomp.ref import (aircomp_ref, quant_aircomp_ref,
                                       sparse_aircomp_ref)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def aircomp_aggregate_flat(x: jnp.ndarray, w: jnp.ndarray, z: jnp.ndarray,
                           *, noise_std, k,
                           use_pallas: bool = None) -> jnp.ndarray:
    """Fused (sum_i w_i x_i + sigma z)/k over stacked flat updates [N, M].

    ``noise_std`` and ``k`` may be traced scalars (the simulator sweeps the
    former and computes the latter from the round's actual scheduled count);
    both paths accept them without recompiling per value.
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if jnp.dtype(x.dtype).itemsize > 4:
        # f64 accumulation: the Pallas kernel is f32-only — keep precision
        use_pallas = False
    if use_pallas:
        return aircomp_pallas(x, w, z, noise_std=noise_std, k=k,
                              interpret=not on_tpu())
    return aircomp_ref(x, w, z, noise_std, k)


def quant_aircomp_flat(x: jnp.ndarray, w: jnp.ndarray, d: jnp.ndarray,
                       u: jnp.ndarray, z: jnp.ndarray, *, noise_std, k,
                       use_pallas: bool = None) -> jnp.ndarray:
    """Fused quantize-aggregate (Σ_c w_c·Q_c(x_c) + σz)/k over flat payload
    rows [C, M] (the quantized transport's eq. (10) hot pass).

    ``d`` [C] per-client stochastic-rounding steps, ``u`` [C, M] pre-drawn
    rounding uniforms (see ``core/transport.quantize_rows`` for the key
    discipline). Dispatch mirrors :func:`aircomp_aggregate_flat`: Pallas on
    TPU / interpret off-TPU when forced, the jnp oracle otherwise, and
    always the dtype-preserving oracle for wider-than-f32 buffers.
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if jnp.dtype(x.dtype).itemsize > 4:
        use_pallas = False
    if use_pallas:
        return quant_aircomp_pallas(x, w, d, u, z, noise_std=noise_std, k=k,
                                    interpret=not on_tpu())
    return quant_aircomp_ref(x, w, d, u, z, noise_std, k)


def sparse_aircomp_flat(x: jnp.ndarray, w: jnp.ndarray, thr: jnp.ndarray,
                        z: jnp.ndarray, *, noise_std, k,
                        use_pallas: bool = None) -> jnp.ndarray:
    """Fused compress-aggregate (Σ_c w_c·x_c·1{|x_c| ≥ thr_c} + σz)/k over
    flat payload rows [C, M] (the sparse transport's eq. (10) hot pass).

    ``thr`` [C] per-client magnitude thresholds (see
    ``core/transport.sparse_thresholds`` — the top-k runs outside the
    kernel, compression inside is one compare-and-mask). Dispatch mirrors
    :func:`quant_aircomp_flat`: Pallas on TPU / interpret off-TPU when
    forced, the jnp oracle otherwise, and always the dtype-preserving
    oracle for wider-than-f32 buffers.
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if jnp.dtype(x.dtype).itemsize > 4:
        use_pallas = False
    if use_pallas:
        return sparse_aircomp_pallas(x, w, thr, z, noise_std=noise_std, k=k,
                                     interpret=not on_tpu())
    return sparse_aircomp_ref(x, w, thr, z, noise_std, k)
