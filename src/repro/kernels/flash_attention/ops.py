"""Dispatching wrapper: flash attention over model-layout tensors.

Accepts the model layout q [B, Sq, Hkv, G, d], k/v [B, T, Hkv, d] (the layout
``repro.models.attention`` uses), flattens heads, pads sequence to tile
multiples, and calls the Pallas kernel (compiled on TPU, interpret mode on
CPU) or the jnp oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    tq: int = 256, tk: int = 256,
                    use_pallas: bool = None) -> jnp.ndarray:
    """Model layout in/out: q [B, Sq, Hkv, G, d] -> [B, Sq, Hkv, G, d]."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    b, sq, hkv, g, d = q.shape
    t = k.shape[1]
    qh = q.transpose(0, 2, 3, 1, 4).reshape(b * hkv * g, sq, d)
    kh = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)
    if use_pallas:
        o = flash_attention_pallas(
            qh, kh, vh, group=g, causal=causal, window=window,
            tq=min(tq, sq), tk=min(tk, t),
            interpret=jax.default_backend() != "tpu")
    else:
        o = attention_ref(
            qh.reshape(b, hkv * g, sq, d),
            kh.reshape(b, hkv, t, d),
            vh.reshape(b, hkv, t, d),
            causal=causal, window=window,
        ).reshape(b * hkv * g, sq, d)
    return o.reshape(b, hkv, g, sq, d).transpose(0, 3, 1, 2, 4)
