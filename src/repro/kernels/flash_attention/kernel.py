"""Pallas TPU kernel: blocked online-softmax GQA attention.

Grid (bh_q, num_q_blocks, num_kv_blocks); the kv axis is innermost so the
fp32 running (max, denom, acc) scratch persists across kv steps for one
q block (TPU grids execute sequentially per core). BlockSpec index maps
route each of the G query groups to its shared KV head (GQA never repeats
KV in HBM). Causal + sliding-window masks are applied with block-position
iotas; the MXU sees [TQ, d] x [d, TK] and [TQ, TK] x [TK, d] matmuls with
hardware-aligned tiles (multiples of 128 by construction).

Validated in interpret mode on CPU against ``ref.attention_ref`` (this
container has no TPU); ``ops.flash_attention`` dispatches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 256
DEFAULT_TK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  tq: int, tk: int, num_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # [TQ, d]
    k = k_ref[0].astype(jnp.float32)                  # [TK, d]
    v = v_ref[0].astype(jnp.float32)                  # [TK, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    k_pos = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    allowed = jnp.ones((tq, tk), bool)
    if causal:
        allowed &= k_pos <= q_pos
    if window is not None:
        allowed &= k_pos > q_pos - window
    s = jnp.where(allowed, s, NEG_INF)

    m_prev = m_scr[...]                               # [TQ]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(allowed, p, 0.0)                    # kill exp(NEG_INF-m) noise
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == num_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "tq", "tk", "interpret", "group"))
def flash_attention_pallas(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    group: int, causal: bool = True, window: Optional[int] = None,
    tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
    interpret: bool = False,
) -> jnp.ndarray:
    """q [BHq, Sq, d]; k, v [BHkv, T, d]; BHq = BHkv * group.

    The bh index map sends q head b*G+g to kv head b (GQA routing).
    """
    bhq, sq, d = q.shape
    t = k.shape[1]
    tq = min(tq, sq)
    tk = min(tk, t)
    assert sq % tq == 0 and t % tk == 0, "pad seq to tile multiples"
    num_q, num_k = sq // tq, t // tk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        tq=tq, tk=tk, num_k=num_k)

    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=(bhq, num_q, num_k),
        in_specs=[
            pl.BlockSpec((1, tq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, tk, d), lambda bh, iq, ik: (bh // group, ik, 0)),
            pl.BlockSpec((1, tk, d), lambda bh, iq, ik: (bh // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),       # running max m
            pltpu.VMEM((tq,), jnp.float32),       # running denom l
            pltpu.VMEM((tq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
