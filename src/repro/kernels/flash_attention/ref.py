"""Pure-jnp oracle for the flash-attention kernel (GQA, causal, windowed)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None):
    """q [B, Hq, Sq, d]; k, v [B, Hkv, T, d]; Hq = G * Hkv.

    Returns [B, Hq, Sq, d]. Full-materialization softmax in fp32.
    """
    b, hq, sq, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(t)[None, :]
    allowed = jnp.ones((sq, t), bool)
    if causal:
        allowed &= kp <= qp
    if window is not None:
        allowed &= kp > qp - window
    s = jnp.where(allowed, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)
