from repro.utils.tree import (
    tree_size,
    tree_bytes,
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_l2_norm,
    tree_cast,
)
