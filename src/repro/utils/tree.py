"""Pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of scalar elements in a pytree (the paper's M)."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_l2_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def tree_ravel(tree):
    """Flatten a pytree of arrays into a single 1-D vector (f32)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([x.astype(jnp.float32).reshape(-1) for x in leaves])


def tree_unravel(template, vec):
    """Inverse of tree_ravel given a template pytree with shapes/dtypes."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    out = []
    off = 0
    for leaf in leaves:
        n = int(leaf.size)
        out.append(vec[off : off + n].reshape(leaf.shape).astype(leaf.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)
