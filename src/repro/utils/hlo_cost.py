"""HLO-text cost analyzer with while-loop trip-count multiplication.

XLA's built-in ``Compiled.cost_analysis()`` counts ``while`` bodies ONCE
(verified empirically in this container), which under-counts every scanned
layer stack by ~L×. This analyzer parses the *compiled, partitioned* HLO text
and computes per-device:

    flops  — 2 · |result| · |contracted dims| for every ``dot`` (the MXU work;
             elementwise flops are ignored — they ride the memory term),
    bytes  — Σ (result + operand bytes) per top-level instruction (the same
             convention as XLA's bytes_accessed; fusion internals excluded —
             a fusion is one pass over its boundary operands),
    wire   — collective bytes × ring-algorithm factors (see utils/hlo.py),

recursing into while bodies (× parsed trip count), conditionals (max branch)
and call ops (× 1). Fusion-called computations contribute flops only (CPU/TPU
keep dots un-fused, but guard anyway).

Trip counts: scan lowers the bound into the condition computation as an s32
constant compared against the induction variable — we take the max s32
constant found in the cond computation (documented heuristic; scans built by
this framework always match it).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.utils.hlo import _DTYPE_BYTES, _group_size, _wire_factor, _COLLECTIVES

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_S32_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_elems(type_str: str) -> int:
    m = _SHAPE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    wire_by_kind: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.wire += o.wire
        for k, v in o.wire_by_kind.items():
            self.wire_by_kind[k] = self.wire_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.wire * m,
                    {k: v * m for k, v in self.wire_by_kind.items()})


@dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    rest: str
    is_root: bool = False


def _parse_computations(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line.strip())
        if hdr and ("->" in line):
            cur = hdr.group(2)
            comps[cur] = []
            if hdr.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            comps[cur].append(_Instr(m.group(1), m.group(2), m.group(3),
                                     m.group(4),
                                     is_root=line.lstrip().startswith("ROOT")))
    comps["__entry__"] = comps.get(entry, [])
    if entry:
        comps["__entry_name__"] = entry  # type: ignore[assignment]
    return comps


def _trip_count(comps, cond_name: str) -> int:
    best = 1
    for ins in comps.get(cond_name, []):
        if ins.op == "constant" and ins.type_str.strip() in ("s32[]", "u32[]"):
            m = re.match(r"(\d+)\)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
        if ins.op == "fusion":  # bound folded into a compare fusion
            c = _CALLS.search(ins.rest)
            if c:
                for sub in comps.get(c.group(1), []):
                    if sub.op == "constant" and sub.type_str.strip() in (
                            "s32[]", "u32[]"):
                        m = re.match(r"(\d+)\)", sub.rest)
                        if m:
                            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: _Instr, table: Dict[str, str]) -> float:
    out_elems = _result_elems(ins.type_str)
    mc = _CONTRACT.search(ins.rest)
    ops = _OPERAND.findall(ins.rest.split(")")[0])
    if not mc or not ops:
        return 0.0
    lhs_type = table.get(ops[0], "")
    dims = _shape_dims(lhs_type)
    contract = 1
    for idx in (int(i) for i in mc.group(1).split(",") if i):
        if idx < len(dims):
            contract *= dims[idx]
    return 2.0 * out_elems * contract


_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "tuple-select",
}
_SLICE_OPS = {"dynamic-slice", "gather", "slice"}


def _instr_bytes(ins: _Instr, table: Dict[str, str]) -> float:
    """HBM traffic per instruction: touches only what the op actually moves.

    dynamic-slice/gather read the *slice*, not the buffer (XLA's own cost
    model does the same); dynamic-update-slice writes the update in place;
    tuple plumbing is free; everything else = result + operands.
    """
    if ins.op in _FREE_OPS:
        return 0.0
    if ins.op in _SLICE_OPS:
        return 2.0 * _type_bytes(ins.type_str)
    if ins.op in ("dynamic-update-slice", "scatter"):
        ops = _OPERAND.findall(ins.rest.split("),")[0])
        upd = _type_bytes(table.get(ops[1], "")) if len(ops) > 1 else 0
        return 2.0 * upd
    if ins.op in ("broadcast", "iota"):
        return float(_type_bytes(ins.type_str))
    b = _type_bytes(ins.type_str)
    for op_name in _OPERAND.findall(ins.rest.split("),")[0]):
        if op_name in table:
            b += _type_bytes(table[op_name])
    return float(b)


def _fusion_bytes(instrs: List[_Instr]) -> float:
    """HBM traffic at a fusion boundary.

    Parameters feeding only a slice-type op inside contribute the slice size;
    a dynamic-update-slice root writes just the update. Interior values never
    touch HBM.
    """
    table = {i.name: i.type_str for i in instrs}
    consumers: Dict[str, List[_Instr]] = {}
    for ins in instrs:
        for op_name in _OPERAND.findall(ins.rest.split("),")[0]):
            consumers.setdefault(op_name, []).append(ins)
    total = 0.0
    for ins in instrs:
        if ins.op == "parameter":
            cons = consumers.get(ins.name, [])
            if cons and all(c.op in _SLICE_OPS for c in cons):
                total += sum(_type_bytes(c.type_str) for c in cons)
            elif cons and all(c.op == "dynamic-update-slice" for c in cons):
                # buffer updated in place: reads/writes counted at the root
                continue
            else:
                total += _type_bytes(ins.type_str)
        if ins.is_root:
            if ins.op == "dynamic-update-slice":
                ops = _OPERAND.findall(ins.rest.split("),")[0])
                upd = _type_bytes(table.get(ops[1], "")) if len(ops) > 1 else 0
                total += 2.0 * upd
            else:
                total += _type_bytes(ins.type_str)
    return total


def analyze_hlo(text: str) -> Cost:
    comps = _parse_computations(text)
    entry_name = comps.get("__entry_name__")
    memo: Dict[Tuple[str, bool], Cost] = {}

    def comp_cost(name: str, flops_only: bool = False) -> Cost:
        key = (name, flops_only)
        if key in memo:
            return memo[key]
        memo[key] = Cost()  # cycle guard
        instrs = comps.get(name, [])
        table = {i.name: i.type_str for i in instrs}
        total = Cost()
        for ins in instrs:
            if ins.op == "dot":
                total.flops += _dot_flops(ins, table)
            if ins.op == "while":
                body = _BODY.search(ins.rest)
                cond = _COND.search(ins.rest)
                if body:
                    trips = _trip_count(comps, cond.group(1)) if cond else 1
                    total += comp_cost(body.group(1), flops_only).scaled(trips)
                    if cond and not flops_only:
                        total += comp_cost(cond.group(1), flops_only).scaled(trips)
                continue
            if ins.op == "conditional":
                br = _BRANCHES.search(ins.rest)
                if br:
                    branch_costs = [comp_cost(b.strip().lstrip("%"), flops_only)
                                    for b in br.group(1).split(",")]
                    if branch_costs:
                        big = max(branch_costs, key=lambda c: c.flops + c.bytes)
                        total += big
                continue
            if ins.op in ("call", "async-start"):
                c = _CALLS.search(ins.rest)
                if c:
                    total += comp_cost(c.group(1), flops_only)
                continue
            if ins.op == "fusion":
                c = _CALLS.search(ins.rest)
                if c:  # flops only: dots never fuse on this backend, but guard
                    sub = comp_cost(c.group(1), flops_only=True)
                    total.flops += sub.flops
                    if not flops_only:
                        total.bytes += _fusion_bytes(comps.get(c.group(1), []))
                continue
            # ---- collectives -------------------------------------------
            kind = next((k for k in _COLLECTIVES
                         if ins.op == k or ins.op == k + "-start"), None)
            if kind and not flops_only:
                n = _group_size(ins.rest)
                from repro.utils.hlo import _shape_bytes
                w = _wire_factor(kind, n) * _shape_bytes(
                    ins.type_str, reduce_max=ins.op.endswith("-start"))
                total.wire += w
                total.wire_by_kind[kind] = total.wire_by_kind.get(kind, 0.0) + w
            # ---- bytes: op-aware HBM traffic model -----------------------
            if not flops_only:
                total.bytes += _instr_bytes(ins, table)
        memo[key] = total
        return total

    if entry_name:
        return comp_cost(entry_name)
    return Cost()
