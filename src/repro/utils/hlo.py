"""HLO-text collective parser: per-device wire bytes per collective kind.

``compiled.cost_analysis()`` has no collective-bytes entry, so we parse the
partitioned HLO text and sum the *result-shape* bytes of every collective op,
scaled to ring-algorithm wire cost with the participant count parsed from
``replica_groups``:

    all-gather         (n-1)/n * out_bytes
    reduce-scatter     (n-1)   * out_bytes          (= (n-1)/n * in_bytes)
    all-reduce         2*(n-1)/n * buf_bytes
    all-to-all         (n-1)/n * buf_bytes
    collective-permute buf_bytes

Collectives inside ``while`` bodies appear once in the text; the dry-run
corrects with the L=1/L=2 calibration (see launch/dryrun.py).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# iota groups: replica_groups=[16,32]<=[512] -> group size = second dim? No:
# [G,n]<=[N] means G groups of n participants.
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(type_str: str, reduce_max: bool = False) -> int:
    sizes = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d_ in dims.split(","):
            if d_:
                n *= int(d_)
        sizes.append(n * _DTYPE_BYTES[dt])
    if not sizes:
        return 0
    # async "-start" ops carry (operand, result) tuples: max picks the buffer
    return max(sizes) if reduce_max else sum(sizes)


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # unknown layout: conservative


def _wire_factor(kind: str, n: int) -> float:
    if kind == "all-gather":
        return (n - 1) / n
    if kind == "reduce-scatter":
        return float(n - 1)
    if kind == "all-reduce":
        return 2 * (n - 1) / n
    if kind == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-kind wire bytes (per device) from partitioned HLO text."""
    out: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result line looks like: %name = TYPE op-name(...), attrs
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)", ls)
        if not m:
            continue
        op = m.group(2)
        kind = next((c for c in _COLLECTIVES if op == c or op == c + "-start"
                     or op == c + "-done"), None)
        if kind is None or op.endswith("-done"):
            continue
        n = _group_size(ls)
        out[kind] += _wire_factor(kind, n) * _shape_bytes(
            m.group(1), reduce_max=op.endswith("-start"))
    out["total"] = sum(v for k_, v in out.items() if k_ != "total")
    return dict(out)
