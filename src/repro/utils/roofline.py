"""Roofline math for TPU v5e (the deployment target).

Terms are *per-device seconds* for one step:

    compute    = flops_per_device / PEAK_FLOPS
    memory     = bytes_per_device / HBM_BW
    collective = wire_bytes_per_device / ICI_BW

(dividing per-device quantities by per-chip rates is identical to the
chips-normalized global formula). MODEL_FLOPS is the analytic useful compute
(6·N_active·tokens for training, 2·N_active·tokens for single forward), used
to compute the usefulness ratio MODEL_FLOPS / (HLO_FLOPs · chips).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12   # bf16 per chip
HBM_BW = 819e9        # bytes/s per chip
ICI_BW = 50e9         # bytes/s per link


@dataclass
class Roofline:
    flops: float              # per device per step
    bytes_hbm: float          # per device per step
    bytes_wire: float         # per device per step
    chips: int
    model_flops: float        # analytic useful flops, GLOBAL
    collectives: Dict[str, float] = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.bytes_wire / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound (sum) — we report both bound and max."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        hlo_global = self.flops * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline-implied step time."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def row(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_mfu": self.mfu,
        }


def active_params(cfg, total_params: int) -> float:
    """Parameters touched per token (MoE: only routed experts are active)."""
    if cfg.family != "moe":
        return float(total_params)
    # expert weights: 3 matrices [E, D, F] per layer
    expert = 3 * cfg.num_experts * cfg.d_model * cfg.d_ff * cfg.num_layers
    dense_part = total_params - expert
    active_expert = expert * cfg.experts_per_token / cfg.num_experts
    return float(dense_part + active_expert)


def model_flops(cfg, shape, total_params: int) -> float:
    """Analytic useful FLOPs per step (global).

    train: 6·N_active·tokens (fwd+bwd); prefill: 2·N_active·tokens;
    decode: 2·N_active·batch (one token per request).
    """
    n_act = active_params(cfg, total_params)
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch  # decode: 1 new token/request
