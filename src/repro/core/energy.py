"""Uplink energy model (paper eqs. 3-6).

E_i^(t)  = P_i^(t) * t_trans,      t_trans = (M / N_sc) * tau
P~_i^(t) = psi * N_sc / |h_i|^2    (channel-inversion power, eq. 5)
E~_i^(t) = psi * M * tau / |h_i|^2 (scaling+inversion energy per upload)

Only the channel-inversion component enters scheduling (the symbol power
reflects the learning procedure and is excluded, per the paper).

These are the ANALOG AirComp expressions; the quantized and digital-OFDMA
schemes price uploads through ``repro.core.transport.uplink_energy``, which
delegates here for the analog component.
"""
from __future__ import annotations

import jax.numpy as jnp

# The paper's §IV-A truncation threshold |h| >= 0.05: the channel-inversion
# power (eq. 5) diverges as h -> 0, so every energy expression clamps at the
# same floor the channel model truncates at. Channels drawn through
# ``repro.core.channel`` already satisfy h >= floor (the clamp is then the
# exact identity); the guard exists for raw callers — a literally-zero (or
# denormal) channel draw used to yield inf/NaN energy that poisoned battery
# depletion and greedy scores downstream.
TRUNCATION_FLOOR = 0.05


def transmit_energy(h_eff: jnp.ndarray, model_size: int, psi: float,
                    tau: float, floor: float = TRUNCATION_FLOOR):
    """Per-client upload energy E~_i (Joules); h_eff: [...] effective channels.

    ``floor`` is the deep-fade guard (the scenario's traced truncation
    threshold where available): energy is priced at max(h, floor), keeping
    the eq. (5) inversion finite for pathological draws while remaining the
    identity for any channel the truncated fading model can produce.
    """
    return psi * model_size * tau / jnp.square(jnp.maximum(h_eff, floor))


def round_energy(h_eff, mask, model_size: int, psi: float, tau: float,
                 floor: float = TRUNCATION_FLOOR):
    """Cumulative energy of the selected set D^(t): E^(t) = sum_{i in D} E~_i.

    mask: [N] 0/1 participation indicator.
    """
    return jnp.sum(mask * transmit_energy(h_eff, model_size, psi, tau,
                                          floor=floor))
