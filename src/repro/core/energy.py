"""Uplink energy model (paper eqs. 3-6).

E_i^(t)  = P_i^(t) * t_trans,      t_trans = (M / N_sc) * tau
P~_i^(t) = psi * N_sc / |h_i|^2    (channel-inversion power, eq. 5)
E~_i^(t) = psi * M * tau / |h_i|^2 (scaling+inversion energy per upload)

Only the channel-inversion component enters scheduling (the symbol power
reflects the learning procedure and is excluded, per the paper).
"""
from __future__ import annotations

import jax.numpy as jnp


def transmit_energy(h_eff: jnp.ndarray, model_size: int, psi: float, tau: float):
    """Per-client upload energy E~_i (Joules); h_eff: [...] effective channels."""
    return psi * model_size * tau / jnp.square(h_eff)


def round_energy(h_eff, mask, model_size: int, psi: float, tau: float):
    """Cumulative energy of the selected set D^(t): E^(t) = sum_{i in D} E~_i.

    mask: [N] 0/1 participation indicator.
    """
    return jnp.sum(mask * transmit_energy(h_eff, model_size, psi, tau))
