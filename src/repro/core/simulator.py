"""Fully-jitted FL simulator at the paper's native scale (Algorithm 1).

The entire T-round run is a single ``lax.scan``; per-client work is ``vmap``'d
over the stacked client shards, so one simulation of (N=100, T=500, logreg)
runs in seconds on CPU and the five-seed average of the paper is a ``vmap``
over keys.

The per-round body is factored as ``round_fn(point, state, t)`` where
``point`` is a :class:`repro.core.sweep.SweepPoint` pytree of *traced* knobs
(learning rates, energy_C, GCA params, channel scenario). ``run_simulation``
binds one point and scans; the sweep engine (``repro.core.sweep``) instead
``vmap``s the same body over a whole stacked grid of points × seeds under a
single compilation — which is how a five-seed × four-method paper comparison
drops from ~20 compilations to one per selection method.

Faithfulness notes:
  - Descent (Alg. 1 lines 3-9): K clients sampled from ρ^(t) (eq. 9) w/o
    replacement (Gumbel-top-K == the sequential renormalized sampling of
    Prop. 2's analysis); each runs `local_steps` SGD steps with the
    exponentially-decayed η; the PS aggregates over the air (eq. 10).
  - Ascent (lines 10-15): K clients sampled uniformly; scalar losses of the
    *new* global model update λ via γ-ascent + simplex projection.
  - Energy (eqs. 3-6): channel-inversion energy of the selected set only;
    the ascent scalars ride the control channel (no energy charged), as in
    the paper.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.aircomp import aircomp_aggregate_tree
from repro.core.channel import draw_channels_scenario, effective_channel
from repro.core.dro import lambda_ascent
from repro.core.energy import round_energy
from repro.core.selection import gumbel_topk_mask, select_clients
from repro.models.logreg import SimModel
from repro.utils.tree import tree_size


class SimState(NamedTuple):
    w: object          # global model pytree
    lam: jnp.ndarray   # [N] simplex weights
    energy: jnp.ndarray  # cumulative Joules
    key: jnp.ndarray


class SimHistory(NamedTuple):
    avg_acc: jnp.ndarray    # [T]
    worst_acc: jnp.ndarray  # [T]
    std_acc: jnp.ndarray    # [T]
    energy: jnp.ndarray     # [T] cumulative
    loss: jnp.ndarray       # [T] mean train loss of selected set
    num_scheduled: jnp.ndarray  # [T]
    lam: jnp.ndarray        # [T, N]


def _sample_batches(key, x, y, batch_size):
    """Sample one batch per client from stacked shards [N, S, ...]."""
    n, s = y.shape
    idx = jax.random.randint(key, (n, batch_size), 0, s)
    xb = jax.vmap(lambda xc, ic: xc[ic])(x, idx)
    yb = jax.vmap(lambda yc, ic: yc[ic])(y, idx)
    return xb, yb


def make_param_round_fn(model: SimModel, fl: FLConfig, data, model_size: int,
                        method: str, noise_free: bool | None = None):
    """Build ``round_fn(point, state, t)``.

    Everything structural (N, K, T, batch/local-step counts, subcarriers,
    flat-vs-selective fading, selection *method*) comes statically from
    ``fl``/``method``; every scalar knob that may ride a sweep axis comes
    traced from ``point`` (see ``repro.core.sweep.SweepPoint``).

    ``noise_free=True`` statically elides the receiver-noise draw of eq. (10)
    (adding z with std 0 is the identity, but the Gaussian sample itself is
    model-sized work per round). The sweep engine sets it when *every* point
    in a compilation group has ``noise_std == 0``; a traced ``noise_std``
    stays live otherwise.
    """
    x, y, x_test, y_test = data
    n = fl.num_clients
    if noise_free is None:
        noise_free = fl.noise_std == 0
    grad_fn = jax.grad(model.loss)
    vloss = jax.vmap(model.loss, in_axes=(None, 0, 0))
    vacc = jax.vmap(model.accuracy, in_axes=(None, 0, 0))
    vgrad_clients = jax.vmap(grad_fn, in_axes=(None, 0, 0))

    def local_update(w, eta, xb, yb):
        """`local_steps` SGD steps from the global model (one client)."""

        def body(wc, _):
            g = grad_fn(wc, xb, yb)
            return jax.tree.map(lambda p, gg: p - eta * gg, wc, g), None

        wc, _ = jax.lax.scan(body, w, None, length=fl.local_steps)
        return wc

    def round_fn(point, state: SimState, t):
        key, k_chan, k_sel, k_batch, k_noise, k_asel, k_abatch = jax.random.split(state.key, 7)
        scen = point.scenario

        # ---- physical layer: fresh block-fading channels (coherence = 1 round)
        h = effective_channel(
            draw_channels_scenario(k_chan, scen, n, fl.num_subcarriers)
        )

        # ---- client selection (descent set D^(t))
        if method == "gca":
            xb0, yb0 = _sample_batches(k_batch, x, y, fl.batch_size)
            grads0 = vgrad_clients(state.w, xb0, yb0)
            gnorms = jax.vmap(
                lambda g: jnp.sqrt(
                    sum(jnp.sum(jnp.square(l)) for l in jax.tree_util.tree_leaves(g))
                )
            )(grads0)
            mask = select_clients("gca", k_sel, state.lam, h, fl.clients_per_round,
                                  grad_norms=gnorms, gca=point.gca)
            k_denom = jnp.maximum(jnp.sum(mask), 1.0)
        else:
            mask = select_clients(method, k_sel, state.lam, h,
                                  fl.clients_per_round, C=point.energy_C)
            k_denom = float(fl.clients_per_round)

        # ---- local updates (vmap over all N; only selected enter the sum)
        eta = point.lr0 * (point.lr_decay ** t)
        xb, yb = _sample_batches(k_batch, x, y, fl.batch_size)
        w_stack = jax.vmap(local_update, in_axes=(None, None, 0, 0))(state.w, eta, xb, yb)

        # ---- AirComp aggregation (eq. 10)
        noise_std = 0.0 if noise_free else scen.noise_std
        w_new = aircomp_aggregate_tree(w_stack, mask, k_noise, noise_std,
                                       k_denom)

        # ---- energy ledger (only the selected set transmits)
        e_round = round_energy(h, mask, model_size, scen.psi, scen.tau)
        energy = state.energy + e_round

        # ---- ascent step on lambda (uniform K, control channel)
        amask = gumbel_topk_mask(k_asel, jnp.zeros((n,)), fl.clients_per_round)
        xab, yab = _sample_batches(k_abatch, x, y, fl.batch_size)
        losses = vloss(w_new, xab, yab)
        lam_new = lambda_ascent(state.lam, losses, amask, point.ascent_lr)

        # ---- metrics
        accs = vacc(w_new, x_test, y_test)
        sel_loss = jnp.sum(mask * losses) / k_denom
        metrics = SimHistory(
            avg_acc=jnp.mean(accs),
            worst_acc=jnp.min(accs),
            std_acc=jnp.std(accs),
            energy=energy,
            loss=sel_loss,
            num_scheduled=jnp.sum(mask),
            lam=lam_new,
        )
        return SimState(w_new, lam_new, energy, key), metrics

    return round_fn


def make_round_fn(model: SimModel, fl: FLConfig, data, model_size: int):
    """Back-compat wrapper: bind ``fl``'s own knobs, return (state, t) -> ..."""
    from repro.core.sweep import sweep_point_from_config  # local: avoid cycle

    point = sweep_point_from_config(fl)
    round_fn = make_param_round_fn(model, fl, data, model_size, fl.method)
    return lambda state, t: round_fn(point, state, t)


def init_sim_state(model: SimModel, fl: FLConfig, key) -> SimState:
    k_init, k_run = jax.random.split(key)
    w0 = model.init(k_init)
    return SimState(
        w=w0,
        lam=jnp.full((fl.num_clients,), 1.0 / fl.num_clients),
        energy=jnp.zeros(()),
        key=k_run,
    )


def run_simulation(
    model: SimModel,
    fl: FLConfig,
    data,
    seed: Optional[int] = None,
) -> SimHistory:
    """Run T rounds of Algorithm 1 (or a baseline, per fl.method)."""
    from repro.core.sweep import sweep_point_from_config  # local: avoid cycle

    seed = fl.seed if seed is None else seed
    state = init_sim_state(model, fl, jax.random.PRNGKey(seed))
    model_size = tree_size(state.w)
    round_fn = make_param_round_fn(model, fl, data, model_size, fl.method)
    point = sweep_point_from_config(fl)

    @jax.jit
    def run(point, state):
        _, hist = jax.lax.scan(
            lambda s, t: round_fn(point, s, t), state, jnp.arange(fl.rounds))
        return hist

    return run(point, state)


def run_multi_seed(model: SimModel, fl: FLConfig, data, seeds) -> SimHistory:
    """Average over simulation runs (the paper averages 5 seeds).

    Implemented as a one-point sweep through ``repro.core.sweep``: the seed
    axis is a ``vmap`` inside a single jitted computation, replacing the old
    per-seed re-jit loop (one compilation total instead of ``len(seeds)``).
    """
    from repro.core.sweep import run_sweep  # local: avoid import cycle

    result = run_sweep(model, data, [("run", fl)], seeds=tuple(seeds))
    return result.mean_history("run")
