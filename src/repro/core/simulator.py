"""Fully-jitted FL simulator at the paper's native scale (Algorithm 1).

The entire T-round run is a single ``lax.scan``; the per-round body is
factored as ``round_fn(point, state, t)`` where ``point`` is a
:class:`repro.core.sweep.SweepPoint` pytree of *traced* knobs (learning
rates, energy_C, GCA params, channel scenario). ``run_simulation`` binds one
point and scans; the sweep engine (``repro.core.sweep``) instead ``vmap``s
the same body over a whole stacked grid of points × seeds under a single
compilation.

Hot-path contract (see ROADMAP): per-round *model-sized* work scales with
the scheduled set K, not the population N. For exact-K selection methods
(``selection.EXACT_K_METHODS``) the round is gather-compute-scatter:

  1. selection returns the ``lax.top_k`` *indices* [K] alongside the mask
     (``select_clients_sparse``) — availability/battery-gated slots keep
     their index but carry weight 0, so variable-K rounds stay one
     static-shape program;
  2. the K selected clients' batches are gathered and ``local_update`` runs
     on a [K, ...] stack — the [N, model] weight stack is never built;
  3. eq. (10) is one fused pass over the raveled [K, P] flat buffer
     (``aircomp.aircomp_aggregate_stack_tree``: Pallas on TPU, fused jnp
     elsewhere), and the ascent-side losses are evaluated only at the
     ascent + descent slots and scattered back to [N].

GCA's thresholded scheduled count is unbounded by K, so it stays on the
dense [N, model] path — which is also kept (``dense=True``) as the reference
implementation the differential tests pin the sparse path against.

The uplink transport (``repro.core.transport``) is a structural axis of the
round: ``fl.transport`` selects the aggregation + energy program (analog
AirComp / quantized AirComp / digital OFDMA) while every scheme knob rides
traced in ``point.transport`` — the analog program is the pre-transport one
bit-for-bit. The full
N-client test-set eval runs every ``fl.eval_every`` rounds (structural knob;
metrics forward-fill in between). All key consumption is identical across
the sparse/dense/GCA paths, so masks, channels, λ and energy agree
bit-for-bit and model trajectories agree to summation-order.

Faithfulness notes:
  - Descent (Alg. 1 lines 3-9): K clients sampled from ρ^(t) (eq. 9) w/o
    replacement (Gumbel-top-K == the sequential renormalized sampling of
    Prop. 2's analysis); each runs `local_steps` SGD steps with the
    exponentially-decayed η; the PS aggregates over the air (eq. 10).
  - Ascent (lines 10-15): K clients sampled uniformly; scalar losses of the
    *new* global model update λ via γ-ascent + simplex projection.
  - Energy (eqs. 3-6): channel-inversion energy of the selected set only;
    the ascent scalars ride the control channel (no energy charged), as in
    the paper.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.aircomp import (aircomp_aggregate_stack_tree,
                                aircomp_aggregate_tree, aircomp_psum_tree)
from repro.core.channel import (client_keys, draw_channels_scenario,
                                draw_channels_scenario_ids, effective_channel)
from repro.core.dro import lambda_ascent, lambda_summary
from repro.core.dynamics import (commit_process, init_chan_state,
                                 init_chan_state_ids, process_from_config,
                                 step_process)
from repro.core.selection import (EXACT_K_METHODS, availability_logits,
                                  client_gumbel, exact_k_scores, gumbel_topk,
                                  select_clients, select_clients_pop,
                                  select_clients_sparse)
from repro.core.sharding import (all_gather_axis, assemble_batch_rows,
                                 assemble_rows, hierarchical_top_k,
                                 local_slice, project_simplex_sharded)
from repro.core import transport as transport_mod
from repro.core.transport import (TRANSPORTS, quantized_aggregate_psum_tree,
                                  quantized_aggregate_stack_tree,
                                  sparse_aggregate_psum_tree,
                                  sparse_aggregate_stack_tree, sparse_k_coords)
from repro.models.logreg import SimModel
from repro.utils.tree import tree_size


class SimState(NamedTuple):
    w: object          # global model pytree
    lam: jnp.ndarray   # [N] simplex weights
    energy: jnp.ndarray  # cumulative Joules
    key: jnp.ndarray
    # ChanState for temporal scenarios (core/dynamics.py); the empty tuple
    # for static scenarios — a leaf-less slot, so the i.i.d. program (and the
    # scan carry XLA sees) is exactly PR 1's.
    chan_state: Any = ()
    # [3] last computed (avg, worst, std) test accuracy when eval_every > 1
    # (forward-filled between evals); the leaf-less () when eval_every == 1,
    # so the per-round-eval program is carried unchanged.
    eval_cache: Any = ()
    # [ceil(T/E), n_rows] strided λ snapshot buffer when
    # record_lambda_every = E > 1 (lax.scan cannot emit strided stacked
    # outputs, so the snapshots ride the carry and the runner attaches the
    # final buffer as SimHistory.lam); the leaf-less () at E in {0, 1}, so
    # the dense-recording program is carried unchanged.
    lam_snaps: Any = ()
    # [n_rows, P] per-client error-feedback residual memory of the sparse
    # transport (transport="sparse" only; the leaf-less () otherwise, so the
    # analog/quantized/digital programs are carried unchanged). Rows are
    # indexed by client id — LOCAL rows under population sharding, per the
    # ChanState new-carry-leaf rule (core/dynamics.py).
    ef_resid: Any = ()
    # scalar cumulative downlink Joules (the broadcast share of `energy`);
    # exactly zero at the default dl_rx_power = 0
    dl_energy: Any = ()


class SimHistory(NamedTuple):
    avg_acc: jnp.ndarray    # [T]
    worst_acc: jnp.ndarray  # [T]
    std_acc: jnp.ndarray    # [T]
    energy: jnp.ndarray     # [T] cumulative
    loss: jnp.ndarray       # [T] mean train loss of selected set
    num_scheduled: jnp.ndarray  # [T]
    # λ history on the record_lambda_every cadence: [T, N] dense at E=1
    # (today's per-round rows, bit-for-bit), [ceil(T/E), N] snapshots of
    # rounds t % E == 0 at E > 1, the leaf-less () at E=0
    lam: Any
    avail_count: jnp.ndarray  # [T] schedulable clients (avail ∧ battery-ok)
    min_battery: jnp.ndarray  # [T] min remaining Joules (inf when static)
    # always-on O(T) λ diagnostics (dro.lambda_summary — psum-of-local-rows
    # under the sharded control plane): max weight, Shannon entropy, and the
    # effective support size 1/Σλ² (participation ratio)
    lam_max: jnp.ndarray      # [T]
    lam_entropy: jnp.ndarray  # [T]
    lam_ess: jnp.ndarray      # [T]
    # [T] cumulative downlink Joules — the broadcast share of `energy`
    # (which is now uplink + downlink). Additive column: exactly zero at the
    # default dl_rx_power = 0, so pre-downlink trajectories are untouched.
    dl_energy: jnp.ndarray = jnp.float32(0.0)


def _record_lambda(fl: FLConfig, state: SimState, lam_new, t):
    """The λ recording step of a round body: ``(lam history leaf, lam_snaps
    carry)`` under the STRUCTURAL ``fl.record_lambda_every`` cadence.

    E=1 emits the full row as a per-round scan output (the dense [T, N]
    history, today's program bit-for-bit, with an untouched () carry slot);
    E>1 emits a leaf-less () and instead writes row ``t // E`` of the
    fixed-size carry buffer on rounds t % E == 0 (``lax.cond`` +
    ``dynamic_update_slice``, so the buffer is updated in place under the
    scan's donation); E=0 records nothing at all.
    """
    e = fl.record_lambda_every
    if e == 1:
        return lam_new, state.lam_snaps
    if e == 0:
        return (), state.lam_snaps
    snaps = jax.lax.cond(
        t % e == 0,
        lambda buf: jax.lax.dynamic_update_slice_in_dim(
            buf, lam_new[None].astype(buf.dtype), t // e, axis=0),
        lambda buf: buf,
        state.lam_snaps)
    return (), snaps


def _batch_indices(key, n, shard_size, batch_size):
    """The [N, B] in-shard sample indices — the ONLY randomness of batch
    sampling, drawn for all N clients on every path (it is O(N·B) int32s)
    so sparse and dense rounds consume ``k_batch`` identically."""
    return jax.random.randint(key, (n, batch_size), 0, shard_size)


def _sample_batches(key, x, y, batch_size):
    """Sample one batch per client from stacked shards [N, S, ...]."""
    n, s = y.shape
    idx = _batch_indices(key, n, s, batch_size)
    xb = jax.vmap(lambda xc, ic: xc[ic])(x, idx)
    yb = jax.vmap(lambda yc, ic: yc[ic])(y, idx)
    return xb, yb


def _needs_two_stage_gather(n: int, s: int) -> bool:
    """True when the composed flat index ``cidx * s + bidx`` (max N·S - 1)
    no longer fits int32 — the static dispatch predicate of
    :func:`_gather_batches`, decided from shapes at trace time."""
    return n * s - 1 > jnp.iinfo(jnp.int32).max


def _gather_batches(x, y, cidx, bidx, two_stage: bool | None = None):
    """Batches of the selected clients only: [K, B, ...].

    ``cidx`` [K] client indices; ``bidx`` [K, B] in-shard sample indices
    (the selected rows of :func:`_batch_indices`' draw). Composed into one
    flat gather so no [K, shard] intermediate is materialized.

    The composed flat index ``cidx * S + bidx`` needs log2(N·S) bits: at
    population scale (N·S > 2^31, e.g. 2^26 clients × 64-sample shards) the
    int32 arithmetic silently wraps negative and gathers garbage rows. Since
    int64 indices need the x64 mode the rest of the engine does not run
    under, such populations take a two-stage per-client gather instead
    (client row, then in-shard take) — the [K, S, ...] intermediate it may
    materialize is small exactly in the huge-N/modest-S regime that
    overflows. ``two_stage`` forces the choice (tests pin path equality);
    the default decides statically from the shapes.
    """
    n, s = y.shape
    if two_stage is None:
        two_stage = _needs_two_stage_gather(n, s)
    if two_stage:
        xb = jax.vmap(lambda c, b: jnp.asarray(x)[c][b])(cidx, bidx)
        yb = jax.vmap(lambda c, b: jnp.asarray(y)[c][b])(cidx, bidx)
        return xb, yb
    flat = cidx[:, None] * s + bidx                       # [K, B]
    xb = jnp.reshape(jnp.asarray(x), (n * s,) + x.shape[2:])[flat]
    yb = jnp.reshape(jnp.asarray(y), (n * s,))[flat]
    return xb, yb


def make_param_round_fn(model: SimModel, fl: FLConfig, data, model_size: int,
                        method: str, noise_free: bool | None = None,
                        dense: bool = False, axis_name: str | None = None):
    """Build ``round_fn(point, state, t)``.

    Everything structural (N, K, T, batch/local-step counts, subcarriers,
    flat-vs-selective fading, selection *method*, ``eval_every``) comes
    statically from ``fl``/``method``; every scalar knob that may ride a
    sweep axis comes traced from ``point`` (see ``repro.core.sweep``).

    ``dense=True`` forces the [N, model] reference path for exact-K methods
    (GCA always uses it) — the oracle the sparse gather path is pinned
    against by ``tests/test_hotpath.py``.

    ``noise_free=True`` statically elides the receiver-noise draw of eq. (10)
    (adding z with std 0 is the identity, but the Gaussian sample itself is
    model-sized work per round). The sweep engine sets it when *every* point
    in a compilation group has ``noise_std == 0``; a traced ``noise_std``
    stays live otherwise.

    ``axis_name`` (population sharding, ``core/sharding.py``): the round body
    runs inside a ``shard_map`` over a clients mesh axis of that name, and
    ``data`` holds THIS shard's client rows while ``fl.num_clients`` stays
    the global N. The control plane (channels, selection scores, λ, energy,
    availability, batch indices) is drawn replicated at full [N] exactly as
    in the unsharded program — bit-identical O(N) scalars — while the
    model-sized per-client work (local SGD stacks, gradients, losses, the
    test eval) runs on the local rows and eq. (10) becomes a local weighted
    partial-sum + ``psum`` (``aircomp.aircomp_psum_tree``). Dense/GCA rounds
    only: the selected-K gather path stays single-device.
    """
    if fl.control_plane == "sharded":
        if dense:
            raise ValueError(
                "control_plane='sharded' has a single per-method program "
                "(the slot path IS the reference); dense=True selects the "
                "replicated-discipline [N, model] path only")
        return make_control_sharded_round_fn(
            model, fl, data, model_size, method, noise_free=noise_free,
            axis_name=axis_name)
    if fl.control_plane != "replicated":
        raise ValueError(
            f"unknown control_plane {fl.control_plane!r}; "
            "pick 'replicated' or 'sharded'")
    x, y, x_test, y_test = data
    n = fl.num_clients
    shard = y.shape[1]
    if noise_free is None:
        noise_free = fl.noise_std == 0
    pop = axis_name is not None
    sparse = (method in EXACT_K_METHODS) and not dense
    # the uplink transport scheme is STRUCTURAL (Python branches below):
    # "analog" compiles to exactly the pre-transport program, "quantized"
    # swaps the aggregation for the fused quantize-aggregate pass over
    # per-client deltas, "digital" statically elides the superposition noise
    # (orthogonal decode) — every scheme KNOB stays traced in point.transport
    scheme = fl.transport
    if scheme not in TRANSPORTS:
        raise ValueError(
            f"unknown transport {scheme!r}; pick one of {TRANSPORTS}")
    if pop and sparse:
        raise ValueError(
            "population sharding runs the dense [N, model] reference "
            "program; build with dense=True (the selected-K gather path "
            "stays single-device)")
    n_local = y.shape[0]  # == n unless population-sharded
    grad_fn = jax.grad(model.loss)
    vloss = jax.vmap(model.loss, in_axes=(None, 0, 0))
    vacc = jax.vmap(model.accuracy, in_axes=(None, 0, 0))
    vgrad_clients = jax.vmap(grad_fn, in_axes=(None, 0, 0))

    def local_update(w, eta, xb, yb):
        """`local_steps` SGD steps from the global model (one client)."""

        def body(wc, _):
            g = grad_fn(wc, xb, yb)
            return jax.tree.map(lambda p, gg: p - eta * gg, wc, g), None

        wc, _ = jax.lax.scan(body, w, None, length=fl.local_steps)
        return wc

    def local_update_rest(w1, eta, xb, yb):
        """Steps 2..local_steps when step 1's gradient was precomputed."""

        def body(wc, _):
            g = grad_fn(wc, xb, yb)
            return jax.tree.map(lambda p, gg: p - eta * gg, wc, g), None

        wc, _ = jax.lax.scan(body, w1, None, length=fl.local_steps - 1)
        return wc

    temporal = fl.temporal
    # sparse transport: the kept-coordinate count is STATIC (it bakes the
    # compiled top-k width — fl.sparse_density joins STATIC_FIELDS)
    k_coords = (sparse_k_coords(fl.sparse_density, model_size)
                if scheme == "sparse" else None)

    def aggregate_full(tpt, w_prev, w_stack, mask, mask_l, k_noise,
                       noise_std, k_denom, ef_resid):
        """Transport-dispatched eq. (10) over a full [n(_local), model]
        update stack (the dense/GCA and population-sharded paths); returns
        ``(w_new, ef_resid')``. Analog compiles to exactly the
        pre-transport per-leaf/psum calls; digital statically drops the
        AWGN (orthogonal decode); quantized aggregates stochastically-
        rounded per-client deltas, with global client ids addressing the
        rounding streams so sharded rows quantize identically to dense
        ones; sparse top-k-compresses delta + residual per client and
        carries the dropped mass forward (``ef_resid`` rows are LOCAL under
        population sharding — each device updates only its own clients'
        memory). Non-sparse schemes pass the (leaf-less) residual through
        untouched."""
        if scheme == "quantized":
            if pop:
                ids = (jax.lax.axis_index(axis_name) * n_local
                       + jnp.arange(n_local))
                return quantized_aggregate_psum_tree(
                    w_prev, w_stack, mask_l, ids, k_noise, noise_std,
                    tpt.bits, k_denom, axis_name), ef_resid
            return quantized_aggregate_stack_tree(
                w_prev, w_stack, mask, jnp.arange(n), k_noise, noise_std,
                tpt.bits, k_denom), ef_resid
        if scheme == "sparse":
            if pop:
                return sparse_aggregate_psum_tree(
                    w_prev, w_stack, mask_l, k_noise, noise_std, k_coords,
                    k_denom, ef_resid, axis_name)
            return sparse_aggregate_stack_tree(
                w_prev, w_stack, mask, k_noise, noise_std, k_coords,
                k_denom, ef_resid)
        eff_noise = 0.0 if scheme == "digital" else noise_std
        if pop:
            return aircomp_psum_tree(w_stack, mask_l, k_noise, eff_noise,
                                     k_denom, axis_name), ef_resid
        return aircomp_aggregate_tree(w_stack, mask, k_noise, eff_noise,
                                      k_denom), ef_resid

    def sample_batches(key):
        """One batch per client — local rows [n_local, B, ...] under
        population sharding, the full [N, B, ...] otherwise. The [N, B]
        index draw is ALWAYS full-N and replicated (same key, same shape on
        every device), so sharded and unsharded programs consume ``k_batch``
        identically; only the gather is local."""
        if not pop:
            return _sample_batches(key, x, y, fl.batch_size)
        bidx = local_slice(_batch_indices(key, n, shard, fl.batch_size),
                           axis_name, n_local)
        xb = jax.vmap(lambda xc, ic: xc[ic])(x, bidx)
        yb = jax.vmap(lambda yc, ic: yc[ic])(y, bidx)
        return xb, yb

    def round_fn(point, state: SimState, t):
        key, k_chan, k_sel, k_batch, k_noise, k_asel, k_abatch = jax.random.split(state.key, 7)
        scen = point.scenario
        proc = point.process

        # ---- physical layer: block-fading channels (static: i.i.d. redraw;
        # temporal: Gauss-Markov/walk evolution of the chan_state carry).
        # step_process is shared with ParameterServer.step so the two tiers
        # evolve the identical process; battery gating means a client that
        # cannot afford THIS round's upload is excluded from selection, so
        # batteries deplete monotonically and never go negative.
        if temporal:
            cs = state.chan_state
            pstep = step_process(k_chan, scen, proc, cs, n,
                                 fl.num_subcarriers, model_size,
                                 scheme=scheme, tp=point.transport,
                                 dl_num_tx=fl.clients_per_round)
            h, avail, eligible = pstep.h, pstep.avail, pstep.eligible
        else:
            h = effective_channel(
                draw_channels_scenario(k_chan, scen, n, fl.num_subcarriers)
            )
            avail = eligible = None

        # ---- client selection (descent set D^(t))
        sel_idx = None
        if method == "gca":
            # ONE batch draw: the probe batch IS the descent batch by design
            # — GCA's gradient probe doubles as the first descent step, so
            # grads0 is reused as SGD step 1 below instead of being
            # recomputed inside local_update (the former double-work bug:
            # two identical _sample_batches(k_batch, ...) draws feeding two
            # identical per-client gradient computations).
            xb, yb = sample_batches(k_batch)
            grads0 = vgrad_clients(state.w, xb, yb)
            gnorms = jax.vmap(
                lambda g: jnp.sqrt(
                    sum(jnp.sum(jnp.square(l)) for l in jax.tree_util.tree_leaves(g))
                )
            )(grads0)
            if pop:
                # the per-client probe ran on local rows; GCA's threshold
                # statistics (mean/median) are population-wide, so gather
                # the O(N) norms back to the replicated control plane
                gnorms = all_gather_axis(gnorms, axis_name)
            mask = select_clients("gca", k_sel, state.lam, h, fl.clients_per_round,
                                  grad_norms=gnorms, gca=point.gca,
                                  avail=eligible)
        elif sparse:
            mask, sel_idx = select_clients_sparse(
                method, k_sel, state.lam, h, fl.clients_per_round,
                C=point.energy_C, avail=eligible)
        elif pop:
            # exact-K on the sharded population: local top-k per shard, then
            # a global top-k over the K·n_shards candidates — equal to the
            # dense lax.top_k by construction (ties pinned to lowest index)
            mask, _ = select_clients_pop(
                method, k_sel, state.lam, h, fl.clients_per_round, n_local,
                axis_name, C=point.energy_C, avail=eligible)
        else:
            mask = select_clients(method, k_sel, state.lam, h,
                                  fl.clients_per_round, C=point.energy_C,
                                  avail=eligible)
        # the actual scheduled count: == clients_per_round for exact-K
        # methods on static scenarios, variable for GCA and under
        # availability/battery gating. Always traced, so the static and the
        # degenerate-temporal programs do this arithmetic identically.
        k_denom = jnp.maximum(jnp.sum(mask), 1.0)

        # ---- local updates + AirComp aggregation (eq. 10)
        eta = point.lr0 * (point.lr_decay ** t)
        # lint: allow(structural-field): noise_free is an explicit structural arg; the fl.noise_std==0 default binds only single-config runs, and the sweep engine groups on all-noise-free explicitly (see run_sweep)
        noise_std = 0.0 if noise_free else scen.noise_std
        # under population sharding the update stacks are [n_local, model]
        # and eq. (10) is the local partial-sum + psum; the AWGN key/leaf
        # discipline is shared with the dense reference either way
        mask_l = local_slice(mask, axis_name, n_local) if pop else mask
        if method == "gca":
            # SGD step 1 reuses the probe gradients (same batch, same w)
            w1 = jax.vmap(
                lambda g: jax.tree.map(lambda p, gg: p - eta * gg, state.w, g)
            )(grads0)
            if fl.local_steps > 1:
                w_stack = jax.vmap(local_update_rest,
                                   in_axes=(0, None, 0, 0))(w1, eta, xb, yb)
            else:
                w_stack = w1
            w_new, ef_new = aggregate_full(point.transport, state.w, w_stack,
                                           mask, mask_l, k_noise, noise_std,
                                           k_denom, state.ef_resid)
        elif sparse:
            # gather-compute-scatter: only the K selected clients descend
            bidx = _batch_indices(k_batch, n, shard, fl.batch_size)
            xb_s, yb_s = _gather_batches(x, y, sel_idx, bidx[sel_idx])
            w_sel = jax.vmap(local_update,
                             in_axes=(None, None, 0, 0))(state.w, eta, xb_s, yb_s)
            sel_w = mask[sel_idx]  # 0 for availability/battery-gated slots
            ef_new = state.ef_resid
            if scheme == "quantized":
                # sel_idx addresses the rounding streams, so the K gathered
                # rows quantize bit-identically to the dense [N] program's
                w_new = quantized_aggregate_stack_tree(
                    state.w, w_sel, sel_w, sel_idx, k_noise, noise_std,
                    point.transport.bits, k_denom)
            elif scheme == "sparse":
                # the K winners' residual rows ride the same gather/scatter
                # as their batches: compression is a within-row threshold,
                # so the gathered rows compress bit-identically to dense;
                # gated slots (weight 0) keep their residual, and sel_idx
                # is a top-k output (unique), so the scatter-back is exact
                resid_sel = state.ef_resid[sel_idx]
                w_new, resid_new = sparse_aggregate_stack_tree(
                    state.w, w_sel, sel_w, k_noise, noise_std, k_coords,
                    k_denom, resid_sel)
                ef_new = state.ef_resid.at[sel_idx].set(resid_new)
            else:
                w_new = aircomp_aggregate_stack_tree(
                    w_sel, sel_w, k_noise,
                    0.0 if scheme == "digital" else noise_std, k_denom)
        else:
            xb, yb = sample_batches(k_batch)
            w_stack = jax.vmap(local_update,
                               in_axes=(None, None, 0, 0))(state.w, eta, xb, yb)
            w_new, ef_new = aggregate_full(point.transport, state.w, w_stack,
                                           mask, mask_l, k_noise, noise_std,
                                           k_denom, state.ef_resid)
        if temporal or method == "gca":
            # the scheduled set can be EMPTY (battery/availability gating, or
            # GCA's thresholding): the PS then receives nothing over the air
            # and must keep the current global model — not eq. (10)'s zero
            # sum. Exact-K static methods always transmit, so their program
            # stays untouched.
            any_sched = jnp.sum(mask) > 0
            w_new = jax.tree.map(
                lambda agg, old: jnp.where(any_sched, agg, old), w_new, state.w)

        # ---- energy ledger (only the selected set transmits, priced under
        # the round's uplink transport — analog is eqs. 3-6 verbatim; every
        # listening client pays the broadcast receive, exactly zero at the
        # default dl_rx_power = 0)
        e_round = transport_mod.round_energy(scheme, point.transport, h, mask,
                                             model_size, scen)
        recv_count = jnp.sum(pstep.recv) if temporal else jnp.float32(n)
        e_dl = recv_count * transport_mod.downlink_energy(
            scheme, point.transport, model_size, scen,
            num_tx=fl.clients_per_round)
        dl_energy = state.dl_energy + e_dl
        energy = state.energy + e_round + e_dl

        # ---- temporal carry: deplete batteries, persist the process state
        if temporal:
            chan_state = commit_process(pstep, cs, mask)
            avail_count = jnp.sum(eligible)
            min_battery = jnp.min(chan_state.battery)
        else:
            chan_state = state.chan_state
            avail_count = jnp.float32(n)
            min_battery = jnp.float32(jnp.inf)

        # ---- ascent step on lambda (uniform K of the AVAILABLE clients,
        # control channel — no transmit energy, so no battery gating)
        amask, asc_idx = gumbel_topk(
            k_asel, jnp.zeros((n,)) + availability_logits(avail),
            fl.clients_per_round)
        if temporal:
            amask = amask * avail
        if sparse:
            # loss forwards only where they are consumed: the ascent slots
            # (λ update) and the descent slots (selected-set loss metric),
            # scattered back to [N] — identical values to the dense path,
            # which evaluates all N and masks.
            abidx = _batch_indices(k_abatch, n, shard, fl.batch_size)
            xa, ya = _gather_batches(x, y, asc_idx, abidx[asc_idx])
            asc_losses = vloss(w_new, xa, ya)
            losses = jnp.zeros((n,), asc_losses.dtype).at[asc_idx].set(asc_losses)
            xd, yd = _gather_batches(x, y, sel_idx, abidx[sel_idx])
            sel_loss = jnp.sum(mask[sel_idx] * vloss(w_new, xd, yd)) / k_denom
        else:
            xab, yab = sample_batches(k_abatch)
            losses = vloss(w_new, xab, yab)
            if pop:
                # per-client losses computed on local rows; λ's ascent and
                # the selected-set loss metric live on the replicated [N]
                # control plane, so gather them back in client order
                losses = all_gather_axis(losses, axis_name)
            sel_loss = jnp.sum(mask * losses) / k_denom
        lam_new = lambda_ascent(state.lam, losses, amask, point.ascent_lr)
        lam_max, lam_entropy, lam_ess = lambda_summary(lam_new)
        lam_hist, lam_snaps = _record_lambda(fl, state, lam_new, t)

        # ---- metrics: the full N-client test-set eval runs on the
        # eval_every cadence (forward-filled in between); everything else is
        # O(N) scalars and stays per-round.
        def eval_accs():
            """Full test eval: per-client accuracy over the local rows (the
            sharded O(N·test) work), gathered to [N] for the stats."""
            accs = vacc(w_new, x_test, y_test)
            return all_gather_axis(accs, axis_name) if pop else accs

        if fl.eval_every == 1:
            accs = eval_accs()
            stats = jnp.stack([jnp.mean(accs), jnp.min(accs), jnp.std(accs)])
            eval_cache = state.eval_cache  # the leaf-less ()
        else:
            def fresh_eval(_):
                accs = eval_accs()
                return jnp.stack([jnp.mean(accs), jnp.min(accs),
                                  jnp.std(accs)])

            stats = jax.lax.cond(t % fl.eval_every == 0, fresh_eval,
                                 lambda _: state.eval_cache, None)
            eval_cache = stats
        metrics = SimHistory(
            avg_acc=stats[0],
            worst_acc=stats[1],
            std_acc=stats[2],
            energy=energy,
            loss=sel_loss,
            num_scheduled=jnp.sum(mask),
            lam=lam_hist,
            avail_count=avail_count,
            min_battery=min_battery,
            lam_max=lam_max,
            lam_entropy=lam_entropy,
            lam_ess=lam_ess,
            dl_energy=dl_energy,
        )
        return SimState(w_new, lam_new, energy, key, chan_state,
                        eval_cache, lam_snaps, ef_new, dl_energy), metrics

    return round_fn


def _batch_indices_ids(key, ids, shard_size, batch_size):
    """[n, B] in-shard sample indices, content-addressed per client id.

    Row c is ``randint(fold_in(key, ids[c]), ...)`` — a function of (key,
    id) only, so any device can (re)draw any client's batch indices. The
    control_plane="sharded" replacement for :func:`_batch_indices`'s full-[N]
    draw: a shard draws only its own rows, and the selected-K slot gathers
    re-draw just the K winners' rows from the same streams.
    """
    keys = client_keys(key, ids)
    return jax.vmap(
        lambda k: jax.random.randint(k, (batch_size,), 0, shard_size))(keys)


def make_control_sharded_round_fn(model: SimModel, fl: FLConfig, data,
                                  model_size: int, method: str,
                                  noise_free: bool | None = None,
                                  axis_name: str | None = None,
                                  topk_group_size: int | None = None):
    """Build ``round_fn(point, state, t)`` under the SHARDED control plane.

    The O(N)-replicated discipline of :func:`make_param_round_fn` draws every
    per-client random vector at full [N] on every device. Here each device
    materializes only its own ``n_local`` rows of channels, availability,
    selection scores, λ and batch indices, with every draw content-addressed
    by GLOBAL client id (``channel.client_keys`` / ``selection.client_gumbel``
    / :func:`_batch_indices_ids`) — so the unsharded program
    (``ids = arange(N)``) and the mesh-sharded one (``ids`` = this shard's
    rows) specify identical per-client values by construction. (The two
    compiled programs agree to compiler instruction selection: XLA's FMA
    contraction differs across program shapes, worth a few ulps on
    transcendental-adjacent values — integer draws and all discrete
    decisions built from them agree exactly.)

    Exact-K methods select via ``sharding.hierarchical_top_k`` (per-shard →
    group → global tree reduction, O(n_local + K·log D) per device) and run
    the gather-compute-scatter hot path with slot assembly: each winner's
    row/batch is owned by exactly one shard, contributed as
    ``where(owned, v, 0)`` and ``psum``-assembled — adding exact zeros, so
    slots are bit-identical to a single-device gather. Model-sized [K] work
    then runs replicated on every device (it is O(K·model), independent
    of N). GCA keeps its dense per-client probe on local rows, gathering only
    the O(N) norm/channel scalars for its population-wide threshold.

    ``state.lam`` is the LOCAL λ slice [n_local]; the simplex projection is
    the psum-bisection ``sharding.project_simplex_sharded`` (no gather, no
    sort) and the test-eval statistics are psum-of-local-rows, so the
    exact-K round contains NO O(N) collective at all — GCA's population-wide
    threshold statistics are the single documented exception. Machine-checked
    by ``repro.lint`` (AST gather-then-reduce rule + jaxpr primitive census).
    ``axis_name=None`` builds the unsharded reference program the
    differential tests pin the mesh program against.
    """
    x, y, x_test, y_test = data
    n = fl.num_clients
    shard = y.shape[1]
    if noise_free is None:
        noise_free = fl.noise_std == 0
    pop = axis_name is not None
    scheme = fl.transport
    if scheme not in TRANSPORTS:
        raise ValueError(
            f"unknown transport {scheme!r}; pick one of {TRANSPORTS}")
    if method != "gca" and method not in EXACT_K_METHODS:
        raise ValueError(f"unknown selection method {method!r}")
    n_rows = y.shape[0]  # == n unless mesh-sharded
    n_shards = n // n_rows if pop else 1
    kk = fl.clients_per_round
    # sparse transport: static kept-coordinate count (fl.sparse_density is
    # STRUCTURAL — it bakes the compiled top-k width)
    k_coords = (sparse_k_coords(fl.sparse_density, model_size)
                if scheme == "sparse" else None)
    grad_fn = jax.grad(model.loss)
    vloss = jax.vmap(model.loss, in_axes=(None, 0, 0))
    vacc = jax.vmap(model.accuracy, in_axes=(None, 0, 0))
    vgrad_clients = jax.vmap(grad_fn, in_axes=(None, 0, 0))
    temporal = fl.temporal

    def local_update(w, eta, xb, yb):
        def body(wc, _):
            g = grad_fn(wc, xb, yb)
            return jax.tree.map(lambda p, gg: p - eta * gg, wc, g), None

        wc, _ = jax.lax.scan(body, w, None, length=fl.local_steps)
        return wc

    def local_update_rest(w1, eta, xb, yb):
        def body(wc, _):
            g = grad_fn(wc, xb, yb)
            return jax.tree.map(lambda p, gg: p - eta * gg, wc, g), None

        wc, _ = jax.lax.scan(body, w1, None, length=fl.local_steps - 1)
        return wc

    def topk_idx(scores):
        """Global top-k indices [K] of a (sharded) score vector."""
        if pop:
            return hierarchical_top_k(scores, kk, axis_name, n_shards,
                                      group_size=topk_group_size)
        return jax.lax.top_k(scores, kk)[1]

    def slot_vals(vals, idx):
        """vals[idx] across shards: each index is owned by exactly one
        shard; psum of where(owned, v, 0) adds exact zeros — bit-identical
        to the single-device gather."""
        if pop:
            return assemble_rows(vals, idx, axis_name, n_rows)
        return vals[idx]

    def slot_batches(arr, idx, bidx):
        if pop:
            return assemble_batch_rows(arr, idx, bidx, axis_name, n_rows)
        return jax.vmap(lambda c, b: jnp.asarray(arr)[c][b])(idx, bidx)

    def round_fn(point, state: SimState, t):
        key, k_chan, k_sel, k_batch, k_noise, k_asel, k_abatch = jax.random.split(state.key, 7)
        scen = point.scenario
        proc = point.process
        off = jax.lax.axis_index(axis_name) * n_rows if pop else 0
        ids = off + jnp.arange(n_rows, dtype=jnp.int32)

        def scatter_slots(idx, wvals):
            """[K] slot values → local [n_rows] scatter (owned slots only)."""
            lidx = jnp.clip(idx - off, 0, n_rows - 1)
            owned = (idx >= off) & (idx < off + n_rows)
            return jnp.zeros((n_rows,), wvals.dtype).at[lidx].add(
                jnp.where(owned, wvals, jnp.zeros_like(wvals)))

        # ---- physical layer: per-id channel draws (only this shard's rows)
        if temporal:
            cs = state.chan_state
            pstep = step_process(k_chan, scen, proc, cs, n_rows,
                                 fl.num_subcarriers, model_size,
                                 scheme=scheme, tp=point.transport, ids=ids,
                                 dl_num_tx=kk)
            h, avail, eligible = pstep.h, pstep.avail, pstep.eligible
        else:
            h = effective_channel(
                draw_channels_scenario_ids(k_chan, scen, ids,
                                           fl.num_subcarriers))
            avail = eligible = None

        eta = point.lr0 * (point.lr_decay ** t)
        # lint: allow(structural-field): noise_free is an explicit structural arg; the fl.noise_std==0 default binds only single-config runs, and the sweep engine groups on all-noise-free explicitly (see run_sweep)
        noise_std = 0.0 if noise_free else scen.noise_std

        if method == "gca":
            # dense per-client probe on local rows; the probe batch IS the
            # descent batch (grads0 reused as SGD step 1, as in the
            # replicated program)
            bidx_all = _batch_indices_ids(k_batch, ids, shard, fl.batch_size)
            xb = jax.vmap(lambda xc, ic: xc[ic])(x, bidx_all)
            yb = jax.vmap(lambda yc, ic: yc[ic])(y, bidx_all)
            grads0 = vgrad_clients(state.w, xb, yb)
            gnorms = jax.vmap(
                lambda g: jnp.sqrt(
                    sum(jnp.sum(jnp.square(l))
                        for l in jax.tree_util.tree_leaves(g))
                )
            )(grads0)
            if pop:
                # GCA's threshold statistics (mean/median/max) are
                # population-wide: gather the O(N) control scalars — the
                # documented dense-path exception to the psum-of-local-rows
                # rule (the median has no psum form)
                # lint: allow(gather-then-reduce): GCA median/mean thresholds need the full [N] score vector
                gnorms_f = all_gather_axis(gnorms, axis_name)
                # lint: allow(gather-then-reduce): GCA median/mean thresholds need the full [N] score vector
                h_f = all_gather_axis(h, axis_name)
                # lint: allow(gather-then-reduce): GCA median/mean thresholds need the full [N] score vector
                elig_f = (all_gather_axis(eligible, axis_name)
                          if temporal else None)
            else:
                gnorms_f, h_f, elig_f = gnorms, h, eligible
            mask_f = select_clients("gca", k_sel, jnp.zeros_like(h_f), h_f,
                                    kk, grad_norms=gnorms_f, gca=point.gca,
                                    avail=elig_f)
            mask_l = local_slice(mask_f, axis_name, n_rows) if pop else mask_f
            num_sched = jnp.sum(mask_f)
            k_denom = jnp.maximum(num_sched, 1.0)

            w1 = jax.vmap(
                lambda g: jax.tree.map(lambda p, gg: p - eta * gg, state.w, g)
            )(grads0)
            if fl.local_steps > 1:
                w_stack = jax.vmap(local_update_rest,
                                   in_axes=(0, None, 0, 0))(w1, eta, xb, yb)
            else:
                w_stack = w1
            ef_new = state.ef_resid
            if scheme == "quantized":
                if pop:
                    w_new = quantized_aggregate_psum_tree(
                        state.w, w_stack, mask_l, ids, k_noise, noise_std,
                        point.transport.bits, k_denom, axis_name)
                else:
                    w_new = quantized_aggregate_stack_tree(
                        state.w, w_stack, mask_l, ids, k_noise, noise_std,
                        point.transport.bits, k_denom)
            elif scheme == "sparse":
                # residual rows stay shard-local: every device compresses
                # and updates only its own clients' memory
                if pop:
                    w_new, ef_new = sparse_aggregate_psum_tree(
                        state.w, w_stack, mask_l, k_noise, noise_std,
                        k_coords, k_denom, state.ef_resid, axis_name)
                else:
                    w_new, ef_new = sparse_aggregate_stack_tree(
                        state.w, w_stack, mask_l, k_noise, noise_std,
                        k_coords, k_denom, state.ef_resid)
            else:
                eff_noise = 0.0 if scheme == "digital" else noise_std
                if pop:
                    w_new = aircomp_psum_tree(w_stack, mask_l, k_noise,
                                              eff_noise, k_denom, axis_name)
                else:
                    w_new = aircomp_aggregate_tree(w_stack, mask_l, k_noise,
                                                   eff_noise, k_denom)
            # GCA can schedule nobody (thresholding / gating): keep w
            any_sched = num_sched > 0
            w_new = jax.tree.map(
                lambda agg, old: jnp.where(any_sched, agg, old),
                w_new, state.w)
            e_local = transport_mod.round_energy(
                scheme, point.transport, h, mask_l, model_size, scen)
            e_round = jax.lax.psum(e_local, axis_name) if pop else e_local
        else:
            # ---- exact-K: sharded scores → hierarchical top-k → slot path.
            # λ enters per-client (normalizer-free logits), so local lam
            # rows score identically to the dense program's.
            scores = exact_k_scores(method, k_sel, state.lam, h,
                                    C=point.energy_C, avail=eligible, ids=ids)
            sel_idx = topk_idx(scores)
            # availability/battery-gated slots keep their index, weight 0
            sel_w = (slot_vals(eligible, sel_idx) if temporal
                     else jnp.ones((kk,), jnp.float32))
            num_sched = jnp.sum(sel_w)
            k_denom = jnp.maximum(num_sched, 1.0)
            mask_l = scatter_slots(sel_idx, sel_w)

            bidx_sel = _batch_indices_ids(k_batch, sel_idx, shard,
                                          fl.batch_size)
            xb_s = slot_batches(x, sel_idx, bidx_sel)
            yb_s = slot_batches(y, sel_idx, bidx_sel)
            # O(K·model) work, replicated on every device — independent of N
            w_sel = jax.vmap(local_update,
                             in_axes=(None, None, 0, 0))(state.w, eta,
                                                         xb_s, yb_s)
            ef_new = state.ef_resid
            if scheme == "quantized":
                w_new = quantized_aggregate_stack_tree(
                    state.w, w_sel, sel_w, sel_idx, k_noise, noise_std,
                    point.transport.bits, k_denom)
            elif scheme == "sparse":
                # the winners' residual rows ride the same ownership-psum
                # slot assembly as their batches ([K, P] rows, exact
                # zeros), the [K]-slot compression runs replicated on
                # every device, and each shard scatters back only its
                # OWNED rows — duplicate-safe: non-owned clipped indices
                # contribute a zero hit, owned top-k indices are unique
                resid_sel = slot_vals(state.ef_resid, sel_idx)
                w_new, resid_new = sparse_aggregate_stack_tree(
                    state.w, w_sel, sel_w, k_noise, noise_std, k_coords,
                    k_denom, resid_sel)
                lidx = jnp.clip(sel_idx - off, 0, n_rows - 1)
                owned = (sel_idx >= off) & (sel_idx < off + n_rows)
                upd = jnp.zeros_like(state.ef_resid).at[lidx].add(
                    jnp.where(owned[:, None], resid_new,
                              jnp.zeros_like(resid_new)))
                hit = jnp.zeros((n_rows,), jnp.float32).at[lidx].add(
                    jnp.where(owned, 1.0, 0.0))
                ef_new = jnp.where(hit[:, None] > 0, upd, state.ef_resid)
            else:
                w_new = aircomp_aggregate_stack_tree(
                    w_sel, sel_w, k_noise,
                    0.0 if scheme == "digital" else noise_std, k_denom)
            if temporal:
                any_sched = num_sched > 0
                w_new = jax.tree.map(
                    lambda agg, old: jnp.where(any_sched, agg, old),
                    w_new, state.w)
            # energy ledger as a [K]-slot sum — same shape and op order
            # sharded and unsharded, so the ledger is bit-identical
            h_sel = slot_vals(h, sel_idx)
            e_round = jnp.sum(sel_w * transport_mod.uplink_energy(
                scheme, point.transport, h_sel, model_size, scen))
        # downlink: every receiver that can afford the listen window pays
        # for the broadcast (psum-of-local-rows under pop; static N when the
        # process model is off). dl_power=0 keeps the whole block an exact
        # no-op (x + 0·anything = x), preserving pre-downlink trajectories.
        if temporal:
            rc = jnp.sum(pstep.recv)
            recv_count = jax.lax.psum(rc, axis_name) if pop else rc
        else:
            recv_count = jnp.float32(n)
        e_dl = recv_count * transport_mod.downlink_energy(
            scheme, point.transport, model_size, scen, num_tx=kk)
        dl_energy = state.dl_energy + e_dl
        energy = state.energy + e_round + e_dl

        # ---- temporal carry (local rows only)
        if temporal:
            chan_state = commit_process(pstep, cs, mask_l)
            ac = jnp.sum(eligible)
            avail_count = jax.lax.psum(ac, axis_name) if pop else ac
            mb = jnp.min(chan_state.battery)
            min_battery = jax.lax.pmin(mb, axis_name) if pop else mb
        else:
            chan_state = state.chan_state
            avail_count = jnp.float32(n)
            min_battery = jnp.float32(jnp.inf)

        # ---- ascent on λ: uniform-K of the available clients, per-id
        # Gumbel streams, hierarchical top-k over the sharded scores
        ascores = (jnp.zeros((n_rows,)) + availability_logits(avail)
                   + client_gumbel(k_asel, ids))
        asc_idx = topk_idx(ascores)
        a_gate = (slot_vals(avail, asc_idx) if temporal
                  else jnp.ones((kk,), jnp.float32))
        if method == "gca":
            # dense per-client losses on local rows (GCA keeps the [N]
            # loss vector; ascent and sel_loss read it locally)
            bidx_ab = _batch_indices_ids(k_abatch, ids, shard, fl.batch_size)
            xab = jax.vmap(lambda xc, ic: xc[ic])(x, bidx_ab)
            yab = jax.vmap(lambda yc, ic: yc[ic])(y, bidx_ab)
            losses_l = vloss(w_new, xab, yab)
            amask_l = scatter_slots(asc_idx, a_gate)
            asc_contrib = amask_l * losses_l
            sl = jnp.sum(mask_l * losses_l)
            sel_loss = (jax.lax.psum(sl, axis_name) if pop else sl) / k_denom
        else:
            # slot path: losses only where consumed (ascent + descent slots)
            bidx_a = _batch_indices_ids(k_abatch, asc_idx, shard,
                                        fl.batch_size)
            xa = slot_batches(x, asc_idx, bidx_a)
            ya = slot_batches(y, asc_idx, bidx_a)
            asc_losses = vloss(w_new, xa, ya)
            asc_contrib = scatter_slots(asc_idx, a_gate * asc_losses)
            bidx_d = _batch_indices_ids(k_abatch, sel_idx, shard,
                                        fl.batch_size)
            xd = slot_batches(x, sel_idx, bidx_d)
            yd = slot_batches(y, sel_idx, bidx_d)
            sel_loss = jnp.sum(sel_w * vloss(w_new, xd, yd)) / k_denom
        lam_tilde = state.lam + point.ascent_lr * asc_contrib
        # the simplex projection couples all coordinates, but only through
        # the scalar water level θ: psum-bisection keeps it O(N/D + iters)
        # per device with no gather and no sort (ISSUE 8)
        lam_new = project_simplex_sharded(
            lam_tilde, axis_name=axis_name if pop else None)
        lam_max, lam_entropy, lam_ess = lambda_summary(
            lam_new, axis_name if pop else None)
        lam_hist, lam_snaps = _record_lambda(fl, state, lam_new, t)

        # ---- metrics: test eval as psum-of-local-rows. The accuracy vector
        # used to be all_gather'd to [N] for the stats — the one remaining
        # O(N) gather on the exact-K sharded path, flagged by the contract
        # linter's gather-then-reduce rule. mean/min ride one psum/pmin pair
        # and std the two-pass variance (the same centered formula jnp.std
        # evaluates, so the unsharded reference agrees to summation order).
        def eval_stats():
            accs = vacc(w_new, x_test, y_test)
            if not pop:
                return jnp.stack(
                    [jnp.mean(accs), jnp.min(accs), jnp.std(accs)])
            n_eval = n_rows * n_shards
            mean = jax.lax.psum(jnp.sum(accs), axis_name) / n_eval
            amin = jax.lax.pmin(jnp.min(accs), axis_name)
            var = jax.lax.psum(jnp.sum(jnp.square(accs - mean)),
                               axis_name) / n_eval
            return jnp.stack([mean, amin, jnp.sqrt(var)])

        if fl.eval_every == 1:
            stats = eval_stats()
            eval_cache = state.eval_cache
        else:
            stats = jax.lax.cond(t % fl.eval_every == 0,
                                 lambda _: eval_stats(),
                                 lambda _: state.eval_cache, None)
            eval_cache = stats
        metrics = SimHistory(
            avg_acc=stats[0],
            worst_acc=stats[1],
            std_acc=stats[2],
            energy=energy,
            loss=sel_loss,
            num_scheduled=num_sched,
            lam=lam_hist,  # LOCAL rows; out_specs concatenate to [T, N]
            avail_count=avail_count,
            min_battery=min_battery,
            lam_max=lam_max,
            lam_entropy=lam_entropy,
            lam_ess=lam_ess,
            dl_energy=dl_energy,
        )
        return SimState(w_new, lam_new, energy, key, chan_state,
                        eval_cache, lam_snaps, ef_new, dl_energy), metrics

    return round_fn


def make_round_fn(model: SimModel, fl: FLConfig, data, model_size: int):
    """Back-compat wrapper: bind ``fl``'s own knobs, return (state, t) -> ..."""
    from repro.core.sweep import sweep_point_from_config  # local: avoid cycle

    point = sweep_point_from_config(fl)
    round_fn = make_param_round_fn(model, fl, data, model_size, fl.method)
    return lambda state, t: round_fn(point, state, t)


def init_sim_state(model: SimModel, fl: FLConfig, key,
                   process=None, ids=None) -> SimState:
    """Initial carry. ``process`` (a traced ``ChannelProcess``, e.g. from a
    ``SweepPoint``) overrides the one derived from ``fl`` so traced knobs like
    ``battery_init`` ride the sweep's vmap axis; static scenarios get the
    leaf-less ``chan_state = ()`` and an unchanged key stream.

    ``ids`` (control_plane="sharded" only): the GLOBAL client ids whose rows
    this state holds — λ and ``chan_state`` are initialized for just those
    rows, with per-id draws (``dynamics.init_chan_state_ids``) so a shard's
    slice is bit-identical to the same rows of the unsharded state. Defaults
    to ``arange(N)`` (the unsharded reference) under the sharded discipline.
    """
    k_init, k_run = jax.random.split(key)
    w0 = model.init(k_init)
    if process is None:
        process = process_from_config(fl)
    sharded_cp = fl.control_plane == "sharded"
    if ids is not None and not sharded_cp:
        raise ValueError(
            "ids is a control_plane='sharded' argument; the replicated "
            "discipline always initializes the full [N] state")
    if sharded_cp and ids is None:
        ids = jnp.arange(fl.num_clients, dtype=jnp.int32)
    chan_state = ()
    if process.temporal:
        # fold_in: an independent stream, so the static path's k_init/k_run
        # consumption (and therefore its trajectories) is untouched
        k_cs = jax.random.fold_in(k_init, 1)
        if sharded_cp:
            chan_state = init_chan_state_ids(
                process, k_cs, ids, fl.num_subcarriers, fl.flat_fading)
        else:
            chan_state = init_chan_state(
                process, k_cs, fl.num_clients, fl.num_subcarriers,
                fl.flat_fading)
    n_rows = fl.num_clients if ids is None else ids.shape[0]
    # round 0 always evaluates (0 % eval_every == 0), so the zeros are never
    # read — the slot just keeps the carry static-shape
    eval_cache = () if fl.eval_every == 1 else jnp.zeros((3,), jnp.float32)
    e = fl.record_lambda_every
    if not isinstance(e, int) or isinstance(e, bool) or e < 0:
        raise ValueError(
            f"record_lambda_every must be an int >= 0, got {e!r}")
    # E in {0, 1} needs no snapshot carry (dense recording / no recording);
    # E > 1 carries the fixed [ceil(T/E), n_rows] strided buffer
    lam_snaps = () if e in (0, 1) else jnp.zeros(
        ((fl.rounds + e - 1) // e, n_rows), jnp.float32)
    # sparse transport: per-client error-feedback memory over the FLAT model
    # ([n_rows, P] — local rows only under the sharded control plane, same
    # per-id row discipline as chan_state). Other transports carry the
    # leaf-less () so their scan carries are byte-identical to before.
    ef_resid = ()
    if fl.transport == "sparse":
        p = sum(int(l.size) for l in jax.tree_util.tree_leaves(w0))
        ef_resid = jnp.zeros((n_rows, p), jnp.float32)
    return SimState(
        w=w0,
        lam=jnp.full((n_rows,), 1.0 / fl.num_clients),
        energy=jnp.zeros(()),
        key=k_run,
        chan_state=chan_state,
        eval_cache=eval_cache,
        lam_snaps=lam_snaps,
        ef_resid=ef_resid,
        dl_energy=jnp.zeros(()),
    )


def run_simulation(
    model: SimModel,
    fl: FLConfig,
    data,
    seed: Optional[int] = None,
    dense: bool = False,
    mesh=None,
) -> SimHistory:
    """Run T rounds of Algorithm 1 (or a baseline, per fl.method).

    ``dense=True`` forces the [N, model] reference path (differential tests
    and benchmarks; exact-K methods default to the sparse gather path).

    ``mesh`` (a 1-D ``jax.sharding.Mesh``, see ``sharding.client_mesh``)
    shards the client population across its devices: dense/GCA rounds and
    the full N-client eval run with per-client state split over the mesh and
    eq. (10) as a cross-device ``psum``. A mesh of size 1 (or None) is a
    structural no-op — this function compiles exactly the single-device
    program.
    """
    from repro.core.sweep import sweep_point_from_config  # local: avoid cycle

    if mesh is not None and mesh.size > 1:
        if fl.control_plane == "sharded":
            from repro.core.sharding import run_simulation_control_sharded
            return run_simulation_control_sharded(model, fl, data, mesh,
                                                  seed=seed)
        from repro.core.sharding import run_simulation_sharded
        return run_simulation_sharded(model, fl, data, mesh, seed=seed,
                                      dense=True)
    seed = fl.seed if seed is None else seed
    point = sweep_point_from_config(fl)
    state = init_sim_state(model, fl, jax.random.PRNGKey(seed),
                           process=point.process)
    model_size = tree_size(state.w)
    round_fn = make_param_round_fn(model, fl, data, model_size, fl.method,
                                   dense=dense)

    @jax.jit
    def run(point, state):
        final, hist = jax.lax.scan(
            lambda s, t: round_fn(point, s, t), state, jnp.arange(fl.rounds))
        if fl.record_lambda_every > 1:
            # the strided snapshots ride the carry; attach the final buffer
            # as the history's λ leaf (scan can't emit strided stacks)
            hist = hist._replace(lam=final.lam_snaps)
        return hist

    return run(point, state)


def run_multi_seed(model: SimModel, fl: FLConfig, data, seeds) -> SimHistory:
    """Average over simulation runs (the paper averages 5 seeds).

    Implemented as a one-point sweep through ``repro.core.sweep``: the seed
    axis is a ``vmap`` inside a single jitted computation, replacing the old
    per-seed re-jit loop (one compilation total instead of ``len(seeds)``).
    """
    from repro.core.sweep import run_sweep  # local: avoid import cycle

    result = run_sweep(model, data, [("run", fl)], seeds=tuple(seeds))
    return result.mean_history("run")
