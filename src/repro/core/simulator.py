"""Fully-jitted FL simulator at the paper's native scale (Algorithm 1).

The entire T-round run is a single ``lax.scan``; per-client work is ``vmap``'d
over the stacked client shards, so one simulation of (N=100, T=500, logreg)
runs in seconds on CPU and the five-seed average of the paper is a ``vmap``
over keys.

Faithfulness notes:
  - Descent (Alg. 1 lines 3-9): K clients sampled from ρ^(t) (eq. 9) w/o
    replacement (Gumbel-top-K == the sequential renormalized sampling of
    Prop. 2's analysis); each runs `local_steps` SGD steps with the
    exponentially-decayed η; the PS aggregates over the air (eq. 10).
  - Ascent (lines 10-15): K clients sampled uniformly; scalar losses of the
    *new* global model update λ via γ-ascent + simplex projection.
  - Energy (eqs. 3-6): channel-inversion energy of the selected set only;
    the ascent scalars ride the control channel (no energy charged), as in
    the paper.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.aircomp import aircomp_aggregate_tree
from repro.core.channel import draw_channels, effective_channel
from repro.core.dro import lambda_ascent
from repro.core.energy import round_energy, transmit_energy
from repro.core.selection import GCAParams, gumbel_topk_mask, select_clients
from repro.models.logreg import SimModel
from repro.utils.tree import tree_size


class SimState(NamedTuple):
    w: object          # global model pytree
    lam: jnp.ndarray   # [N] simplex weights
    energy: jnp.ndarray  # cumulative Joules
    key: jnp.ndarray


class SimHistory(NamedTuple):
    avg_acc: jnp.ndarray    # [T]
    worst_acc: jnp.ndarray  # [T]
    std_acc: jnp.ndarray    # [T]
    energy: jnp.ndarray     # [T] cumulative
    loss: jnp.ndarray       # [T] mean train loss of selected set
    num_scheduled: jnp.ndarray  # [T]
    lam: jnp.ndarray        # [T, N]


def _sample_batches(key, x, y, batch_size):
    """Sample one batch per client from stacked shards [N, S, ...]."""
    n, s = y.shape
    idx = jax.random.randint(key, (n, batch_size), 0, s)
    xb = jax.vmap(lambda xc, ic: xc[ic])(x, idx)
    yb = jax.vmap(lambda yc, ic: yc[ic])(y, idx)
    return xb, yb


def make_round_fn(model: SimModel, fl: FLConfig, data, model_size: int):
    x, y, x_test, y_test = data
    n = fl.num_clients
    grad_fn = jax.grad(model.loss)
    vloss = jax.vmap(model.loss, in_axes=(None, 0, 0))
    vacc = jax.vmap(model.accuracy, in_axes=(None, 0, 0))
    vgrad_clients = jax.vmap(grad_fn, in_axes=(None, 0, 0))

    def local_update(w, eta, xb, yb):
        """`local_steps` SGD steps from the global model (one client)."""

        def body(wc, _):
            g = grad_fn(wc, xb, yb)
            return jax.tree.map(lambda p, gg: p - eta * gg, wc, g), None

        wc, _ = jax.lax.scan(body, w, None, length=fl.local_steps)
        return wc

    def round_fn(state: SimState, t):
        key, k_chan, k_sel, k_batch, k_noise, k_asel, k_abatch = jax.random.split(state.key, 7)

        # ---- physical layer: fresh block-fading channels (coherence = 1 round)
        h = effective_channel(
            draw_channels(k_chan, n, fl.num_subcarriers, fl.channel_floor,
                          flat=fl.flat_fading)
        )

        # ---- client selection (descent set D^(t))
        if fl.method == "gca":
            xb0, yb0 = _sample_batches(k_batch, x, y, fl.batch_size)
            grads0 = vgrad_clients(state.w, xb0, yb0)
            gnorms = jax.vmap(
                lambda g: jnp.sqrt(
                    sum(jnp.sum(jnp.square(l)) for l in jax.tree_util.tree_leaves(g))
                )
            )(grads0)
            mask = select_clients("gca", k_sel, state.lam, h, fl.clients_per_round,
                                  grad_norms=gnorms)
            k_denom = jnp.maximum(jnp.sum(mask), 1.0)
        else:
            mask = select_clients(fl.method, k_sel, state.lam, h,
                                  fl.clients_per_round, C=fl.energy_C)
            k_denom = float(fl.clients_per_round)

        # ---- local updates (vmap over all N; only selected enter the sum)
        eta = fl.lr0 * (fl.lr_decay ** t)
        xb, yb = _sample_batches(k_batch, x, y, fl.batch_size)
        w_stack = jax.vmap(local_update, in_axes=(None, None, 0, 0))(state.w, eta, xb, yb)

        # ---- AirComp aggregation (eq. 10)
        w_new = aircomp_aggregate_tree(w_stack, mask, k_noise, fl.noise_std, k_denom)

        # ---- energy ledger (only the selected set transmits)
        e_round = round_energy(h, mask, model_size, fl.psi, fl.tau)
        energy = state.energy + e_round

        # ---- ascent step on lambda (uniform K, control channel)
        amask = gumbel_topk_mask(k_asel, jnp.zeros((n,)), fl.clients_per_round)
        xab, yab = _sample_batches(k_abatch, x, y, fl.batch_size)
        losses = vloss(w_new, xab, yab)
        lam_new = lambda_ascent(state.lam, losses, amask, fl.ascent_lr)

        # ---- metrics
        accs = vacc(w_new, x_test, y_test)
        sel_loss = jnp.sum(mask * losses) / k_denom
        metrics = SimHistory(
            avg_acc=jnp.mean(accs),
            worst_acc=jnp.min(accs),
            std_acc=jnp.std(accs),
            energy=energy,
            loss=sel_loss,
            num_scheduled=jnp.sum(mask),
            lam=lam_new,
        )
        return SimState(w_new, lam_new, energy, key), metrics

    return round_fn


def run_simulation(
    model: SimModel,
    fl: FLConfig,
    data,
    seed: Optional[int] = None,
) -> SimHistory:
    """Run T rounds of Algorithm 1 (or a baseline, per fl.method)."""
    seed = fl.seed if seed is None else seed
    key = jax.random.PRNGKey(seed)
    k_init, k_run = jax.random.split(key)
    w0 = model.init(k_init)
    model_size = tree_size(w0)
    state = SimState(
        w=w0,
        lam=jnp.full((fl.num_clients,), 1.0 / fl.num_clients),
        energy=jnp.zeros(()),
        key=k_run,
    )
    round_fn = make_round_fn(model, fl, data, model_size)

    @jax.jit
    def run(state):
        _, hist = jax.lax.scan(round_fn, state, jnp.arange(fl.rounds))
        return hist

    return run(state)


def run_multi_seed(model: SimModel, fl: FLConfig, data, seeds) -> SimHistory:
    """Average over simulation runs (the paper averages 5 seeds) — one jit."""
    hists = [run_simulation(model, fl, data, seed=s) for s in seeds]
    return jax.tree.map(lambda *xs: jnp.stack(xs).mean(0), *hists)
