"""Multi-device scale-out: the device-mesh layer (ROADMAP "sharding" lever).

Two embarrassingly-parallel axes of the engine are sharded here:

  - **Sweep cells** (``repro.core.sweep.run_sweep(devices=...)``): the stacked
    [S, R] points × seeds grid of a compilation group is split over a 1-D
    ``"cells"`` mesh with ``shard_map`` — each device scans its own seed
    columns of every point row. Cells are fully independent (no cross-cell
    reduction anywhere in the round), so the sharded sweep is *bit-identical*
    to the single-device sweep on every history leaf.

  - **Client population** (``run_simulation(mesh=...)``, dense/GCA rounds +
    the full N-client test eval): per-client model-sized state — data shards,
    batch gathers, local SGD stacks, per-client gradients/losses/accuracies —
    is sharded over a ``"clients"`` mesh axis, and eq. (10)'s over-the-air
    superposition is computed as a local weighted partial-sum followed by a
    ``psum`` (``aircomp.aircomp_psum_tree``): the multiple-access sum the
    paper gets "for free" in the air IS the all-reduce, exactly the mapping
    ``core/aircomp.py`` documents. Exact-K selection is a local top-k per
    shard followed by a global top-k over the K·n_shards candidates
    (:func:`distributed_top_k`), equal to the dense ``lax.top_k`` by
    construction, tie-break included.

Key discipline under sharding — two generations, selected by the STRUCTURAL
``FLConfig.control_plane`` field:

  - ``"replicated"`` (the pre-ISSUE-7 default): every [N]-shaped
    control-plane draw (channels, Gumbel noise, batch indices, availability,
    process innovations) is drawn *replicated* — each device draws the full-N
    array from the identical key and slices its rows — and the model-sized
    AWGN of eq. (10) is drawn once per leaf with the per-leaf key discipline
    of ``aircomp_aggregate_tree``. Masks, λ inputs, energy and every O(N)
    scalar are bit-identical to the single-device program, and the model
    trajectory differs only in the summation order of the cross-shard
    ``psum``. The control plane is O(N) *per device*, which caps N.

  - ``"sharded"`` (ISSUE 7): per-client draws are content-addressed by
    GLOBAL client id (``channel.client_keys`` fold_in streams — the
    quantizer's trick), so each device draws and stores only its N/D rows of
    channels, availability, selection scores, batch indices and ``ChanState``
    — O(N/D) control plane per device. Exact-K selection runs as a
    hierarchical tree top-k (:func:`hierarchical_top_k`); the K winners'
    batches/channels are assembled replicated via ownership-``psum``
    (:func:`assemble_rows` — adding exact zeros, so bit-exact), and the
    mesh run is BIT-identical to the single-device run of the same
    discipline on every history leaf for exact-K methods
    (``run_simulation_control_sharded``; pinned by
    ``tests/test_control_sharded.py``). The λ simplex projection runs as a
    shard-local bisection on the water level (:func:`project_simplex_sharded`
    — psum-of-local-rows, no gather, no sort; ISSUE 8), so the only O(N)
    gather left is GCA's population-wide threshold statistics.

A mesh of size 1 is a structural no-op: callers skip the ``shard_map``
wrapping entirely and compile today's exact programs.

On this CPU container the mesh is realized with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see the CI
multi-device lane and ``tests/test_sharding.py``); on TPU the same code
shards over real chips and the ``psum`` lowers to the ICI all-reduce.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "CELL_AXIS", "CLIENT_AXIS", "cell_mesh", "client_mesh",
    "cells_clients_mesh", "factor_client_devices",
    "resolve_device_count", "population_device_count", "local_slice",
    "all_gather_axis", "distributed_top_k", "hierarchical_top_k",
    "project_simplex_sharded", "global_client_ids", "assemble_rows",
    "assemble_batch_rows", "shard_leading", "shard_batch",
    "run_simulation_sharded", "run_simulation_control_sharded",
    "control_sharded_cell_run", "build_control_sharded_runner",
    "pad_to_multiple",
]

# Mesh axis names. "cells" parallelizes independent sweep cells (points ×
# seeds); "clients" parallelizes the client population inside one simulation.
CELL_AXIS = "cells"
CLIENT_AXIS = "clients"


# ---------------------------------------------------------------------------
# Mesh construction / device accounting
# ---------------------------------------------------------------------------


def _mesh(n_devices: int, axis: str) -> Mesh:
    devs = jax.devices()
    if n_devices > len(devs):
        raise ValueError(
            f"requested {n_devices} devices, only {len(devs)} present "
            "(on CPU, set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.array(devs[:n_devices]), (axis,))


def cell_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D ``"cells"`` mesh over the first ``n_devices`` (default: all)."""
    return _mesh(n_devices or jax.device_count(), CELL_AXIS)


def client_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D ``"clients"`` mesh over the first ``n_devices`` (default: all)."""
    return _mesh(n_devices or jax.device_count(), CLIENT_AXIS)


def cells_clients_mesh(n_devices: int, client_devices: int) -> Mesh:
    """2-D ``("cells", "clients")`` mesh: ``n_devices // client_devices``
    rows of sweep cells × ``client_devices`` columns of client shards, so a
    sweep grid and the client populations inside its cells shard
    simultaneously (ISSUE 8 — ``run_sweep`` factors its device budget here).
    """
    devs = jax.devices()
    if n_devices > len(devs):
        raise ValueError(
            f"requested {n_devices} devices, only {len(devs)} present "
            "(on CPU, set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    if isinstance(client_devices, bool) or \
            not isinstance(client_devices, (int, np.integer)) or \
            client_devices < 1:
        raise ValueError(
            f"client_devices must be a positive int, got {client_devices!r}")
    if n_devices % client_devices:
        raise ValueError(
            f"client_devices must divide the device count evenly, got "
            f"{client_devices} of {n_devices}")
    arr = np.array(devs[:n_devices]).reshape(
        n_devices // client_devices, client_devices)
    return Mesh(arr, (CELL_AXIS, CLIENT_AXIS))


def factor_client_devices(num_clients: int, n_devices: int,
                          client_devices=None) -> int:
    """The ``clients``-axis extent of a 2-D sweep mesh: an explicit request
    (validated — it must divide both the device count and N) or, by default,
    the LARGEST divisor of ``n_devices`` that also divides ``num_clients``
    (maximal population sharding, the million-client north star; remaining
    devices parallelize sweep cells). Always >= 1 — a population no divisor
    fits degrades to pure cell sharding, never an error.
    """
    if isinstance(num_clients, bool) or \
            not isinstance(num_clients, (int, np.integer)) or num_clients < 1:
        raise ValueError(
            f"num_clients must be a positive int, got {num_clients!r}")
    if client_devices is not None:
        if isinstance(client_devices, bool) or \
                not isinstance(client_devices, (int, np.integer)) or \
                client_devices < 1:
            raise ValueError(
                f"client_devices must be a positive int or None, got "
                f"{client_devices!r}")
        c = int(client_devices)
        if n_devices % c:
            raise ValueError(
                f"client_devices={c} must divide devices={n_devices} evenly")
        if num_clients % c:
            raise ValueError(
                f"client_devices={c} must divide num_clients={num_clients} "
                "evenly (equal client shards per device)")
        return c
    for c in range(n_devices, 0, -1):
        if n_devices % c == 0 and num_clients % c == 0:
            return c
    return 1


def resolve_device_count(devices) -> int:
    """Normalize a ``devices`` request: None -> 1 (single-device, today's
    exact program), "auto" -> every local device, int -> exactly that many.

    An over-request raises the same actionable error as ``_mesh`` — it used
    to be silently clamped to the present device count, so
    ``run_sweep(devices=16)`` on an 8-device host quietly ran 8-wide and the
    missing parallelism surfaced only as mystery slowness much later.
    """
    if devices is None:
        return 1
    if devices == "auto":
        return jax.device_count()
    if isinstance(devices, bool) or not isinstance(devices, (int, np.integer)):
        raise TypeError(
            f"devices must be an int, 'auto' or None, got {devices!r}")
    n = int(devices)
    if n < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if n > jax.device_count():
        raise ValueError(
            f"requested {n} devices, only {jax.device_count()} present "
            "(on CPU, set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return n


def population_device_count(num_clients: int,
                            devices: Optional[int] = None) -> int:
    """Largest device count <= ``devices`` (default: all) dividing N evenly —
    population sharding keeps equal client shards per device.

    Validates its inputs: ``num_clients`` must be a positive int (0 used to
    spin the divisor search forever) and ``devices`` must be an int or None
    (a stray ``"auto"`` belongs to :func:`resolve_device_count`; here it
    used to be treated as truthy garbage by the modulo).
    """
    if isinstance(num_clients, bool) or \
            not isinstance(num_clients, (int, np.integer)):
        raise TypeError(
            f"num_clients must be an int, got {num_clients!r}")
    if num_clients < 1:
        raise ValueError(
            f"num_clients must be >= 1, got {num_clients}")
    if devices is None:
        n_dev = jax.device_count()
    else:
        if isinstance(devices, bool) or \
                not isinstance(devices, (int, np.integer)):
            raise TypeError(
                f"devices must be an int or None, got {devices!r} "
                "(resolve 'auto' via resolve_device_count first)")
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        n_dev = int(devices)
    while num_clients % n_dev:
        n_dev -= 1
    return n_dev


# ---------------------------------------------------------------------------
# In-shard-map primitives
# ---------------------------------------------------------------------------


def local_slice(arr: jnp.ndarray, axis_name: str, n_local: int) -> jnp.ndarray:
    """This device's rows of a *replicated* leading-[N] array.

    The control plane draws full-N arrays on every device (identical values —
    same key, same shape); the model-sized work then runs on the local rows
    only. ``n_local`` must be static (N // mesh size)."""
    d = jax.lax.axis_index(axis_name)
    return jax.lax.dynamic_slice_in_dim(arr, d * n_local, n_local)


def all_gather_axis(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Concatenate per-shard leading axes back to the global [N] order."""
    return jax.lax.all_gather(x, axis_name, tiled=True)


def _auto_group_size(n_shards: int) -> int:
    """Default tree fan-in: the largest divisor of D not above sqrt(D), so
    both gather stages carry O(sqrt(D))·k candidates. Below 16 shards the
    flat two-level pass (group = all shards) is already minimal."""
    if n_shards < 16:
        return n_shards
    best = 1
    for g in range(2, int(n_shards ** 0.5) + 1):
        if n_shards % g == 0:
            best = g
    return best if best > 1 else n_shards


def hierarchical_top_k(scores_local: jnp.ndarray, k: int, axis_name: str,
                       n_shards: int, group_size: Optional[int] = None
                       ) -> jnp.ndarray:
    """Global top-k indices [k] of a sharded score vector, tree-reduced.

    Three levels — per-shard → group → global (ISSUE 7):

      1. each shard ``lax.top_k``'s its own rows: kk = min(k, n_local)
         candidates (a shard can contribute at most that many to the true
         top-k, so nothing is lost);
      2. shards ``all_gather`` within *contiguous groups* of ``group_size``
         (``axis_index_groups``) and keep the group's top min(k, G·kk);
      3. one representative gather across the groups (each device sits in
         exactly one transposed representative group, and every member of a
         group computed identical stage-2 results) and a final top-k.

    Per-device traffic is O(G·kk + (D/G)·k) ≈ O(k·sqrt(D)) at the default
    fan-in instead of the flat pass's O(k·D); with ``group_size`` in
    {None at D<16, 1, D} the tree degenerates to the flat two-level pass.

    Equal to dense ``lax.top_k`` *by construction*, ties included: top_k
    emits ties lowest-index-first, groups are contiguous shard ranges
    gathered in shard order, and representative gathers run in group order —
    so every level resolves ties to the lowest global index, recursively
    reproducing the dense semantics. Returns the replicated winner indices;
    callers scatter their own (local or global) masks.
    """
    n_local = scores_local.shape[0]
    kk = min(k, n_local)
    v, i = jax.lax.top_k(scores_local, kk)
    gi = i + jax.lax.axis_index(axis_name) * n_local
    g = group_size if group_size is not None else _auto_group_size(n_shards)
    if g <= 1 or g >= n_shards or n_shards % g:
        # flat two-level: gather all D shards' candidates at once
        cand_v = all_gather_axis(v, axis_name)        # [D*kk], shard order
        cand_i = all_gather_axis(gi, axis_name)
    else:
        n_groups = n_shards // g
        # stage 2: contiguous groups [r·g, (r+1)·g) gather in shard order
        groups = [[b * g + r for r in range(g)] for b in range(n_groups)]
        vv = jax.lax.all_gather(v, axis_name, axis_index_groups=groups,
                                tiled=True)           # [g*kk]
        ii = jax.lax.all_gather(gi, axis_name, axis_index_groups=groups,
                                tiled=True)
        k2 = min(k, g * kk)
        gv, gpos = jax.lax.top_k(vv, k2)
        gidx = ii[gpos]
        # stage 3: transposed representative groups — member r of every
        # group gathers all groups' (identical per member) stage-2 winners
        # in group order; each device appears in exactly one rep group
        rep = [[b * g + r for b in range(n_groups)] for r in range(g)]
        cand_v = jax.lax.all_gather(gv, axis_name, axis_index_groups=rep,
                                    tiled=True)       # [n_groups*k2]
        cand_i = jax.lax.all_gather(gidx, axis_name, axis_index_groups=rep,
                                    tiled=True)
    _, pos = jax.lax.top_k(cand_v, k)
    return cand_i[pos]


def distributed_top_k(scores_local: jnp.ndarray, k: int, axis_name: str,
                      n_global: int, group_size: Optional[int] = None):
    """Exact-K selection over a sharded score vector: ``(mask [N], idx [k])``.

    The winner indices come from :func:`hierarchical_top_k` (flat two-level
    by default below 16 shards — the pre-tree program — and a per-shard →
    group → global tree above, or at an explicit ``group_size``); the [N]
    mask is their scatter. Equal to the dense ``lax.top_k(scores, k)`` by
    construction, tie-break pinned to the lowest global index (see
    :func:`hierarchical_top_k` for the argument). Callers that must not
    materialize O(N) use ``hierarchical_top_k`` directly and scatter a
    local mask.
    """
    n_local = scores_local.shape[0]
    idx = hierarchical_top_k(scores_local, k, axis_name,
                             n_shards=n_global // n_local,
                             group_size=group_size)
    mask = jnp.zeros((n_global,), jnp.float32).at[idx].set(1.0)
    return mask, idx


def project_simplex_sharded(v_local: jnp.ndarray,
                            axis_name: Optional[str] = None,
                            iters: int = 64) -> jnp.ndarray:
    """Euclidean simplex projection of a row-sharded vector — bisection on
    the water level θ, the distributed replacement for the sort-based
    ``dro.project_simplex`` (ISSUE 8).

    θ* is the unique root of the monotone-decreasing piecewise-linear
    g(θ) = Σᵢ max(vᵢ − θ, 0) − 1: each device sums ``max(v_local − θ, 0)``
    over its own N/D rows and one ``psum`` per iteration yields the global
    g — O(N/D + iters) per device with NO gather and NO sort, following the
    distributed-projection rule (psum-of-local-rows, never
    gather-then-reduce). The initial bracket [vmax − 1, vmax] always
    contains θ*: g(vmax) = −1 < 0, and g(vmax − 1) ≥ vmax − (vmax − 1) − 1
    = 0. ``iters=64`` halvings of the unit-width bracket pin the SUPPORT
    SET {i : vᵢ > θ*} (a discrete object, robust to θ jitter); a final
    closed-form polish then recomputes θ from that support —
    θ = (Σ_supp vᵢ − 1) / |supp|, one more psum pair — which is EXACTLY the
    sort-based reference's θ formula with ρ = |supp|, so the result matches
    it to ≤1e-6 relative at any input magnitude (raw bisection alone
    saturates at ulp(vmax), ~4e-6 already at vmax ≈ 40; pinned by
    ``tests/test_lambda_control.py``).

    ``axis_name=None`` runs the identical program on unsharded rows (local
    sums only) — the single-device reference of the sharded discipline, so
    the mesh and no-mesh programs differ only by psum summation order.
    −inf rows are legal (they project to exact 0, as under the sort); the
    projection is undefined when every row is −inf/+inf, exactly as for the
    sort-based reference.
    """
    v = v_local
    vmax = jnp.max(v)
    if axis_name is not None:
        vmax = jax.lax.pmax(vmax, axis_name)

    def g(theta):
        s = jnp.sum(jnp.maximum(v - theta, 0.0))
        if axis_name is not None:
            s = jax.lax.psum(s, axis_name)
        return s - 1.0

    def body(_, bracket):
        lo, hi = bracket
        mid = 0.5 * (lo + hi)
        above = g(mid) > 0          # θ* lies right of mid
        return (jnp.where(above, mid, lo), jnp.where(above, hi, mid))

    lo, hi = jax.lax.fori_loop(0, iters, body, (vmax - 1.0, vmax))
    # support-set polish: >= keeps the argmax in support even if the
    # collapsed bracket rounds to vmax itself, and a row sitting exactly AT
    # the water level contributes θ* to both sums, leaving θ unchanged
    supp = v >= 0.5 * (lo + hi)
    cnt = jnp.sum(supp.astype(v.dtype))
    ssum = jnp.sum(jnp.where(supp, v, 0.0))
    if axis_name is not None:
        cnt = jax.lax.psum(cnt, axis_name)
        ssum = jax.lax.psum(ssum, axis_name)
    theta = (ssum - 1.0) / cnt
    return jnp.maximum(v - theta, 0.0)


def global_client_ids(axis_name: str, n_local: int) -> jnp.ndarray:
    """This shard's GLOBAL client ids [n_local]: d·n_local + arange."""
    return (jax.lax.axis_index(axis_name) * n_local
            + jnp.arange(n_local, dtype=jnp.int32))


def assemble_rows(values_local: jnp.ndarray, idx: jnp.ndarray,
                  axis_name: str, n_local: int) -> jnp.ndarray:
    """Replicated [K, ...] stack of the rows at GLOBAL indices ``idx`` from a
    row-sharded array — the ownership-``psum`` gather of the sharded control
    plane.

    Each global index is owned by exactly one shard; every shard contributes
    its owned rows and an EXACT zero elsewhere (``jnp.where``, never
    multiplication — 0·inf would be NaN), so the psum adds one value and
    D−1 exact zeros per slot: bit-identical to an unsharded gather. O(K·D)
    traffic, O(K) per-device memory.
    """
    off = jax.lax.axis_index(axis_name) * n_local
    lidx = jnp.clip(idx - off, 0, n_local - 1)
    rows = values_local[lidx]                          # [K, ...]
    owned = (idx >= off) & (idx < off + n_local)
    oshape = (-1,) + (1,) * (rows.ndim - 1)
    rows = jnp.where(owned.reshape(oshape), rows, jnp.zeros_like(rows))
    return jax.lax.psum(rows, axis_name)


def assemble_batch_rows(shards_local: jnp.ndarray, idx: jnp.ndarray,
                        bidx: jnp.ndarray, axis_name: str,
                        n_local: int) -> jnp.ndarray:
    """Replicated [K, B, ...] batch stack gathered from sharded client data.

    ``shards_local`` [n_local, S, ...] is this device's client rows;
    ``idx`` [K] global winner ids; ``bidx`` [K, B] their in-shard sample
    indices (content-addressed by id, so any device can draw them — only the
    data rows need the ownership-psum). Same exact-zero argument as
    :func:`assemble_rows`.
    """
    off = jax.lax.axis_index(axis_name) * n_local
    lidx = jnp.clip(idx - off, 0, n_local - 1)
    rows = jax.vmap(lambda c, b: shards_local[c][b])(lidx, bidx)  # [K, B, ...]
    owned = (idx >= off) & (idx < off + n_local)
    oshape = (-1,) + (1,) * (rows.ndim - 1)
    rows = jnp.where(owned.reshape(oshape), rows, jnp.zeros_like(rows))
    return jax.lax.psum(rows, axis_name)


# ---------------------------------------------------------------------------
# Host-side sharding helpers
# ---------------------------------------------------------------------------


def shard_leading(tree, mesh: Mesh, axis: Optional[str] = None):
    """``device_put`` every leaf with its leading axis split over ``mesh``."""
    axis = axis or mesh.axis_names[0]
    sh = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


def shard_batch(batch: dict, mesh: Mesh) -> dict:
    """Shard a production-tier batch dict over the clients axis.

    Leaves whose leading (example) axis divides the mesh size are split; any
    other leaf is replicated. With the canonical one-block-per-client layout
    this partitions per-client forward/backward work across devices under
    jit's SPMD partitioner — semantics are unchanged (sharding is metadata to
    XLA), it is purely a placement hint.
    """
    axis = mesh.axis_names[0]
    split = NamedSharding(mesh, P(axis))
    repl = NamedSharding(mesh, P())
    def put(x):
        arr = jnp.asarray(x)
        ok = arr.ndim >= 1 and arr.shape[0] % mesh.size == 0
        return jax.device_put(arr, split if ok else repl)
    return {k: put(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# Population-sharded simulation runner
# ---------------------------------------------------------------------------


def run_simulation_sharded(model, fl, data, mesh: Mesh, seed=None,
                           dense: bool = True):
    """Run T rounds with the client population sharded over ``mesh``.

    The whole scan runs inside one ``shard_map``: per-client data shards ride
    in split over the ``clients`` axis, the carry (global model, λ, energy,
    keys, ChanState) is replicated, and the round body is the simulator's own
    ``round_fn`` built with ``axis_name`` set (see
    ``simulator.make_param_round_fn``) — dense/GCA rounds only, the regime
    population sharding exists for. Exact-K methods run their dense reference
    program (sharded D ways); the selected-K gather path stays single-device.
    """
    from repro.core.simulator import init_sim_state, make_param_round_fn
    from repro.core.sweep import sweep_point_from_config
    from repro.utils.tree import tree_size

    axis = mesh.axis_names[0]
    n_dev = mesh.size
    if fl.num_clients % n_dev:
        raise ValueError(
            f"population sharding needs N % devices == 0, got "
            f"N={fl.num_clients}, devices={n_dev} "
            "(pick a count via population_device_count)")

    seed = fl.seed if seed is None else seed
    point = sweep_point_from_config(fl)
    state = init_sim_state(model, fl, jax.random.PRNGKey(seed),
                           process=point.process)
    model_size = tree_size(state.w)

    def run(point, state, x, y, x_test, y_test):
        # x/y/x_test/y_test arrive as this device's client rows
        round_fn = make_param_round_fn(
            model, fl, (x, y, x_test, y_test), model_size, fl.method,
            dense=dense, axis_name=axis)
        final, hist = jax.lax.scan(
            lambda s, t: round_fn(point, s, t), state, jnp.arange(fl.rounds))
        if fl.record_lambda_every > 1:
            # strided λ snapshots ride the scan carry, not the per-round
            # stacked outputs (lax.scan cannot emit [T/E] stacks)
            hist = hist._replace(lam=final.lam_snaps)
        return hist

    shard_mapped = shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(), check_rep=False)
    sharded_data = tuple(shard_leading(jnp.asarray(d), mesh, axis)
                         for d in data)
    return jax.jit(shard_mapped)(point, state, *sharded_data)


def run_simulation_control_sharded(model, fl, data, mesh: Mesh, seed=None,
                                   group_size: Optional[int] = None):
    """Run T rounds with the CONTROL PLANE sharded over ``mesh`` (ISSUE 7).

    The ``control_plane="sharded"`` discipline end to end: each device holds
    only its N/D client rows of data, λ, ``ChanState`` and every per-round
    draw (content-addressed by global client id — ``channel.client_keys``),
    selection is the hierarchical tree top-k, and the K winners' batches and
    channels are assembled replicated via ownership-``psum``. Every
    per-client value is sharding-independent by construction (same fold_in
    stream per id, slot assembly adds exact zeros, the tree top-k preserves
    dense tie-breaks); what remains between this and ``run_simulation`` of
    the same config on one device is compiler instruction selection — XLA
    contracts mul+add chains to FMA differently for differently-shaped
    programs — so discrete decisions (scheduled counts, masks, availability)
    agree exactly and continuous histories to a few ulps
    (``tests/test_control_sharded.py`` pins both). ``group_size`` tunes the
    top-k tree fan-in (None = auto).

    The scan carry stays O(model + N/D) per device; the λ simplex projection
    is the psum-bisection :func:`project_simplex_sharded` (O(N/D + iters)
    per device) and the λ history is strided/elidable via
    ``FLConfig.record_lambda_every``, so no O(N) array lands on any single
    device during a round — only the host-side [T, N] stitch of the λ
    history output remains at ``record_lambda_every=1``.
    """
    fn, point, sharded_data = build_control_sharded_runner(
        model, fl, data, mesh, group_size=group_size)
    seed = fl.seed if seed is None else seed
    return fn(point, jax.random.PRNGKey(seed), *sharded_data)


def build_control_sharded_runner(model, fl, data, mesh: Mesh,
                                 group_size: Optional[int] = None):
    """Assemble the sharded-control-plane executable without running it.

    Returns ``(fn, point, sharded_data)`` where
    ``fn(point, key, *sharded_data) -> SimHistory`` is the jitted T-round
    scan of ``run_simulation_control_sharded``. Split out so callers that
    need the compiled artifact itself — ``benchmarks/popscale_bench.py``
    queries ``fn.lower(...).compile().memory_analysis()`` for the O(N/D)
    per-device-memory ceiling — share one definition with the public runner.
    """
    from repro.core.sweep import sweep_point_from_config

    axis = mesh.axis_names[0]
    n_dev = mesh.size
    if fl.control_plane != "sharded":
        raise ValueError(
            "run_simulation_control_sharded needs control_plane='sharded' "
            f"(got {fl.control_plane!r}); the replicated discipline shards "
            "via run_simulation_sharded")
    if fl.num_clients % n_dev:
        raise ValueError(
            f"population sharding needs N % devices == 0, got "
            f"N={fl.num_clients}, devices={n_dev} "
            "(pick a count via population_device_count)")
    n_local = fl.num_clients // n_dev
    point = sweep_point_from_config(fl)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    model_size = int(sum(int(np.prod(l.shape))
                         for l in jax.tree_util.tree_leaves(shapes)))

    run = control_sharded_cell_run(model, fl, fl.method, axis, n_local,
                                   model_size, group_size=group_size)
    shard_mapped = shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis)),
        out_specs=control_sharded_history_specs(fl, axis), check_rep=False)
    sharded_data = tuple(shard_leading(jnp.asarray(d), mesh, axis)
                         for d in data)
    return jax.jit(shard_mapped), point, sharded_data


def control_sharded_cell_run(model, fl, method: str, axis_name,
                             n_local: int, model_size: int,
                             noise_free=None, group_size=None):
    """The shared per-cell body of the sharded control plane:
    ``run(point, key, x, y, x_test, y_test) -> SimHistory`` over THIS
    device's client rows, with the state initialized inside (λ/ChanState
    born local, ids = this shard's global client ids).

    One definition serves both meshes (ISSUE 8): the 1-D clients mesh of
    :func:`build_control_sharded_runner` wraps it in ``shard_map`` directly,
    and the sweep engine's 2-D ``cells × clients`` group runner ``vmap``s it
    over stacked points × seeds inside the donated per-group jit —
    collectives on the clients axis vmap over the cells batch unchanged.
    ``axis_name=None`` builds the unsharded reference program of the same
    discipline. The strided λ snapshot buffer (``record_lambda_every > 1``)
    rides the scan carry and is attached as ``hist.lam`` on the way out.
    """
    from repro.core.simulator import (init_sim_state,
                                      make_control_sharded_round_fn)

    def run(point, key, x, y, x_test, y_test):
        ids = (global_client_ids(axis_name, n_local)
               if axis_name is not None
               else jnp.arange(n_local, dtype=jnp.int32))
        state = init_sim_state(model, fl, key, process=point.process,
                               ids=ids)
        round_fn = make_control_sharded_round_fn(
            model, fl, (x, y, x_test, y_test), model_size, method,
            noise_free=noise_free, axis_name=axis_name,
            topk_group_size=group_size)
        final, hist = jax.lax.scan(
            lambda s, t: round_fn(point, s, t), state, jnp.arange(fl.rounds))
        if fl.record_lambda_every > 1:
            hist = hist._replace(lam=final.lam_snaps)
        return hist

    return run


def control_sharded_history_specs(fl, axis: str, lead: Sequence = ()):
    """``shard_map`` out_specs for a sharded-control-plane ``SimHistory``:
    every leaf is a replicated scalar-per-round except λ, whose rows live
    sharded on their LAST axis and stitch back to global client order
    (``[T, N]`` dense at ``record_lambda_every=1``, ``[ceil(T/E), N]``
    strided at E > 1, the leaf-less ``()`` at E = 0 — the spec on an empty
    subtree is inert). ``lead`` prefixes batch axes (the sweep group
    runner's ``[points, seeds]`` leading dims)."""
    from repro.core.simulator import SimHistory

    rep = P(*lead)
    lam = rep if fl.record_lambda_every == 0 else P(*lead, None, axis)
    return SimHistory(
        avg_acc=rep, worst_acc=rep, std_acc=rep, energy=rep, loss=rep,
        num_scheduled=rep, lam=lam, avail_count=rep, min_battery=rep,
        lam_max=rep, lam_entropy=rep, lam_ess=rep, dl_energy=rep)


def pad_to_multiple(values: Sequence[int], multiple: int) -> list[int]:
    """Pad a seed list so its length divides the cells mesh evenly; padding
    reuses existing entries (the padded columns are computed and discarded).

    An empty ``values`` used to crash with ZeroDivisionError deep in the
    modulo; a non-positive ``multiple`` would pad garbage. Both are caller
    bugs — reject them with actionable errors.
    """
    if not isinstance(multiple, (int, np.integer)) or \
            isinstance(multiple, bool) or multiple < 1:
        raise ValueError(f"multiple must be a positive int, got {multiple!r}")
    values = list(values)
    if not values:
        raise ValueError(
            "pad_to_multiple needs at least one value to pad from "
            "(got an empty sequence)")
    pad = (-len(values)) % multiple
    return values + [values[i % len(values)] for i in range(pad)]
