"""Multi-device scale-out: the device-mesh layer (ROADMAP "sharding" lever).

Two embarrassingly-parallel axes of the engine are sharded here:

  - **Sweep cells** (``repro.core.sweep.run_sweep(devices=...)``): the stacked
    [S, R] points × seeds grid of a compilation group is split over a 1-D
    ``"cells"`` mesh with ``shard_map`` — each device scans its own seed
    columns of every point row. Cells are fully independent (no cross-cell
    reduction anywhere in the round), so the sharded sweep is *bit-identical*
    to the single-device sweep on every history leaf.

  - **Client population** (``run_simulation(mesh=...)``, dense/GCA rounds +
    the full N-client test eval): per-client model-sized state — data shards,
    batch gathers, local SGD stacks, per-client gradients/losses/accuracies —
    is sharded over a ``"clients"`` mesh axis, and eq. (10)'s over-the-air
    superposition is computed as a local weighted partial-sum followed by a
    ``psum`` (``aircomp.aircomp_psum_tree``): the multiple-access sum the
    paper gets "for free" in the air IS the all-reduce, exactly the mapping
    ``core/aircomp.py`` documents. Exact-K selection is a local top-k per
    shard followed by a global top-k over the K·n_shards candidates
    (:func:`distributed_top_k`), equal to the dense ``lax.top_k`` by
    construction, tie-break included.

Key discipline under sharding: every [N]-shaped control-plane draw (channels,
Gumbel noise, batch indices, availability, process innovations) is *replicated*
— each device draws the full-N array from the identical key and slices its
rows — and the model-sized AWGN of eq. (10) is drawn once per leaf with the
per-leaf key discipline of ``aircomp_aggregate_tree``. Consequence: masks, λ
inputs, energy and every O(N) scalar are bit-identical to the single-device
program, and the model trajectory differs only in the summation order of the
cross-shard ``psum``. A mesh of size 1 is a structural no-op: callers skip the
``shard_map`` wrapping entirely and compile today's exact programs.

On this CPU container the mesh is realized with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see the CI
multi-device lane and ``tests/test_sharding.py``); on TPU the same code
shards over real chips and the ``psum`` lowers to the ICI all-reduce.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "CELL_AXIS", "CLIENT_AXIS", "cell_mesh", "client_mesh",
    "resolve_device_count", "population_device_count", "local_slice",
    "all_gather_axis", "distributed_top_k", "shard_leading", "shard_batch",
    "run_simulation_sharded",
]

# Mesh axis names. "cells" parallelizes independent sweep cells (points ×
# seeds); "clients" parallelizes the client population inside one simulation.
CELL_AXIS = "cells"
CLIENT_AXIS = "clients"


# ---------------------------------------------------------------------------
# Mesh construction / device accounting
# ---------------------------------------------------------------------------


def _mesh(n_devices: int, axis: str) -> Mesh:
    devs = jax.devices()
    if n_devices > len(devs):
        raise ValueError(
            f"requested {n_devices} devices, only {len(devs)} present "
            "(on CPU, set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.array(devs[:n_devices]), (axis,))


def cell_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D ``"cells"`` mesh over the first ``n_devices`` (default: all)."""
    return _mesh(n_devices or jax.device_count(), CELL_AXIS)


def client_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D ``"clients"`` mesh over the first ``n_devices`` (default: all)."""
    return _mesh(n_devices or jax.device_count(), CLIENT_AXIS)


def resolve_device_count(devices) -> int:
    """Normalize a ``devices`` request: None -> 1 (single-device, today's
    exact program), "auto" -> every local device, int -> min(int, present)."""
    if devices is None:
        return 1
    if devices == "auto":
        return jax.device_count()
    n = int(devices)
    if n < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    return min(n, jax.device_count())


def population_device_count(num_clients: int,
                            devices: Optional[int] = None) -> int:
    """Largest device count <= ``devices`` (default: all) dividing N evenly —
    population sharding keeps equal client shards per device."""
    n_dev = devices or jax.device_count()
    while num_clients % n_dev:
        n_dev -= 1
    return n_dev


# ---------------------------------------------------------------------------
# In-shard-map primitives
# ---------------------------------------------------------------------------


def local_slice(arr: jnp.ndarray, axis_name: str, n_local: int) -> jnp.ndarray:
    """This device's rows of a *replicated* leading-[N] array.

    The control plane draws full-N arrays on every device (identical values —
    same key, same shape); the model-sized work then runs on the local rows
    only. ``n_local`` must be static (N // mesh size)."""
    d = jax.lax.axis_index(axis_name)
    return jax.lax.dynamic_slice_in_dim(arr, d * n_local, n_local)


def all_gather_axis(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Concatenate per-shard leading axes back to the global [N] order."""
    return jax.lax.all_gather(x, axis_name, tiled=True)


def distributed_top_k(scores_local: jnp.ndarray, k: int, axis_name: str,
                      n_global: int):
    """Exact-K selection over a sharded score vector: ``(mask [N], idx [k])``.

    Local ``lax.top_k`` of min(k, n_local) candidates per shard, then a global
    ``lax.top_k`` over the gathered K·n_shards candidates. Equal to the dense
    ``lax.top_k(scores, k)`` *by construction*, tie-break pinned: within a
    shard ``lax.top_k`` emits ties lowest-index-first, and shards gather in
    index order, so the global pass also resolves ties to the lowest global
    index — exactly the dense semantics the masks were always built from.
    (A shard can contribute at most n_local elements to the true top-k, so
    min(k, n_local) candidates per shard lose nothing.)
    """
    n_local = scores_local.shape[0]
    kk = min(k, n_local)
    v, i = jax.lax.top_k(scores_local, kk)
    gi = i + jax.lax.axis_index(axis_name) * n_local
    cand_v = all_gather_axis(v, axis_name)            # [D*kk], shard order
    cand_i = all_gather_axis(gi, axis_name)
    _, pos = jax.lax.top_k(cand_v, k)
    idx = cand_i[pos]
    mask = jnp.zeros((n_global,), jnp.float32).at[idx].set(1.0)
    return mask, idx


# ---------------------------------------------------------------------------
# Host-side sharding helpers
# ---------------------------------------------------------------------------


def shard_leading(tree, mesh: Mesh, axis: Optional[str] = None):
    """``device_put`` every leaf with its leading axis split over ``mesh``."""
    axis = axis or mesh.axis_names[0]
    sh = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


def shard_batch(batch: dict, mesh: Mesh) -> dict:
    """Shard a production-tier batch dict over the clients axis.

    Leaves whose leading (example) axis divides the mesh size are split; any
    other leaf is replicated. With the canonical one-block-per-client layout
    this partitions per-client forward/backward work across devices under
    jit's SPMD partitioner — semantics are unchanged (sharding is metadata to
    XLA), it is purely a placement hint.
    """
    axis = mesh.axis_names[0]
    split = NamedSharding(mesh, P(axis))
    repl = NamedSharding(mesh, P())
    def put(x):
        arr = jnp.asarray(x)
        ok = arr.ndim >= 1 and arr.shape[0] % mesh.size == 0
        return jax.device_put(arr, split if ok else repl)
    return {k: put(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# Population-sharded simulation runner
# ---------------------------------------------------------------------------


def run_simulation_sharded(model, fl, data, mesh: Mesh, seed=None,
                           dense: bool = True):
    """Run T rounds with the client population sharded over ``mesh``.

    The whole scan runs inside one ``shard_map``: per-client data shards ride
    in split over the ``clients`` axis, the carry (global model, λ, energy,
    keys, ChanState) is replicated, and the round body is the simulator's own
    ``round_fn`` built with ``axis_name`` set (see
    ``simulator.make_param_round_fn``) — dense/GCA rounds only, the regime
    population sharding exists for. Exact-K methods run their dense reference
    program (sharded D ways); the selected-K gather path stays single-device.
    """
    from repro.core.simulator import init_sim_state, make_param_round_fn
    from repro.core.sweep import sweep_point_from_config
    from repro.utils.tree import tree_size

    axis = mesh.axis_names[0]
    n_dev = mesh.size
    if fl.num_clients % n_dev:
        raise ValueError(
            f"population sharding needs N % devices == 0, got "
            f"N={fl.num_clients}, devices={n_dev} "
            "(pick a count via population_device_count)")

    seed = fl.seed if seed is None else seed
    point = sweep_point_from_config(fl)
    state = init_sim_state(model, fl, jax.random.PRNGKey(seed),
                           process=point.process)
    model_size = tree_size(state.w)

    def run(point, state, x, y, x_test, y_test):
        # x/y/x_test/y_test arrive as this device's client rows
        round_fn = make_param_round_fn(
            model, fl, (x, y, x_test, y_test), model_size, fl.method,
            dense=dense, axis_name=axis)
        _, hist = jax.lax.scan(
            lambda s, t: round_fn(point, s, t), state, jnp.arange(fl.rounds))
        return hist

    shard_mapped = shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(), check_rep=False)
    sharded_data = tuple(shard_leading(jnp.asarray(d), mesh, axis)
                         for d in data)
    return jax.jit(shard_mapped)(point, state, *sharded_data)


def pad_to_multiple(values: Sequence[int], multiple: int) -> list[int]:
    """Pad a seed list so its length divides the cells mesh evenly; padding
    reuses existing entries (the padded columns are computed and discarded)."""
    pad = (-len(values)) % multiple
    return list(values) + [values[i % len(values)] for i in range(pad)]
