"""Client-selection strategies.

Each strategy returns a float mask of shape [N] with entries in {0, 1}
indicating the participating set D^(t). Exactly-K strategies (FedAvg, AFL,
CA-AFL, greedy) sample K clients *without replacement*; sampling from a PMF
w/o replacement is done with Gumbel-top-K, which realizes precisely the
sequential renormalized scheme analysed in the paper's Prop. 2
(Plackett-Luce). Masks are built from ``jax.lax.top_k`` indices, so exactly
K clients are selected even when scores tie (quantized/floor-clipped
channels, -inf-masked logits); a threshold comparison would over-select.

``avail`` (temporal scenarios, ``repro.core.dynamics``): clients whose
availability entry is 0 get -inf logits (or are dropped from the greedy/GCA
indicator) and the returned mask is additionally multiplied by ``avail``, so
an unavailable client is never scheduled by ANY method — even when fewer
than K clients remain available.

GCA [10] is reimplemented faithfully-in-spirit from its description in the
paper (exact indicator algebra of [10] is not reproduced in the provided
text): a composite of normalized gradient-norm benefit and channel/energy
benefit, thresholded per-client, yielding a *variable* number of scheduled
clients per round (the "unpredictability" the paper criticizes).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GCAParams
from repro.core.poe import ca_afl_logits

__all__ = ["GCAParams", "availability_logits", "gumbel_topk_mask",
           "topk_mask", "select_clients"]


def _exact_k_mask(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """0/1 mask of the top-k scores — exactly k ones, ties broken by index."""
    _, idx = jax.lax.top_k(scores, k)
    return jnp.zeros(scores.shape, jnp.float32).at[idx].set(1.0)


def availability_logits(avail: Optional[jnp.ndarray]) -> jnp.ndarray | float:
    """Additive logit mask: 0 where available, -inf where not (0.0 if None)."""
    if avail is None:
        return 0.0
    return jnp.where(avail > 0, 0.0, -jnp.inf)


def gumbel_topk_mask(key, logits: jnp.ndarray, k: int) -> jnp.ndarray:
    """Sample k items w/o replacement from softmax(logits); return 0/1 mask [N]."""
    g = jax.random.gumbel(key, logits.shape)
    return _exact_k_mask(logits + g, k)


def topk_mask(values: jnp.ndarray, k: int) -> jnp.ndarray:
    return _exact_k_mask(values, k)


def select_clients(
    method: str,
    key,
    lam: jnp.ndarray,
    h_eff: jnp.ndarray,
    k: int,
    C: float = 0.0,
    grad_norms: Optional[jnp.ndarray] = None,
    gca: GCAParams = GCAParams(),
    avail: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Return participation mask [N] for the descent step.

    ``avail`` is an optional 0/1 availability mask (temporal scenarios);
    masked-out clients are never selected. When fewer than ``k`` clients are
    available, exact-K methods schedule only the available ones.
    """
    n = lam.shape[0]
    a_logits = availability_logits(avail)

    def gate(mask):
        return mask if avail is None else mask * avail

    if method == "fedavg":
        return gate(gumbel_topk_mask(key, jnp.zeros((n,)) + a_logits, k))
    if method == "afl":
        return gate(gumbel_topk_mask(
            key, jnp.log(jnp.clip(lam, 1e-38)) + a_logits, k))
    if method == "ca_afl":
        return gate(gumbel_topk_mask(
            key, ca_afl_logits(lam, h_eff, C) + a_logits, k))
    if method == "greedy":
        # Prop. 2 limit: top-K lowest-energy == top-K best effective channel.
        return gate(topk_mask(h_eff + a_logits, k))
    if method == "gca":
        if grad_norms is None:
            raise ValueError("GCA requires per-client gradient norms")
        # In-spirit reconstruction of [10] (exact indicator algebra is not in
        # the provided text). Gradient norms enter as a *global* scheduling-
        # intensity signal (alpha-scaled, log-compressed against sigma_t) —
        # training phases with large gradients schedule more aggressively —
        # while the per-client discriminator is the channel/energy benefit.
        # This matches every property the reproduced paper ascribes to GCA:
        # gradient- and channel-aware, variable/unpredictable scheduled count,
        # energy-efficient, and NON-robust (it does not equalize clients).
        g_sq = jnp.square(grad_norms)
        g_signal = jnp.mean(
            jnp.log1p(gca.alpha * g_sq / gca.sigma_t)
            / jnp.log1p(gca.alpha * jnp.clip(jnp.max(g_sq), 1e-12) / gca.sigma_t)
        )
        h_ben = h_eff / jnp.clip(jnp.max(h_eff), 1e-12)
        indicator = gca.lambda_V * g_signal + gca.lambda_E * h_ben
        # Per-client thresholding: clients above a (mean, median) blend are
        # scheduled, plus a small sigma_t/alpha noise-floor correction. With
        # the paper's settings (rho1=rho2=0.5, sigma_t=1, alpha=1500) this
        # schedules ~42 of 100 clients on average while the exact count
        # varies per round (the "unpredictability" the paper criticizes).
        # The threshold statistics stay population-wide (GCA [10] has no
        # availability notion); unavailable clients are excluded post-hoc.
        thr = (
            gca.rho1 * jnp.mean(indicator)
            + gca.rho2 * jnp.median(indicator)
            + gca.sigma_t / gca.alpha
        )
        return gate((indicator > thr).astype(jnp.float32))
    raise ValueError(f"unknown selection method {method!r}")
