"""Client-selection strategies.

Each strategy returns a float mask of shape [N] with entries in {0, 1}
indicating the participating set D^(t). Exactly-K strategies (FedAvg, AFL,
CA-AFL, greedy) sample K clients *without replacement*; sampling from a PMF
w/o replacement is done with Gumbel-top-K, which realizes precisely the
sequential renormalized scheme analysed in the paper's Prop. 2
(Plackett-Luce). Masks are built from ``jax.lax.top_k`` indices, so exactly
K clients are selected even when scores tie (quantized/floor-clipped
channels, -inf-masked logits); a threshold comparison would over-select.

``avail`` (temporal scenarios, ``repro.core.dynamics``): clients whose
availability entry is 0 get -inf logits (or are dropped from the greedy/GCA
indicator) and the returned mask is additionally multiplied by ``avail``, so
an unavailable client is never scheduled by ANY method — even when fewer
than K clients remain available.

GCA [10] is reimplemented faithfully-in-spirit from its description in the
paper (exact indicator algebra of [10] is not reproduced in the provided
text): a composite of normalized gradient-norm benefit and channel/energy
benefit, thresholded per-client, yielding a *variable* number of scheduled
clients per round (the "unpredictability" the paper criticizes).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GCAParams
from repro.core.channel import client_keys
from repro.core.poe import ca_afl_logits

__all__ = ["GCAParams", "EXACT_K_METHODS", "availability_logits",
           "client_gumbel", "gumbel_topk_mask", "gumbel_topk", "topk_mask",
           "select_clients", "select_clients_sparse", "exact_k_scores",
           "select_clients_pop"]

# Methods whose scheduled set is bounded by a static K (lax.top_k over a
# score vector). These — and only these — can ride the simulator's sparse
# gather-compute-scatter hot path (see ROADMAP "hot-path contract"): their
# top-k *indices* are static-shape [K], so per-round model work gathers the
# K selected clients instead of masking all N. GCA's thresholding yields an
# unbounded scheduled count (can exceed clients_per_round), so it stays on
# the dense reference path.
EXACT_K_METHODS = ("fedavg", "afl", "ca_afl", "greedy")


def _exact_k(scores: jnp.ndarray, k: int):
    """(mask, idx) of the top-k scores — exactly k ones, ties broken by index.

    ``idx`` is the raw ``lax.top_k`` index vector (static shape [k], sorted by
    descending score) the sparse hot path gathers with; the mask is its
    scatter. Deriving both from ONE top_k keeps them consistent by
    construction.
    """
    _, idx = jax.lax.top_k(scores, k)
    return jnp.zeros(scores.shape, jnp.float32).at[idx].set(1.0), idx


def _exact_k_mask(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """0/1 mask of the top-k scores — exactly k ones, ties broken by index."""
    return _exact_k(scores, k)[0]


def availability_logits(avail: Optional[jnp.ndarray]) -> jnp.ndarray | float:
    """Additive logit mask: 0 where available, -inf where not (0.0 if None)."""
    if avail is None:
        return 0.0
    return jnp.where(avail > 0, 0.0, -jnp.inf)


def client_gumbel(key, ids: jnp.ndarray) -> jnp.ndarray:
    """[n] Gumbel noise content-addressed by GLOBAL client id (the
    control_plane="sharded" discipline, ``core/channel.py``): entry c is
    gumbel(fold_in(key, ids[c])), independent of which device draws it."""
    keys = client_keys(key, ids)
    return jax.vmap(lambda k: jax.random.gumbel(k, ()))(keys)


def gumbel_topk(key, logits: jnp.ndarray, k: int, ids=None):
    """Sample k items w/o replacement from softmax(logits); (mask, idx).

    ``ids``: per-client content-addressed Gumbel streams instead of one
    full-array draw (control_plane="sharded")."""
    # lint: allow(sharded-randomness): replicated-discipline branch — ids is None draws the full [N] Gumbel field in one stream
    g = jax.random.gumbel(key, logits.shape) if ids is None \
        else client_gumbel(key, ids)
    return _exact_k(logits + g, k)


def gumbel_topk_mask(key, logits: jnp.ndarray, k: int, ids=None) -> jnp.ndarray:
    """Sample k items w/o replacement from softmax(logits); return 0/1 mask [N]."""
    return gumbel_topk(key, logits, k, ids=ids)[0]


def topk_mask(values: jnp.ndarray, k: int) -> jnp.ndarray:
    return _exact_k_mask(values, k)


def select_clients(
    method: str,
    key,
    lam: jnp.ndarray,
    h_eff: jnp.ndarray,
    k: int,
    C: float = 0.0,
    grad_norms: Optional[jnp.ndarray] = None,
    gca: Optional[GCAParams] = None,
    avail: Optional[jnp.ndarray] = None,
    ids=None,
) -> jnp.ndarray:
    """Return participation mask [N] for the descent step.

    ``avail`` is an optional 0/1 availability mask (temporal scenarios);
    masked-out clients are never selected. When fewer than ``k`` clients are
    available, exact-K methods schedule only the available ones.
    """
    if gca is None:
        gca = GCAParams()

    def gate(mask):
        return mask if avail is None else mask * avail

    if method in EXACT_K_METHODS:
        return select_clients_sparse(method, key, lam, h_eff, k, C=C,
                                     avail=avail, ids=ids)[0]
    if method == "gca":
        if grad_norms is None:
            raise ValueError("GCA requires per-client gradient norms")
        # In-spirit reconstruction of [10] (exact indicator algebra is not in
        # the provided text). Gradient norms enter as a *global* scheduling-
        # intensity signal (alpha-scaled, log-compressed against sigma_t) —
        # training phases with large gradients schedule more aggressively —
        # while the per-client discriminator is the channel/energy benefit.
        # This matches every property the reproduced paper ascribes to GCA:
        # gradient- and channel-aware, variable/unpredictable scheduled count,
        # energy-efficient, and NON-robust (it does not equalize clients).
        g_sq = jnp.square(grad_norms)
        g_signal = jnp.mean(
            jnp.log1p(gca.alpha * g_sq / gca.sigma_t)
            / jnp.log1p(gca.alpha * jnp.clip(jnp.max(g_sq), 1e-12) / gca.sigma_t)
        )
        h_ben = h_eff / jnp.clip(jnp.max(h_eff), 1e-12)
        indicator = gca.lambda_V * g_signal + gca.lambda_E * h_ben
        # Per-client thresholding: clients above a (mean, median) blend are
        # scheduled, plus a small sigma_t/alpha noise-floor correction. With
        # the paper's settings (rho1=rho2=0.5, sigma_t=1, alpha=1500) this
        # schedules ~42 of 100 clients on average while the exact count
        # varies per round (the "unpredictability" the paper criticizes).
        # The threshold statistics stay population-wide (GCA [10] has no
        # availability notion); unavailable clients are excluded post-hoc.
        thr = (
            gca.rho1 * jnp.mean(indicator)
            + gca.rho2 * jnp.median(indicator)
            + gca.sigma_t / gca.alpha
        )
        return gate((indicator > thr).astype(jnp.float32))
    raise ValueError(f"unknown selection method {method!r}")


def exact_k_scores(
    method: str,
    key,
    lam: jnp.ndarray,
    h_eff: jnp.ndarray,
    C: float = 0.0,
    avail: Optional[jnp.ndarray] = None,
    ids=None,
) -> jnp.ndarray:
    """The score vector [N] whose ``lax.top_k`` IS the method's selection.

    Single source of the per-method scoring: ``select_clients_sparse`` feeds
    it to a dense ``lax.top_k``; the population-sharded path
    (:func:`select_clients_pop`) slices it per shard and runs the
    local-then-global distributed top-k — identical draws (the Gumbel noise
    consumes ``key`` exactly as before; greedy draws nothing), so the two
    paths select identically by construction.

    ``ids`` (control_plane="sharded"): the inputs hold only these clients'
    rows and the Gumbel noise is content-addressed per id
    (:func:`client_gumbel`) — score_c depends only on (key, id_c, lam_c,
    h_c), so any sharding of the population scores identically per client.
    The per-client logits are already normalizer-free (``ca_afl_logits`` is
    the *unnormalized* log of eq. (9); top-k is invariant to the softmax
    constant), so no cross-shard reduction is needed.

    This per-id independence is what lets the scoring vmap over the sweep
    engine's 2-D ``cells × clients`` mesh (ISSUE 8): each sweep cell folds
    its own key into the SAME per-id streams, so moving a client row between
    mesh columns — or adding/removing cell rows — never changes any draw.
    λ itself reaches here as local rows projected by the psum-bisection
    ``sharding.project_simplex_sharded`` under that discipline; the scores
    consume it element-wise, preserving the rule that nothing on the scoring
    path materializes an O(N) array per device.
    """
    a_logits = availability_logits(avail)
    if method == "greedy":
        # Prop. 2 limit: top-K lowest-energy == top-K best effective channel
        # — deterministic, no Gumbel draw.
        return h_eff + a_logits
    if method == "fedavg":
        logits = jnp.zeros(lam.shape) + a_logits
    elif method == "afl":
        logits = jnp.log(jnp.clip(lam, 1e-38)) + a_logits
    elif method == "ca_afl":
        logits = ca_afl_logits(lam, h_eff, C) + a_logits
    else:
        raise ValueError(
            f"sparse selection needs a static-K method, got {method!r}")
    # lint: allow(sharded-randomness): replicated-discipline branch — ids is None draws the full [N] Gumbel field in one stream
    g = jax.random.gumbel(key, logits.shape) if ids is None \
        else client_gumbel(key, ids)
    return logits + g


def select_clients_sparse(
    method: str,
    key,
    lam: jnp.ndarray,
    h_eff: jnp.ndarray,
    k: int,
    C: float = 0.0,
    avail: Optional[jnp.ndarray] = None,
    ids=None,
):
    """Exact-K selection returning ``(mask [N], idx [K])``.

    ``idx`` is the single ``lax.top_k`` index vector the masks were always
    built from — returned instead of re-derived so the simulator's hot path
    can gather the K selected clients' shards/batches and never materialize
    [N, model] work. The mask is the scatter of ``idx`` (times ``avail``):
    under availability/battery gating some of the K slots carry weight 0
    (``mask[idx]``), which is how variable-K rounds stay a static-shape
    program — zero-weight slots compute and contribute nothing to eq. (10).

    Only :data:`EXACT_K_METHODS` qualify; GCA's thresholded count is
    unbounded by ``k`` and must use the dense :func:`select_clients` path.
    """
    mask, idx = _exact_k(
        exact_k_scores(method, key, lam, h_eff, C, avail, ids=ids), k)
    if avail is not None:
        mask = mask * avail
    return mask, idx


def select_clients_pop(
    method: str,
    key,
    lam: jnp.ndarray,
    h_eff: jnp.ndarray,
    k: int,
    n_local: int,
    axis_name: str,
    C: float = 0.0,
    avail: Optional[jnp.ndarray] = None,
):
    """Population-sharded exact-K selection: ``(mask [N], idx [K])``.

    Scores are computed replicated (every [N] input is replicated under the
    clients mesh — see ``core/sharding.py``), each shard top-k's its own
    rows, and the global winner set comes from a second top-k over the
    gathered candidates (``sharding.distributed_top_k``) — equal to
    :func:`select_clients_sparse` by construction, ties included.
    """
    from repro.core.sharding import distributed_top_k, local_slice

    scores = exact_k_scores(method, key, lam, h_eff, C, avail)
    mask, idx = distributed_top_k(
        local_slice(scores, axis_name, n_local), k, axis_name,
        n_global=scores.shape[0])
    if avail is not None:
        mask = mask * avail
    return mask, idx
