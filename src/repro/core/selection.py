"""Client-selection strategies.

Each strategy returns a float mask of shape [N] with entries in {0, 1}
indicating the participating set D^(t). Exactly-K strategies (FedAvg, AFL,
CA-AFL, greedy) sample K clients *without replacement*; sampling from a PMF
w/o replacement is done with Gumbel-top-K, which realizes precisely the
sequential renormalized scheme analysed in the paper's Prop. 2
(Plackett-Luce).

GCA [10] is reimplemented faithfully-in-spirit from its description in the
paper (exact indicator algebra of [10] is not reproduced in the provided
text): a composite of normalized gradient-norm benefit and channel/energy
benefit, thresholded per-client, yielding a *variable* number of scheduled
clients per round (the "unpredictability" the paper criticizes).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GCAParams
from repro.core.poe import ca_afl_logits

__all__ = ["GCAParams", "gumbel_topk_mask", "topk_mask", "select_clients"]


def gumbel_topk_mask(key, logits: jnp.ndarray, k: int) -> jnp.ndarray:
    """Sample k items w/o replacement from softmax(logits); return 0/1 mask [N]."""
    g = jax.random.gumbel(key, logits.shape)
    scores = logits + g
    thresh = jnp.sort(scores)[-k]
    return (scores >= thresh).astype(jnp.float32)


def topk_mask(values: jnp.ndarray, k: int) -> jnp.ndarray:
    thresh = jnp.sort(values)[-k]
    return (values >= thresh).astype(jnp.float32)


def select_clients(
    method: str,
    key,
    lam: jnp.ndarray,
    h_eff: jnp.ndarray,
    k: int,
    C: float = 0.0,
    grad_norms: Optional[jnp.ndarray] = None,
    gca: GCAParams = GCAParams(),
) -> jnp.ndarray:
    """Return participation mask [N] for the descent step."""
    n = lam.shape[0]
    if method == "fedavg":
        logits = jnp.zeros((n,))
        return gumbel_topk_mask(key, logits, k)
    if method == "afl":
        return gumbel_topk_mask(key, jnp.log(jnp.clip(lam, 1e-38)), k)
    if method == "ca_afl":
        return gumbel_topk_mask(key, ca_afl_logits(lam, h_eff, C), k)
    if method == "greedy":
        # Prop. 2 limit: top-K lowest-energy == top-K best effective channel.
        return topk_mask(h_eff, k)
    if method == "gca":
        if grad_norms is None:
            raise ValueError("GCA requires per-client gradient norms")
        # In-spirit reconstruction of [10] (exact indicator algebra is not in
        # the provided text). Gradient norms enter as a *global* scheduling-
        # intensity signal (alpha-scaled, log-compressed against sigma_t) —
        # training phases with large gradients schedule more aggressively —
        # while the per-client discriminator is the channel/energy benefit.
        # This matches every property the reproduced paper ascribes to GCA:
        # gradient- and channel-aware, variable/unpredictable scheduled count,
        # energy-efficient, and NON-robust (it does not equalize clients).
        g_sq = jnp.square(grad_norms)
        g_signal = jnp.mean(
            jnp.log1p(gca.alpha * g_sq / gca.sigma_t)
            / jnp.log1p(gca.alpha * jnp.clip(jnp.max(g_sq), 1e-12) / gca.sigma_t)
        )
        h_ben = h_eff / jnp.clip(jnp.max(h_eff), 1e-12)
        indicator = gca.lambda_V * g_signal + gca.lambda_E * h_ben
        # Per-client thresholding: clients above a (mean, median) blend are
        # scheduled, plus a small sigma_t/alpha noise-floor correction. With
        # the paper's settings (rho1=rho2=0.5, sigma_t=1, alpha=1500) this
        # schedules ~42 of 100 clients on average while the exact count
        # varies per round (the "unpredictability" the paper criticizes).
        thr = (
            gca.rho1 * jnp.mean(indicator)
            + gca.rho2 * jnp.median(indicator)
            + gca.sigma_t / gca.alpha
        )
        return (indicator > thr).astype(jnp.float32)
    raise ValueError(f"unknown selection method {method!r}")
