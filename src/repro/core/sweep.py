"""Batched scenario-sweep engine: one jit for a whole (seeds × scenarios ×
hyperparameter) grid.

The paper's claims are averages over seeds and comparisons across selection
methods and channel conditions. Running that grid through
``run_simulation`` costs one compilation *per cell*; this engine instead
partitions the grid by its *structural* signature (anything that changes the
traced program: N, K, T, batch size, sub-carriers, flat-vs-selective fading
and the selection method) and runs each group as

    jit( vmap_points( vmap_seeds( lax.scan(round_fn) ) ) )

so every scalar knob — learning rates, ``energy_C``, GCA hyperparameters,
channel floor/noise/shadowing/pathloss — rides a ``vmap`` axis of a single
compiled executable. A five-seed × {FedAvg, AFL, GCA, CA-AFL(C=2), CA-AFL
(C=8)} comparison compiles 4 executables instead of 25.

Usage::

    specs  = expand_grid(base_fl, variants={"afl": {"method": "afl"},
                                            "c8": {"method": "ca_afl",
                                                   "energy_C": 8.0}},
                         scenarios=("default", "noisy_uplink"))
    result = run_sweep(model, data, specs, seeds=(0, 1, 2, 3, 4))
    result.summary()          # per-label mean/std/worst-case across seeds
    result.pareto_front()     # energy-vs-robustness Pareto extraction

Compilations are observable via ``trace_count()`` (a Python side effect at
trace time), which the test suite uses to pin "one compile per method".
"""
from __future__ import annotations

import json
from dataclasses import dataclass, replace
from typing import Any, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.configs.base import FLConfig, GCAParams
from repro.core import sharding
from repro.core.channel import SCENARIOS, scenario_from_config
from repro.core.dynamics import ChannelProcess, process_from_config
from repro.core.transport import TransportParams, transport_from_config
from repro.core.simulator import (SimHistory, init_sim_state,
                                  make_param_round_fn)
from repro.utils.tree import tree_size

__all__ = [
    "SweepPoint", "SweepResult", "sweep_point_from_config", "expand_grid",
    "run_sweep", "trace_count", "reset_trace_log", "pareto_indices",
]


# ---------------------------------------------------------------------------
# Sweep points: the traced per-cell knobs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SweepPoint:
    """All per-cell knobs the round function consumes as traced values.

    ``method`` is pytree metadata (it selects Python branches); the scenario's
    own ``flat`` flag is metadata inside the nested ``ChannelScenario``.
    Points whose metadata differ cannot share a vmap axis — ``run_sweep``
    groups them into separate compilations.
    """

    scenario: Any              # ChannelScenario (data: traced; meta: flat)
    lr0: Any = 0.1
    lr_decay: Any = 0.998
    ascent_lr: Any = 8e-3
    energy_C: Any = 8.0
    gca: Any = GCAParams()     # NamedTuple of (possibly traced) scalars
    process: Any = ChannelProcess()  # temporal dynamics (meta: temporal)
    transport: Any = TransportParams()  # uplink transport (meta: scheme)
    method: str = "ca_afl"


jax.tree_util.register_dataclass(
    SweepPoint,
    data_fields=["scenario", "lr0", "lr_decay", "ascent_lr", "energy_C", "gca",
                 "process", "transport"],
    meta_fields=["method"],
)


def sweep_point_from_config(fl: FLConfig) -> SweepPoint:
    """Promote an ``FLConfig``'s scalar knobs to f32 arrays (vmap-stackable)."""
    f32 = lambda v: jnp.asarray(v, jnp.float32)  # noqa: E731
    return SweepPoint(
        scenario=scenario_from_config(fl),
        lr0=f32(fl.lr0),
        lr_decay=f32(fl.lr_decay),
        ascent_lr=f32(fl.ascent_lr),
        energy_C=f32(fl.energy_C),
        gca=GCAParams(*(f32(v) for v in fl.gca)),
        process=process_from_config(fl),
        transport=transport_from_config(fl),
        method=fl.method,
    )


# Structural FLConfig fields: changing any of these changes the traced
# program, so specs are grouped by this signature (one compile per group).
# `temporal` switches the stateless draw for the ChannelProcess carry
# (core/dynamics.py): all dynamic scenarios share one group per method, and
# the i.i.d. default keeps compiling to exactly PR 1's program. `eval_every`
# changes the metrics sub-program (per-round eval vs cond-gated cadence +
# eval_cache carry), so cells with different cadences cannot share an
# executable — cells with the SAME cadence still do. `transport` selects the
# uplink aggregation/energy program (core/transport.py): each scheme is its
# own group per method, every scheme KNOB (bits, powers, bandwidth) stays
# traced, and "analog" compiles to exactly the pre-transport program.
# `control_plane` selects the per-client randomness discipline (replicated
# full-[N] draws vs per-id fold_in streams + slot assembly, core/simulator.py)
# — two different programs with different key consumption.
# `record_lambda_every` changes the λ-history sub-program (per-round scan
# output vs cond-gated strided snapshot carry vs no history leaf at all), so
# cells with different cadences cannot share an executable.
# `sparse_density` is structural FOR THE SPARSE SCHEME ONLY: it bakes the
# compiled top-k width (`transport.sparse_k_coords`); the other schemes never
# read it, but keeping it in the signature unconditionally is harmless (cells
# that differ only in an unread knob are rare) and keeps the grouping rule
# free of scheme-conditional logic.
STATIC_FIELDS: Tuple[str, ...] = (
    "num_clients", "clients_per_round", "rounds", "batch_size", "local_steps",
    "num_subcarriers", "flat_fading", "temporal", "eval_every", "transport",
    "sparse_density", "method", "control_plane", "record_lambda_every",
)


def _static_signature(fl: FLConfig) -> Tuple:
    return tuple(getattr(fl, f) for f in STATIC_FIELDS)


# ---------------------------------------------------------------------------
# Grid expansion: variants × named scenarios -> labelled FLConfigs
# ---------------------------------------------------------------------------


def expand_grid(
    base: FLConfig,
    variants: Optional[Mapping[str, Mapping[str, Any]]] = None,
    scenarios: Sequence[Any] = ("default",),
) -> list[Tuple[str, FLConfig]]:
    """Cross method/hyperparameter ``variants`` with channel ``scenarios``.

    ``variants`` maps label -> FLConfig field overrides; ``scenarios`` entries
    are names from :data:`repro.core.channel.SCENARIOS`, raw override dicts
    (labelled by their contents, e.g. ``noise_std=0.01``), or explicit
    ``(name, overrides)`` pairs. Returns ``[(label, config), ...]`` ready for
    :func:`run_sweep`.
    """
    variants = dict(variants or {"base": {}})
    specs = []
    for sc in scenarios:
        if isinstance(sc, str):
            sc_name, sc_kw = sc, SCENARIOS[sc]
        elif isinstance(sc, tuple):
            sc_name, sc_kw = sc[0], dict(sc[1])
        else:
            sc_kw = dict(sc)
            sc_name = ",".join(f"{k}={v:g}" if isinstance(v, float) else
                               f"{k}={v}" for k, v in sc_kw.items()) or "default"
        # only the true baseline (no overrides) drops the @suffix — an explicit
        # ("default", {...}) pair with overrides keeps its label distinct
        baseline = sc_name == "default" and not sc_kw
        for vlabel, vkw in variants.items():
            label = vlabel if baseline else f"{vlabel}@{sc_name}"
            specs.append((label, replace(base, **{**sc_kw, **vkw})))
    return specs


# ---------------------------------------------------------------------------
# Compilation accounting (used by tests and the CI benchmark smoke)
# ---------------------------------------------------------------------------

_TRACE_LOG: list[str] = []


def trace_count() -> int:
    """Number of sweep-executable compilations since the last reset."""
    return len(_TRACE_LOG)


def reset_trace_log() -> None:
    _TRACE_LOG.clear()


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


def _stack_points(points: Sequence[SweepPoint]) -> SweepPoint:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *points)


def _build_runner(model, fl_static: FLConfig, data, method: str,
                  noise_free: bool, model_size: int, mesh=None):
    """Two jitted executables: an initializer ``(points [S], seeds [R]) ->
    SimState`` stack with leading [S, R] axes, and the runner ``(points,
    states) -> (final states, SimHistory)``.

    The initial-state stack is built OUTSIDE the runner and donated into it
    (``donate_argnums``): the scan carry then reuses the caller's buffers
    in-place instead of holding both generations of [S, R, model] state live
    — returning the final states (same shapes) is what gives XLA the
    input→output aliasing that makes the donation effective (and warning-
    free, which ``tests/test_sweep.py`` asserts).

    ``mesh`` (sweep-cell sharding, ``core/sharding.py``): both executables
    are wrapped in ``shard_map`` splitting the SEED axis over the ``cells``
    mesh — each device initializes and scans its own [S, R/D] block of
    fully-independent cells, so results are bit-identical to the
    single-device program (no cross-cell reduction exists anywhere).
    ``mesh=None`` / size 1 skips the wrapping entirely: today's exact
    programs.
    """
    round_fn = make_param_round_fn(model, fl_static, data, model_size, method,
                                   noise_free=noise_free)

    def init_one(point, seed):
        # the point's process carries the traced battery_init for ChanState
        return init_sim_state(model, fl_static, jax.random.PRNGKey(seed),
                              process=point.process)

    def init_batched(points, seeds):
        over_seeds = jax.vmap(init_one, in_axes=(None, 0))
        return jax.vmap(over_seeds, in_axes=(0, None))(points, seeds)

    def run_one(point, state):
        final, hist = jax.lax.scan(
            lambda s, t: round_fn(point, s, t), state,
            jnp.arange(fl_static.rounds))
        if fl_static.record_lambda_every > 1:
            # strided λ snapshots ride the scan carry (lax.scan cannot emit
            # [T/E] stacks); attach the final buffer as the history's λ leaf
            hist = hist._replace(lam=final.lam_snaps)
        return final, hist

    def batched(points, states):
        # Python side effect: runs once per *compilation* (trace), never on
        # cached executions — this is the compile counter the tests assert on.
        _TRACE_LOG.append(method)
        over_seeds = jax.vmap(run_one, in_axes=(None, 0))
        return jax.vmap(over_seeds, in_axes=(0, 0))(points, states)

    if mesh is not None and mesh.size > 1:
        P = PartitionSpec
        cell = mesh.axis_names[0]
        # points [S, ...] replicated; states/histories [S, R, ...] split on
        # the seed axis. R % mesh.size == 0 is guaranteed by run_sweep's
        # seed padding.
        init_batched = shard_map(init_batched, mesh=mesh,
                                 in_specs=(P(), P(cell)),
                                 out_specs=P(None, cell), check_rep=False)
        batched = shard_map(batched, mesh=mesh,
                            in_specs=(P(), P(None, cell)),
                            out_specs=(P(None, cell), P(None, cell)),
                            check_rep=False)
    return jax.jit(init_batched), jax.jit(batched, donate_argnums=(1,))


def _build_sharded_group_runner(model, fl_static: FLConfig, method: str,
                                mesh, noise_free: bool, model_size: int):
    """One jitted executable for a ``control_plane="sharded"`` group on the
    2-D ``cells × clients`` mesh (ISSUE 8): ``fn(points [S], seeds [R],
    *sharded_data) -> SimHistory`` with leading [S, R] axes.

    The per-cell body is ``sharding.control_sharded_cell_run`` — the SAME
    function the 1-D client-mesh runner shard_maps — vmapped over stacked
    points × seeds inside ``shard_map``: the seed axis splits over the
    ``cells`` mesh rows while every client-row collective (psum-bisection
    projection, hierarchical top-k, ownership-psum assembly, eq. (10)) runs
    on the ``clients`` columns and vmaps over the cell batch unchanged. The
    state is initialized INSIDE the body (λ/ChanState born as local rows),
    so no [N]-sized array exists per device at any point — there is no
    donated init stack to build, unlike :func:`_build_runner`.
    """
    P = PartitionSpec
    cell_ax, client_ax = mesh.axis_names
    n_client_dev = mesh.shape[client_ax]
    n_local = fl_static.num_clients // n_client_dev
    cell_run = sharding.control_sharded_cell_run(
        model, fl_static, method, client_ax, n_local, model_size,
        noise_free=noise_free)

    def run_cells(points, seeds, x, y, x_test, y_test):
        # same compile-counter side effect as _build_runner.batched
        _TRACE_LOG.append(method)

        def one(point, seed):
            return cell_run(point, jax.random.PRNGKey(seed),
                            x, y, x_test, y_test)

        over_seeds = jax.vmap(one, in_axes=(None, 0))
        return jax.vmap(over_seeds, in_axes=(0, None))(points, seeds)

    mapped = shard_map(
        run_cells, mesh=mesh,
        in_specs=(P(), P(cell_ax), P(client_ax), P(client_ax), P(client_ax),
                  P(client_ax)),
        out_specs=sharding.control_sharded_history_specs(
            fl_static, client_ax, lead=(None, cell_ax)),
        check_rep=False)
    return jax.jit(mapped)


def _grid_fingerprint(specs, seeds) -> np.ndarray:
    """A [32] uint8 digest of the full grid — labels, every config field
    (traced knobs included), seed list and order. Stored inside the resume
    checkpoint so a rerun whose grid differs in ANY way (reordered specs, a
    changed learning rate under the same label, different seeds) fails
    loudly instead of resuming stale or misattributed histories; the 'done'
    flags are positional and only safe under an identical grid."""
    import hashlib

    desc = repr([(lbl, fl) for lbl, fl in specs]) + repr(tuple(seeds))
    return np.frombuffer(hashlib.sha256(desc.encode()).digest(), np.uint8)


def _history_template(fl: FLConfig, num_seeds: int) -> SimHistory:
    """Zero-filled [R, T(, N)] SimHistory with the shapes/dtypes run_sweep
    produces — the restore template of the checkpoint resume hook."""
    r, t, n = num_seeds, fl.rounds, fl.num_clients
    e = fl.record_lambda_every
    z = lambda *shape: np.zeros(shape, np.float32)  # noqa: E731
    lam = () if e == 0 else (z(r, t, n) if e == 1
                             else z(r, (t + e - 1) // e, n))
    return SimHistory(avg_acc=z(r, t), worst_acc=z(r, t), std_acc=z(r, t),
                      energy=z(r, t), loss=z(r, t), num_scheduled=z(r, t),
                      lam=lam, avail_count=z(r, t),
                      min_battery=z(r, t), lam_max=z(r, t),
                      lam_entropy=z(r, t), lam_ess=z(r, t),
                      dl_energy=z(r, t))


def run_sweep(
    model,
    data,
    specs: Sequence[Tuple[str, FLConfig]],
    seeds: Sequence[int] = (0,),
    devices=None,
    client_devices: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
) -> "SweepResult":
    """Run every (spec × seed) cell; one compilation per structural group.

    ``specs`` is ``[(label, FLConfig), ...]`` (see :func:`expand_grid`).
    Returns a :class:`SweepResult` whose per-label histories have a leading
    seed axis [R] on every leaf.

    ``devices`` shards the grid's seed axis over a ``cells`` device mesh
    (``None`` = single device, today's exact program; ``"auto"`` = every
    local device; an int caps the count). Cells are independent, so the
    sharded sweep is bit-identical to the unsharded one — the seed list is
    padded up to a multiple of the mesh size internally and the padding
    columns discarded.

    ``client_devices`` (``control_plane="sharded"`` groups only) factors the
    device count into a 2-D ``cells × clients`` mesh: each group runs with
    its seed axis split over ``devices / client_devices`` mesh rows and its
    client population split over ``client_devices`` columns
    (:func:`sharding.cells_clients_mesh`). ``None`` auto-picks the largest
    divisor of the device count that divides N (1 — a pure cells mesh — when
    none fits or the group is replicated-discipline). The 2-D run is
    differential-pinned against the 1-D and single-device paths: discrete
    fields exact, continuous to ulps (``tests/test_control_sharded.py``).

    ``checkpoint_dir`` (opt-in resume for long grids): after each
    compilation group completes, the per-label histories land in a
    ``repro.checkpoint`` msgpack checkpoint; a rerun with the same specs,
    seeds and directory restores the finished groups and computes only the
    rest. Shape validation comes from the fixed restore template, so a
    changed grid (different seeds/rounds/N) fails loudly instead of
    resuming garbage.
    """
    labels = [lbl for lbl, _ in specs]
    if len(set(labels)) != len(labels):
        raise ValueError(f"duplicate sweep labels: {labels}")

    n_dev = sharding.resolve_device_count(devices)
    mesh = sharding.cell_mesh(n_dev) if n_dev > 1 else None
    num_seeds = len(tuple(seeds))
    run_seeds = (sharding.pad_to_multiple(list(seeds), n_dev)
                 if n_dev > 1 else list(seeds))
    seeds_arr = jnp.asarray(tuple(run_seeds), jnp.int32)

    groups: dict[Tuple, list[int]] = {}
    for i, (_, fl) in enumerate(specs):
        groups.setdefault(_static_signature(fl), []).append(i)

    # ---- checkpoint resume hook (opt-in) -------------------------------
    done = np.zeros((len(specs),), np.float32)
    ckpt_template = None
    if checkpoint_dir is not None:
        from repro.checkpoint.ckpt import (latest_step, restore_checkpoint,
                                           save_checkpoint)
        ckpt_template = {
            "done": np.zeros((len(specs),), np.float32),
            "grid": _grid_fingerprint(specs, seeds),
            "hist": {lbl: _history_template(fl, num_seeds)
                     for lbl, fl in specs},
        }

    histories: list[Optional[SimHistory]] = [None] * len(specs)
    if checkpoint_dir is not None and latest_step(checkpoint_dir) is not None:
        restored = restore_checkpoint(checkpoint_dir, ckpt_template)
        if not np.array_equal(np.asarray(restored["grid"]),
                              ckpt_template["grid"]):
            raise ValueError(
                f"checkpoint in {checkpoint_dir} was written by a different "
                "sweep grid (labels/configs/seeds changed or reordered) — "
                "resuming would misattribute histories; point "
                "checkpoint_dir elsewhere or delete the stale checkpoint")
        done = np.asarray(restored["done"]).copy()
        for i, lbl in enumerate(labels):
            if done[i]:
                histories[i] = restored["hist"][lbl]

    model_size = tree_size(model.init(jax.random.PRNGKey(0)))
    groups_done = sum(
        1 for idxs in groups.values() if all(done[i] for i in idxs))
    for idxs in groups.values():
        if all(done[i] for i in idxs):
            continue  # restored from the checkpoint
        fl0 = specs[idxs[0]][1]
        points = _stack_points(
            [sweep_point_from_config(specs[i][1]) for i in idxs])
        # elide the eq.-(10) noise draw only if the whole group is noise-free
        noise_free = all(specs[i][1].noise_std == 0 for i in idxs)
        d_clients = 1
        if n_dev > 1 and fl0.control_plane == "sharded":
            d_clients = sharding.factor_client_devices(
                fl0.num_clients, n_dev, client_devices)
        if d_clients > 1:
            # 2-D cells × clients mesh: seeds split over the rows, client
            # rows over the columns. The global seed padding to n_dev is a
            # multiple of the cells dimension (d_cells divides n_dev).
            mesh2 = sharding.cells_clients_mesh(n_dev, d_clients)
            runner = _build_sharded_group_runner(
                model, fl0, fl0.method, mesh2, noise_free, model_size)
            sharded_data = tuple(
                sharding.shard_leading(jnp.asarray(d), mesh2,
                                       mesh2.axis_names[1]) for d in data)
            hist = runner(points, seeds_arr, *sharded_data)
        else:
            init_fn, runner = _build_runner(model, fl0, data, fl0.method,
                                            noise_free, model_size, mesh=mesh)
            states = init_fn(points, seeds_arr)  # leaves [S_group, R_pad, ..]
            # final states are discarded; returning them is what lets XLA
            # alias the donated inputs (see _build_runner)
            _, hist = runner(points, states)  # leaves [S_group, R_pad, T, ..]
        for s, i in enumerate(idxs):
            # drop the seed-padding columns of a sharded run
            histories[i] = jax.tree.map(lambda x, s=s: x[s, :num_seeds], hist)
            done[i] = 1.0
        if checkpoint_dir is not None:
            groups_done += 1
            tree = {
                "done": done,
                "grid": ckpt_template["grid"],
                "hist": {lbl: (histories[i] if done[i] else
                               ckpt_template["hist"][lbl])
                         for i, (lbl, _) in enumerate(specs)},
            }
            save_checkpoint(checkpoint_dir, groups_done, tree, keep=1)

    return SweepResult(
        labels=labels,
        configs=[fl for _, fl in specs],
        seeds=tuple(int(s) for s in seeds),
        histories=histories,
    )


# ---------------------------------------------------------------------------
# Aggregation: seed statistics + energy/robustness Pareto extraction
# ---------------------------------------------------------------------------


def pareto_indices(costs: np.ndarray, utilities: np.ndarray) -> list[int]:
    """Indices on the (minimize cost, maximize utility) Pareto frontier."""
    keep = []
    for i in range(len(costs)):
        dominated = np.any(
            (costs <= costs[i]) & (utilities >= utilities[i])
            & ((costs < costs[i]) | (utilities > utilities[i])))
        if not dominated:
            keep.append(i)
    return sorted(keep, key=lambda i: costs[i])


@dataclass
class SweepResult:
    """Sweep output: per-label seed-batched histories + aggregation helpers."""

    labels: list[str]
    configs: list[FLConfig]
    seeds: Tuple[int, ...]
    histories: list[SimHistory]  # leaves [R, T, ...] per label

    def __post_init__(self):
        self._by_label = {lbl: i for i, lbl in enumerate(self.labels)}

    def history(self, label: str) -> SimHistory:
        """Per-seed history for one label (leaves [R, T, ...])."""
        return self.histories[self._by_label[label]]

    def mean_history(self, label: str) -> SimHistory:
        """Seed-averaged history (leaves [T, ...]); == old run_multi_seed."""
        return jax.tree.map(lambda x: x.mean(0), self.history(label))

    def summary(self, window: int = 10) -> dict:
        """Per-label statistics over the final ``window`` *evaluated* rounds.

        mean/std across seeds for avg/worst accuracy, the worst-case (min
        over seeds) worst-client accuracy, and final cumulative energy.

        Under ``eval_every = E > 1`` the accuracy columns between evals are
        forward-filled copies of the last eval; a naive tail window would
        count each fresh eval up to E times and bias the statistic toward
        whichever eval happens to sit closest to the end. The accuracy
        window therefore ranges over the label's actual eval rounds
        (``t % E == 0``) only — at E=1 that is exactly the old behavior,
        and an E>1 summary equals the E=1 summary computed on the
        subsampled eval cadence. Per-round quantities (scheduled counts,
        availability) are genuine every round and keep the plain tail
        window.

        λ-derived statistics follow the same rule on the
        ``record_lambda_every`` cadence (the same forward-fill/aliasing bug
        class): when the dense/strided λ history is recorded, the window
        ranges over the last ``window`` *recorded* rows — an E>1 summary
        equals the E=1 summary subsampled onto the recording cadence
        (test-pinned). At E=0 (no λ history) the columns fall back to the
        always-on per-round summary leaves (max / entropy / effective
        support size), whose tail window is genuine every round.
        """
        out = {}
        for lbl in self.labels:
            h = self.history(lbl)
            cfg = self.configs[self._by_label[lbl]]
            rounds = np.asarray(h.avg_acc).shape[1]
            eval_idx = np.arange(0, rounds, max(1, cfg.eval_every))[-window:]
            avg = np.asarray(h.avg_acc)[:, eval_idx].mean(1)     # [R]
            worst = np.asarray(h.worst_acc)[:, eval_idx].mean(1)  # [R]
            std = np.asarray(h.std_acc)[:, eval_idx].mean(1)     # [R]
            energy = np.asarray(h.energy)[:, -1]                 # [R]
            dl_energy = np.asarray(h.dl_energy)[:, -1]           # [R]
            sched = np.asarray(h.num_scheduled)[:, -window:].mean(1)  # [R]
            avail = np.asarray(h.avail_count)[:, -window:].mean(1)    # [R]
            min_batt = float(np.asarray(h.min_battery)[:, -1].mean())
            lam = np.asarray(h.lam) if not isinstance(h.lam, tuple) else None
            if lam is not None and lam.size:
                # window over the last `window` RECORDED rows ([R, T/E, N]) —
                # never over forward-filled round indices
                la = lam[:, -window:, :]
                lam_max = la.max(-1).mean(1)                          # [R]
                plogp = la * np.log(np.where(la > 0, la, 1.0))
                lam_entropy = (-plogp.sum(-1)).mean(1)                # [R]
                lam_ess = (1.0 / np.maximum(
                    (la ** 2).sum(-1), np.finfo(la.dtype).tiny)).mean(1)
            else:
                # E=0: no λ history — the per-round summary leaves are the
                # only λ record and their tail is genuine every round
                lam_max = np.asarray(h.lam_max)[:, -window:].mean(1)
                lam_entropy = np.asarray(h.lam_entropy)[:, -window:].mean(1)
                lam_ess = np.asarray(h.lam_ess)[:, -window:].mean(1)
            out[lbl] = {
                "avg_acc": float(avg.mean()),
                "avg_acc_std": float(avg.std()),
                "worst_acc": float(worst.mean()),
                "worst_acc_std": float(worst.std()),
                "worst_case_acc": float(worst.min()),
                "client_std": float(std.mean()),
                "energy": float(energy.mean()),
                "energy_std": float(energy.std()),
                # downlink share of the TOTAL `energy` column (additive; 0
                # at the default dl_rx_power=0)
                "dl_energy": float(dl_energy.mean()),
                "num_scheduled": float(sched.mean()),
                "avail_count": float(avail.mean()),
                # None (JSON null) for static scenarios, where it is +inf
                "min_battery": min_batt if np.isfinite(min_batt) else None,
                "lam_max": float(lam_max.mean()),
                "lam_entropy": float(lam_entropy.mean()),
                "lam_ess": float(lam_ess.mean()),
            }
        return out

    def pareto_front(self, window: int = 10, cost: str = "energy",
                     utility: str = "worst_acc") -> list[str]:
        """Labels on the energy-vs-robustness Pareto frontier."""
        s = self.summary(window)
        costs = np.array([s[lbl][cost] for lbl in self.labels])
        utils = np.array([s[lbl][utility] for lbl in self.labels])
        return [self.labels[i] for i in pareto_indices(costs, utils)]

    def to_dict(self, window: int = 10) -> dict:
        return {
            "labels": self.labels,
            "seeds": list(self.seeds),
            "summary": self.summary(window),
            "pareto_energy_vs_worst_acc": self.pareto_front(window),
        }

    def save_json(self, path, window: int = 10, extra: Optional[dict] = None):
        payload = self.to_dict(window)
        if extra:
            payload.update(extra)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        return payload
