"""Pluggable uplink-transport layer: analog / quantized / digital-OFDMA.

The paper's headline claim — 3×+ energy savings — is a claim about
*transmission schemes*, yet until this layer every "baseline" in the repo
rode the same analog AirComp uplink. This module makes the uplink a
first-class, sweepable axis with three schemes:

  - ``"analog"`` — the paper's eq. (10) channel-inversion AirComp. This is
    the pre-existing program, byte-for-byte: the analog branches below
    delegate to the exact functions the simulator always called, with the
    same key consumption, so ``transport="analog"`` trajectories are
    bit-identical to the pre-transport repo (pinned by
    ``tests/test_transport.py``).
  - ``"quantized"`` — Li et al. (arXiv:2208.07237)-style high-precision
    AirComp: each client stochastically rounds its model *update*
    Δ_i = w_i − w̄ to ``bits`` bits (per-client scale), the quantized deltas
    superpose over the air (same AWGN discipline as analog), and the PS
    reconstructs w̄ + (Σ mask·Q(Δ_i) + σz)/K. Fewer bits mean fewer analog
    symbols per parameter, so upload energy scales by ``bits/32`` relative
    to analog — the energy/aggregation-error trade-off the scheme exists
    for. The quantize-scale-sum-noise-normalize pass is fused
    (``repro.kernels.aircomp``: Pallas kernel on TPU, jnp elsewhere).
  - ``"digital"`` — Sun et al. (arXiv:2106.00490)-style orthogonal OFDMA
    uplink: each scheduled client gets its own ``bandwidth`` subband and
    transmits at ``tx_power``; its rate is Shannon's
    B·log2(1 + P·|h|²/N₀), the symbol-time latency is M·32/rate (the PS
    decodes the EXACT f32 update, so the payload is priced at full
    precision — ``bits`` is the quantized scheme's knob), and the upload
    energy is P × latency. Error-free decode means aggregation is the plain
    masked weighted mean with NO superposition noise — the
    clean-but-costly comparison point.

Contract (the "Transport contract" section of the README has the long
form): the *scheme* is structural — ``FLConfig.transport`` joins
``sweep.STATIC_FIELDS``, so each scheme compiles its own program and the
analog program is exactly the pre-transport one. Every scheme *knob*
(``bits``, ``tx_power``, ``bandwidth``, ``rx_noise``) is a traced data
field of :class:`TransportParams` riding the sweep's vmap axis — a whole
bits-grid or power-grid sweeps under ONE compilation per scheme.

Key discipline: quantization randomness derives from
``fold_in(k_noise, _QUANT_STREAM)`` folded again with each client's GLOBAL
index — content-addressed per-client streams, so the dense [N], the
gathered sparse [K] and the population-sharded paths draw bit-identical
per-client uniforms (the same trick the control plane uses for replicated
[N] draws). The AWGN keeps the per-leaf discipline of
``aircomp_aggregate_tree`` on every path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.aircomp import flat_awgn, stack_accum_dtype
from repro.core.energy import TRUNCATION_FLOOR, transmit_energy
from repro.kernels.aircomp.ops import quant_aircomp_flat

__all__ = [
    "TRANSPORTS", "ANALOG_BITS", "TransportParams", "transport_from_config",
    "quant_step", "quantize_rows", "uplink_energy", "round_energy",
    "digital_rate", "digital_latency", "digital_energy",
    "quantized_aggregate_stack_tree", "quantized_aggregate_psum_tree",
    "quantized_aggregate_flat_rows", "flat_awgn_like",
]

TRANSPORTS = ("analog", "quantized", "digital")

# the analog scheme's implicit payload precision: one f32 symbol stream per
# parameter. Quantized airtime (hence energy) scales by bits/ANALOG_BITS.
ANALOG_BITS = 32.0

# fold_in stream of the round's k_noise reserved for quantization uniforms
# (k_chan owns streams 1-3 in core/dynamics.py; this is a different key, the
# constant just keeps the reservation greppable).
_QUANT_STREAM = 7


@dataclass(frozen=True)
class TransportParams:
    """Per-scheme knobs: traced data + the structural ``scheme`` metadata.

    Data fields accept Python floats or (possibly vmapped) jnp scalars, like
    every other sweep knob; ``scheme`` is pytree metadata, so points with
    different schemes land in different sweep compilation groups (the same
    contract ``ChannelScenario.flat`` and ``ChannelProcess.temporal`` use).
    """

    bits: Any = 8.0        # payload precision, bits per model parameter
    tx_power: Any = 0.1    # digital uplink transmit power P (W)
    bandwidth: Any = 1e5   # digital per-client OFDMA subband B (Hz)
    rx_noise: Any = 1e-2   # digital receiver noise+interference power N0 (W)
    scheme: str = "analog"


jax.tree_util.register_dataclass(
    TransportParams,
    data_fields=["bits", "tx_power", "bandwidth", "rx_noise"],
    meta_fields=["scheme"],
)


def transport_from_config(fl: FLConfig) -> TransportParams:
    """Promote the ``FLConfig`` transport knobs to f32 traced scalars."""
    if fl.transport not in TRANSPORTS:
        raise ValueError(
            f"unknown transport {fl.transport!r}; pick one of {TRANSPORTS}")
    f32 = lambda v: jnp.asarray(v, jnp.float32)  # noqa: E731
    return TransportParams(
        bits=f32(fl.quant_bits),
        tx_power=f32(fl.tx_power),
        bandwidth=f32(fl.ofdma_bandwidth),
        rx_noise=f32(fl.rx_noise),
        scheme=fl.transport,
    )


# ---------------------------------------------------------------------------
# Energy accounting per scheme (battery depletion and the round ledger both
# route through here; ``scheme`` is static, so analog compiles to exactly the
# eqs. (3-6) expression it always was)
# ---------------------------------------------------------------------------


# rate floor (bits/s) of the deep-fade/zero-knob guard below: keeps the
# latency/energy finite for degenerate traced knobs (a sweep's tx_power or
# bandwidth grid touching 0 would otherwise produce 0·inf = NaN energy that
# poisons the ledger and battery gating for EVERY client)
_MIN_RATE = 1e-12


def digital_rate(h_eff, tp: TransportParams, floor=TRUNCATION_FLOOR):
    """Per-client Shannon rate r_i = B·log2(1 + P·|h_i|²/N₀) (bits/s).

    ``floor`` guards the deep fade exactly like the analog path's truncation
    (h below the paper's threshold would drive the rate — and hence the
    latency/energy below — to infinity); the rate itself is additionally
    clamped to a tiny positive floor so zero-valued power/bandwidth knobs
    price as astronomically-expensive-but-finite instead of inf/NaN.
    """
    h = jnp.maximum(h_eff, floor)
    snr = tp.tx_power * jnp.square(h) / tp.rx_noise
    return jnp.maximum(tp.bandwidth * jnp.log2(1.0 + snr), _MIN_RATE)


def digital_latency(h_eff, model_size: int, tp: TransportParams,
                    floor=TRUNCATION_FLOOR):
    """Symbol-time latency of one upload: t_i = M·32 / r_i (seconds).

    The digital PS decodes the EXACT full-precision update, so the payload
    is priced at the analog scheme's implicit f32 width (``ANALOG_BITS``
    bits per parameter) — NOT at ``tp.bits``, which is the quantized
    scheme's precision/energy trade-off knob. Billing digital for a b-bit
    payload while delivering the f32 update would make ``bits`` a free
    lunch that corrupts every cross-transport Pareto comparison.
    """
    return model_size * ANALOG_BITS / digital_rate(h_eff, tp, floor)


def digital_energy(h_eff, model_size: int, tp: TransportParams,
                   floor=TRUNCATION_FLOOR):
    """Per-client digital upload energy E_i = P · t_i (Sun et al. accounting).

    Monotone increasing in the payload size (model bits M·32) and decreasing
    in SNR (a better channel shortens the transmission faster than the log
    grows it) — both pinned by ``tests/test_transport.py``.
    """
    return tp.tx_power * digital_latency(h_eff, model_size, tp, floor)


def uplink_energy(scheme: str, tp, h_eff, model_size: int, scenario):
    """Per-client upload energy [..., N] under the given transport scheme.

    ``scenario`` is the round's ``ChannelScenario`` (psi/tau/floor traced).
    Analog is eqs. (3-6) verbatim; quantized scales the analog airtime by
    ``bits/ANALOG_BITS``; digital is the OFDMA rate/latency accounting.
    """
    if scheme == "analog":
        return transmit_energy(h_eff, model_size, scenario.psi, scenario.tau,
                               floor=scenario.floor)
    if scheme == "quantized":
        return transmit_energy(h_eff, model_size, scenario.psi, scenario.tau,
                               floor=scenario.floor) * (tp.bits / ANALOG_BITS)
    if scheme == "digital":
        return digital_energy(h_eff, model_size, tp, floor=scenario.floor)
    raise ValueError(f"unknown transport scheme {scheme!r}")


def round_energy(scheme: str, tp, h_eff, mask, model_size: int, scenario):
    """Cumulative round energy of the selected set under the scheme."""
    return jnp.sum(mask * uplink_energy(scheme, tp, h_eff, model_size,
                                        scenario))


# ---------------------------------------------------------------------------
# Stochastic-rounding quantizer (the reference the fused kernel is pinned to)
# ---------------------------------------------------------------------------


def quant_step(flat_rows: jnp.ndarray, bits) -> jnp.ndarray:
    """Per-client grid step Δ_c = 2·max|row_c| / (2^bits − 1), shape [C].

    Each client scales its own payload into [−scale, scale] and rounds on a
    (2^bits)-level uniform grid; an all-zero row gets Δ = 0 (the quantizer
    passes it through unchanged).
    """
    levels = jnp.exp2(jnp.asarray(bits, flat_rows.dtype)) - 1.0
    return 2.0 * jnp.max(jnp.abs(flat_rows), axis=-1) / levels


def _client_uniforms(key, client_ids, width: int) -> jnp.ndarray:
    """[C, width] stochastic-rounding uniforms, content-addressed by GLOBAL
    client id: row c's stream is fold_in(fold_in(key, _QUANT_STREAM), id_c),
    so dense [N], gathered [K] and sharded rows draw identical values.

    The fold_in is vmapped SEPARATELY from the uniform draw: fusing both
    into one vmapped closure lowers to dramatically slower code on CPU
    (~50× on this container) for the identical values.
    """
    kq = jax.random.fold_in(key, _QUANT_STREAM)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(kq, client_ids)
    return jax.vmap(lambda k: jax.random.uniform(k, (width,)))(keys)


def sround(flat_rows: jnp.ndarray, step: jnp.ndarray,
           u: jnp.ndarray) -> jnp.ndarray:
    """Unbiased stochastic rounding to the per-row grid: Q(x) = ⌊x/Δ + u⌋·Δ.

    E[Q(x)] = x exactly (u ~ U[0,1)) and Var[Q(x)] = Δ²·p(1−p) ≤ Δ²/4 —
    both pinned as property tests. Δ = 0 rows pass through unchanged.
    """
    d = step[..., None]
    safe = jnp.where(d > 0, d, 1.0)
    return jnp.where(d > 0, jnp.floor(flat_rows / safe + u) * d, flat_rows)


def quantize_rows(flat_rows: jnp.ndarray, client_ids: jnp.ndarray, key,
                  bits):
    """Quantize per-client payload rows [C, P]; returns ``(q_rows, step)``.

    The pure-jnp reference of the fused quantize-aggregate kernel
    (``repro.kernels.aircomp``): property tests pin unbiasedness and the
    Δ²/4 variance bound here, and the kernel is differentially pinned
    against aggregating these exact rows.
    """
    step = quant_step(flat_rows, bits)
    u = _client_uniforms(key, client_ids, flat_rows.shape[-1])
    return sround(flat_rows, step, u), step


# ---------------------------------------------------------------------------
# Quantized aggregation (eq. (10) over quantized deltas) — dense/sparse/psum
# ---------------------------------------------------------------------------


def flat_awgn_like(key, tree, dtype=jnp.float32) -> jnp.ndarray:
    """Receiver-noise vector z [P] for an UNstacked model pytree.

    Delegates to :func:`repro.core.aircomp.flat_awgn` with a dummy leading
    client axis (``leaf[None].shape[1:] == leaf.shape``), so the production
    tier's params pytree draws the IDENTICAL per-leaf streams as the
    simulator's stacked trees — one noise-discipline implementation, not
    two that can desynchronize.
    """
    leaves = [leaf[None] for leaf in jax.tree_util.tree_leaves(tree)]
    return flat_awgn(key, leaves, dtype=dtype)


def quantized_aggregate_flat_rows(base_flat, delta_rows, weights, client_ids,
                                  key, noise_std, bits, k, z=None,
                                  use_pallas: bool | None = None):
    """Fused quantized eq. (10) over flat delta rows:
    ``base + (Σ_c w_c·Q(Δ_c) + σz)/k``.

    ``delta_rows`` [C, P] are per-client payloads (w_i − w̄ on the simulator
    tier, −η·g_i on the production tier); ``z`` [P] is the pre-drawn AWGN
    (None ⇒ statically noise-free). The rounding + weighted sum + noise +
    1/k run as ONE fused pass (``quant_aircomp_flat``: Pallas on TPU, jnp
    elsewhere); the stochastic-rounding uniforms are drawn here with the
    per-client fold_in streams.
    """
    step = quant_step(delta_rows, bits)
    u = _client_uniforms(key, client_ids, delta_rows.shape[-1])
    if z is None:
        z = jnp.zeros((delta_rows.shape[-1],), delta_rows.dtype)
        noise_std = 0.0
    agg = quant_aircomp_flat(delta_rows, weights, step, u, z,
                             noise_std=noise_std, k=k, use_pallas=use_pallas)
    return base_flat + agg


def _flatten_stack(trees):
    """(leaves, treedef, flat [C, P], acc_dtype) of a client-stacked pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(trees)
    c = leaves[0].shape[0]
    acc_dtype = stack_accum_dtype(leaves)
    flat = jnp.concatenate(
        [leaf.reshape(c, -1).astype(acc_dtype) for leaf in leaves], axis=1)
    return leaves, treedef, flat, acc_dtype


def _flatten_base(w_base, acc_dtype):
    return jnp.concatenate([
        leaf.reshape(-1).astype(acc_dtype)
        for leaf in jax.tree_util.tree_leaves(w_base)])


def _unflatten_like(flat, leaves, treedef):
    out, off = [], 0
    for leaf in leaves:
        size = int(leaf[0].size)
        out.append(flat[off:off + size].reshape(leaf.shape[1:])
                   .astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def quantized_aggregate_stack_tree(w_base, trees, weights, client_ids, key,
                                   noise_std, bits, k,
                                   use_pallas: bool | None = None):
    """Quantized-transport eq. (10) over a client-stacked pytree.

    ``trees``: leading client/slot axis C (N dense, K sparse) on every leaf;
    ``client_ids`` [C]: each row's GLOBAL client index (the quantization
    stream address); ``weights`` [C]: mask/gain entries, 0 for gated slots.
    Computes w̄ + (Σ_c w_c·Q(tree_c − w̄) + σz)/k with the AWGN drawn via the
    per-leaf discipline of the analog paths (``flat_awgn`` on ``key``), so
    bits→∞ recovers the analog aggregate with the identical noise
    realization.
    """
    leaves, treedef, flat, acc_dtype = _flatten_stack(trees)
    base_flat = _flatten_base(w_base, acc_dtype)
    delta = flat - base_flat[None, :]
    if isinstance(noise_std, (int, float)) and noise_std == 0:
        z = None
    else:
        z = flat_awgn(key, leaves, dtype=acc_dtype)
    new_flat = quantized_aggregate_flat_rows(
        base_flat, delta, weights, client_ids, key, noise_std, bits, k, z=z,
        use_pallas=use_pallas)
    return _unflatten_like(new_flat, leaves, treedef)


def quantized_aggregate_psum_tree(w_base, trees_local, weights_local,
                                  client_ids_local, key, noise_std, bits, k,
                                  axis_name: str = "clients"):
    """Population-sharded quantized eq. (10): local quantized partial-sum +
    ``psum`` + replicated AWGN + 1/k + w̄.

    ``client_ids_local`` are GLOBAL indices of this shard's rows, so each
    row's stochastic-rounding stream is identical to the dense program's —
    the sharded aggregate differs from dense only in the cross-shard
    summation order (the same contract as ``aircomp_psum_tree``).
    """
    leaves, treedef, flat, acc_dtype = _flatten_stack(trees_local)
    base_flat = _flatten_base(w_base, acc_dtype)
    delta = flat - base_flat[None, :]
    q, _ = quantize_rows(delta, client_ids_local, key, bits)
    partial = jnp.einsum("cp,c->p", q, weights_local.astype(acc_dtype))
    total = jax.lax.psum(partial, axis_name)
    if not (isinstance(noise_std, (int, float)) and noise_std == 0):
        total = total + noise_std * flat_awgn(key, leaves, dtype=acc_dtype)
    return _unflatten_like(base_flat + total / k, leaves, treedef)
