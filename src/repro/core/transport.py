"""Pluggable uplink-transport layer: analog / quantized / digital-OFDMA.

The paper's headline claim — 3×+ energy savings — is a claim about
*transmission schemes*, yet until this layer every "baseline" in the repo
rode the same analog AirComp uplink. This module makes the uplink a
first-class, sweepable axis with three schemes:

  - ``"analog"`` — the paper's eq. (10) channel-inversion AirComp. This is
    the pre-existing program, byte-for-byte: the analog branches below
    delegate to the exact functions the simulator always called, with the
    same key consumption, so ``transport="analog"`` trajectories are
    bit-identical to the pre-transport repo (pinned by
    ``tests/test_transport.py``).
  - ``"quantized"`` — Li et al. (arXiv:2208.07237)-style high-precision
    AirComp: each client stochastically rounds its model *update*
    Δ_i = w_i − w̄ to ``bits`` bits (per-client scale), the quantized deltas
    superpose over the air (same AWGN discipline as analog), and the PS
    reconstructs w̄ + (Σ mask·Q(Δ_i) + σz)/K. Fewer bits mean fewer analog
    symbols per parameter, so upload energy scales by ``bits/32`` relative
    to analog — the energy/aggregation-error trade-off the scheme exists
    for. The quantize-scale-sum-noise-normalize pass is fused
    (``repro.kernels.aircomp``: Pallas kernel on TPU, jnp elsewhere).
  - ``"digital"`` — Sun et al. (arXiv:2106.00490)-style orthogonal OFDMA
    uplink: each scheduled client gets its own ``bandwidth`` subband and
    transmits at ``tx_power``; its rate is Shannon's
    B·log2(1 + P·|h|²/N₀), the symbol-time latency is M·32/rate (the PS
    decodes the EXACT f32 update, so the payload is priced at full
    precision — ``bits`` is the quantized scheme's knob), and the upload
    energy is P × latency. Error-free decode means aggregation is the plain
    masked weighted mean with NO superposition noise — the
    clean-but-costly comparison point.
  - ``"sparse"`` — Jin et al. (arXiv:2004.07351)-style top-k sparsified
    AirComp with per-client error-feedback memory: each client adds its
    persistent residual to the fresh delta, keeps only the
    k = max(1, round(``density``·P)) largest-magnitude coordinates (the
    rest feed back into the residual for the NEXT round), and the sparse
    payloads superpose over the air under the same AWGN discipline as
    analog. Airtime prices the compressed payload — density·(32 + log2 P)
    bits per kept coordinate (value + index) — so upload energy scales by
    ``sparse_payload_frac``. The compress-scale-sum-noise-normalize pass is
    fused (``repro.kernels.aircomp.sparse_aircomp_*``). Compression is
    DETERMINISTIC (a per-row magnitude threshold at the k-th largest
    coordinate), so dense [N], gathered [K] and population-sharded rows
    select bit-identical supports with no new randomness stream; the
    error-feedback residual is per-client carried STATE — a new scan-carry
    leaf (``SimState.ef_resid`` / ``ServerState.ef_resid``) indexed by
    global client id, per the dynamics-module rule (new per-client state =
    new carry leaf + new fold_in streams; never re-split existing keys).

This module also owns :func:`downlink_energy`: the per-round broadcast of
the global model is no longer free — every available receiver pays
``dl_power`` × the broadcast airtime, with the airtime scaled by the same
per-scheme payload fraction as the uplink (full f32 for analog/digital,
``bits``/32 for quantized, the K-union compressed payload for sparse). The
default ``dl_power = 0.0`` prices the broadcast at exactly zero, keeping
every pre-downlink trajectory bit-for-bit (the ledger columns are
additive; x − 0 = x elementwise).

Contract (the "Transport contract" section of the README has the long
form): the *scheme* is structural — ``FLConfig.transport`` joins
``sweep.STATIC_FIELDS``, so each scheme compiles its own program and the
analog program is exactly the pre-transport one. Every scheme *knob*
(``bits``, ``tx_power``, ``bandwidth``, ``rx_noise``) is a traced data
field of :class:`TransportParams` riding the sweep's vmap axis — a whole
bits-grid or power-grid sweeps under ONE compilation per scheme.

Key discipline: quantization randomness derives from
``fold_in(k_noise, _QUANT_STREAM)`` folded again with each client's GLOBAL
index — content-addressed per-client streams, so the dense [N], the
gathered sparse [K] and the population-sharded paths draw bit-identical
per-client uniforms (the same trick the control plane uses for replicated
[N] draws). The AWGN keeps the per-leaf discipline of
``aircomp_aggregate_tree`` on every path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.aircomp import flat_awgn, stack_accum_dtype
from repro.core.energy import TRUNCATION_FLOOR, transmit_energy
from repro.kernels.aircomp.ops import quant_aircomp_flat, sparse_aircomp_flat

__all__ = [
    "TRANSPORTS", "ANALOG_BITS", "TransportParams", "transport_from_config",
    "quant_step", "quantize_rows", "uplink_energy", "round_energy",
    "downlink_energy", "sparse_payload_frac", "sparse_k_coords",
    "sparse_thresholds", "sparse_compress_rows",
    "digital_rate", "digital_latency", "digital_energy",
    "quantized_aggregate_stack_tree", "quantized_aggregate_psum_tree",
    "quantized_aggregate_flat_rows", "flat_awgn_like",
    "sparse_aggregate_stack_tree", "sparse_aggregate_psum_tree",
    "sparse_aggregate_flat_rows",
]

TRANSPORTS = ("analog", "quantized", "digital", "sparse")

# the analog scheme's implicit payload precision: one f32 symbol stream per
# parameter. Quantized airtime (hence energy) scales by bits/ANALOG_BITS.
ANALOG_BITS = 32.0

# fold_in stream of the round's k_noise reserved for quantization uniforms
# (k_chan owns streams 1-3 in core/dynamics.py; this is a different key, the
# constant just keeps the reservation greppable).
_QUANT_STREAM = 7


@dataclass(frozen=True)
class TransportParams:
    """Per-scheme knobs: traced data + the structural ``scheme`` metadata.

    Data fields accept Python floats or (possibly vmapped) jnp scalars, like
    every other sweep knob; ``scheme`` is pytree metadata, so points with
    different schemes land in different sweep compilation groups (the same
    contract ``ChannelScenario.flat`` and ``ChannelProcess.temporal`` use).
    """

    bits: Any = 8.0        # payload precision, bits per model parameter
    tx_power: Any = 0.1    # digital uplink transmit power P (W)
    bandwidth: Any = 1e5   # digital per-client OFDMA subband B (Hz)
    rx_noise: Any = 1e-2   # digital receiver noise+interference power N0 (W)
    # lint: allow(single-source-literal): coincidental value collision with energy.TRUNCATION_FLOOR — this is FLConfig.sparse_density's default (kept-fraction), not the paper's channel-truncation constant
    density: Any = 0.05    # sparse kept-coordinate fraction (energy pricing)
    dl_power: Any = 0.0    # downlink broadcast receive power (W); 0 = free
    scheme: str = "analog"


jax.tree_util.register_dataclass(
    TransportParams,
    data_fields=["bits", "tx_power", "bandwidth", "rx_noise", "density",
                 "dl_power"],
    meta_fields=["scheme"],
)


def transport_from_config(fl: FLConfig) -> TransportParams:
    """Promote the ``FLConfig`` transport knobs to f32 traced scalars."""
    if fl.transport not in TRANSPORTS:
        raise ValueError(
            f"unknown transport {fl.transport!r}; pick one of {TRANSPORTS}")
    f32 = lambda v: jnp.asarray(v, jnp.float32)  # noqa: E731
    return TransportParams(
        bits=f32(fl.quant_bits),
        tx_power=f32(fl.tx_power),
        bandwidth=f32(fl.ofdma_bandwidth),
        rx_noise=f32(fl.rx_noise),
        density=f32(fl.sparse_density),
        dl_power=f32(fl.dl_rx_power),
        scheme=fl.transport,
    )


# ---------------------------------------------------------------------------
# Energy accounting per scheme (battery depletion and the round ledger both
# route through here; ``scheme`` is static, so analog compiles to exactly the
# eqs. (3-6) expression it always was)
# ---------------------------------------------------------------------------


# rate floor (bits/s) of the deep-fade/zero-knob guard below: keeps the
# latency/energy finite for degenerate traced knobs (a sweep's tx_power or
# bandwidth grid touching 0 would otherwise produce 0·inf = NaN energy that
# poisons the ledger and battery gating for EVERY client)
_MIN_RATE = 1e-12

# receiver-noise floor (W) of the same guard, on the OTHER side of the SNR
# ratio: a sweep grid touching rx_noise=0 gave SNR=inf → rate=inf →
# latency=0 → a ZERO-COST digital uplink, which is free energy corrupting
# every Pareto front digital appears on (the dual of the _MIN_RATE hole)
_MIN_NOISE = 1e-12


def digital_rate(h_eff, tp: TransportParams, floor=TRUNCATION_FLOOR):
    """Per-client Shannon rate r_i = B·log2(1 + P·|h_i|²/N₀) (bits/s).

    ``floor`` guards the deep fade exactly like the analog path's truncation
    (h below the paper's threshold would drive the rate — and hence the
    latency/energy below — to infinity); the rate itself is additionally
    clamped to a tiny positive floor so zero-valued power/bandwidth knobs
    price as astronomically-expensive-but-finite instead of inf/NaN, and
    the noise knob to a tiny positive floor so ``rx_noise = 0`` prices as
    an enormous-but-FINITE rate instead of a free (zero-latency) upload.
    """
    h = jnp.maximum(h_eff, floor)
    snr = tp.tx_power * jnp.square(h) / jnp.maximum(tp.rx_noise, _MIN_NOISE)
    return jnp.maximum(tp.bandwidth * jnp.log2(1.0 + snr), _MIN_RATE)


def digital_latency(h_eff, model_size: int, tp: TransportParams,
                    floor=TRUNCATION_FLOOR):
    """Symbol-time latency of one upload: t_i = M·32 / r_i (seconds).

    The digital PS decodes the EXACT full-precision update, so the payload
    is priced at the analog scheme's implicit f32 width (``ANALOG_BITS``
    bits per parameter) — NOT at ``tp.bits``, which is the quantized
    scheme's precision/energy trade-off knob. Billing digital for a b-bit
    payload while delivering the f32 update would make ``bits`` a free
    lunch that corrupts every cross-transport Pareto comparison.
    """
    return model_size * ANALOG_BITS / digital_rate(h_eff, tp, floor)


def digital_energy(h_eff, model_size: int, tp: TransportParams,
                   floor=TRUNCATION_FLOOR):
    """Per-client digital upload energy E_i = P · t_i (Sun et al. accounting).

    Monotone increasing in the payload size (model bits M·32) and decreasing
    in SNR (a better channel shortens the transmission faster than the log
    grows it) — both pinned by ``tests/test_transport.py``.
    """
    return tp.tx_power * digital_latency(h_eff, model_size, tp, floor)


def sparse_payload_frac(density, model_size: int, num_tx: int = 1):
    """Airtime fraction of one sparse payload relative to the f32 dense one.

    Each kept coordinate ships its f32 value plus a ⌈log2 P⌉-bit index, so
    ``num_tx`` superposed/unioned sparse payloads cost
    ``num_tx · density · (32 + log2 P) / 32`` of the dense airtime, capped
    at 1.0 (a union can never cost more than just broadcasting densely).
    ``density`` is traced; ``model_size``/``num_tx`` are static.
    """
    idx_bits = math.log2(max(model_size, 2))
    frac = num_tx * density * (ANALOG_BITS + idx_bits) / ANALOG_BITS
    return jnp.minimum(jnp.asarray(frac, jnp.float32), 1.0)


def uplink_energy(scheme: str, tp, h_eff, model_size: int, scenario):
    """Per-client upload energy [..., N] under the given transport scheme.

    ``scenario`` is the round's ``ChannelScenario`` (psi/tau/floor traced).
    Analog is eqs. (3-6) verbatim; quantized scales the analog airtime by
    ``bits/ANALOG_BITS`` (billed bits floored at 1 — a bits→0 grid cell
    must price its one-level payload, not upload for free, matching the
    ``_MIN_RATE`` no-free-energy rule); digital is the OFDMA rate/latency
    accounting; sparse scales the analog airtime by the compressed-payload
    fraction (value + index bits per kept coordinate).
    """
    if scheme == "analog":
        return transmit_energy(h_eff, model_size, scenario.psi, scenario.tau,
                               floor=scenario.floor)
    if scheme == "quantized":
        billed = jnp.maximum(tp.bits, 1.0)
        return transmit_energy(h_eff, model_size, scenario.psi, scenario.tau,
                               floor=scenario.floor) * (billed / ANALOG_BITS)
    if scheme == "digital":
        return digital_energy(h_eff, model_size, tp, floor=scenario.floor)
    if scheme == "sparse":
        return transmit_energy(h_eff, model_size, scenario.psi, scenario.tau,
                               floor=scenario.floor) \
            * sparse_payload_frac(tp.density, model_size)
    raise ValueError(f"unknown transport scheme {scheme!r}")


def downlink_energy(scheme: str, tp, model_size: int, scenario,
                    num_tx: int = 1):
    """Per-receiver energy of ONE global-model broadcast (Joules).

    The broadcast airtime is ``model_size · tau`` symbols scaled by the
    per-scheme payload fraction — full f32 for analog/digital (the PS sends
    the exact model), ``bits/ANALOG_BITS`` for quantized (it can re-quantize
    the broadcast on the same grid; billed bits floored at 1 like the
    uplink), and the K-union sparse payload for sparse (``num_tx`` =
    scheduled-set size: after aggregating K sparse uploads the model delta's
    support is at most the union of their supports — a conservative, static
    bound the ledger uses on every path). Each receiver pays
    ``dl_power × airtime``; the default ``dl_power = 0`` makes the whole
    column exactly zero, so pre-downlink trajectories stay bit-for-bit.
    """
    if scheme in ("analog", "digital"):
        frac = 1.0
    elif scheme == "quantized":
        frac = jnp.maximum(tp.bits, 1.0) / ANALOG_BITS
    elif scheme == "sparse":
        frac = sparse_payload_frac(tp.density, model_size, num_tx=num_tx)
    else:
        raise ValueError(f"unknown transport scheme {scheme!r}")
    return tp.dl_power * model_size * scenario.tau * frac


def round_energy(scheme: str, tp, h_eff, mask, model_size: int, scenario,
                 recv_count=None, dl_num_tx: int = 1):
    """Cumulative round energy of the selected set under the scheme.

    ``recv_count`` (optional traced scalar) adds the downlink side: the
    number of clients that received the round's broadcast, each billed
    :func:`downlink_energy`. ``None`` keeps the uplink-only ledger.
    """
    total = jnp.sum(mask * uplink_energy(scheme, tp, h_eff, model_size,
                                         scenario))
    if recv_count is not None:
        total = total + recv_count * downlink_energy(
            scheme, tp, model_size, scenario, num_tx=dl_num_tx)
    return total


# ---------------------------------------------------------------------------
# Stochastic-rounding quantizer (the reference the fused kernel is pinned to)
# ---------------------------------------------------------------------------


def quant_step(flat_rows: jnp.ndarray, bits) -> jnp.ndarray:
    """Per-client grid step Δ_c = 2·max|row_c| / (2^bits − 1), shape [C].

    Each client scales its own payload into [−scale, scale] and rounds on a
    (2^bits)-level uniform grid; an all-zero row gets Δ = 0 (the quantizer
    passes it through unchanged). The level count is floored at 1: a
    bits-grid touching 0 gave ``levels = 2⁰ − 1 = 0`` → Δ = inf →
    ``floor(x/inf + u)·inf = 0·inf = NaN`` payloads poisoning the whole
    aggregate; bits ≤ 1 now rounds on the coarsest finite grid instead
    (the ``_MIN_RATE``-style degenerate-knob guard).
    """
    levels = jnp.maximum(
        jnp.exp2(jnp.asarray(bits, flat_rows.dtype)) - 1.0, 1.0)
    return 2.0 * jnp.max(jnp.abs(flat_rows), axis=-1) / levels


def _client_uniforms(key, client_ids, width: int) -> jnp.ndarray:
    """[C, width] stochastic-rounding uniforms, content-addressed by GLOBAL
    client id: row c's stream is fold_in(fold_in(key, _QUANT_STREAM), id_c),
    so dense [N], gathered [K] and sharded rows draw identical values.

    The fold_in is vmapped SEPARATELY from the uniform draw: fusing both
    into one vmapped closure lowers to dramatically slower code on CPU
    (~50× on this container) for the identical values.
    """
    kq = jax.random.fold_in(key, _QUANT_STREAM)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(kq, client_ids)
    return jax.vmap(lambda k: jax.random.uniform(k, (width,)))(keys)


def sround(flat_rows: jnp.ndarray, step: jnp.ndarray,
           u: jnp.ndarray) -> jnp.ndarray:
    """Unbiased stochastic rounding to the per-row grid: Q(x) = ⌊x/Δ + u⌋·Δ.

    E[Q(x)] = x exactly (u ~ U[0,1)) and Var[Q(x)] = Δ²·p(1−p) ≤ Δ²/4 —
    both pinned as property tests. Δ = 0 rows pass through unchanged.
    """
    d = step[..., None]
    safe = jnp.where(d > 0, d, 1.0)
    return jnp.where(d > 0, jnp.floor(flat_rows / safe + u) * d, flat_rows)


def quantize_rows(flat_rows: jnp.ndarray, client_ids: jnp.ndarray, key,
                  bits):
    """Quantize per-client payload rows [C, P]; returns ``(q_rows, step)``.

    The pure-jnp reference of the fused quantize-aggregate kernel
    (``repro.kernels.aircomp``): property tests pin unbiasedness and the
    Δ²/4 variance bound here, and the kernel is differentially pinned
    against aggregating these exact rows.
    """
    step = quant_step(flat_rows, bits)
    u = _client_uniforms(key, client_ids, flat_rows.shape[-1])
    return sround(flat_rows, step, u), step


# ---------------------------------------------------------------------------
# Quantized aggregation (eq. (10) over quantized deltas) — dense/sparse/psum
# ---------------------------------------------------------------------------


def flat_awgn_like(key, tree, dtype=jnp.float32) -> jnp.ndarray:
    """Receiver-noise vector z [P] for an UNstacked model pytree.

    Delegates to :func:`repro.core.aircomp.flat_awgn` with a dummy leading
    client axis (``leaf[None].shape[1:] == leaf.shape``), so the production
    tier's params pytree draws the IDENTICAL per-leaf streams as the
    simulator's stacked trees — one noise-discipline implementation, not
    two that can desynchronize.
    """
    leaves = [leaf[None] for leaf in jax.tree_util.tree_leaves(tree)]
    return flat_awgn(key, leaves, dtype=dtype)


def quantized_aggregate_flat_rows(base_flat, delta_rows, weights, client_ids,
                                  key, noise_std, bits, k, z=None,
                                  use_pallas: bool | None = None):
    """Fused quantized eq. (10) over flat delta rows:
    ``base + (Σ_c w_c·Q(Δ_c) + σz)/k``.

    ``delta_rows`` [C, P] are per-client payloads (w_i − w̄ on the simulator
    tier, −η·g_i on the production tier); ``z`` [P] is the pre-drawn AWGN
    (None ⇒ statically noise-free). The rounding + weighted sum + noise +
    1/k run as ONE fused pass (``quant_aircomp_flat``: Pallas on TPU, jnp
    elsewhere); the stochastic-rounding uniforms are drawn here with the
    per-client fold_in streams.
    """
    step = quant_step(delta_rows, bits)
    u = _client_uniforms(key, client_ids, delta_rows.shape[-1])
    if z is None:
        z = jnp.zeros((delta_rows.shape[-1],), delta_rows.dtype)
        noise_std = 0.0
    agg = quant_aircomp_flat(delta_rows, weights, step, u, z,
                             noise_std=noise_std, k=k, use_pallas=use_pallas)
    return base_flat + agg


def _flatten_stack(trees):
    """(leaves, treedef, flat [C, P], acc_dtype) of a client-stacked pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(trees)
    c = leaves[0].shape[0]
    acc_dtype = stack_accum_dtype(leaves)
    flat = jnp.concatenate(
        [leaf.reshape(c, -1).astype(acc_dtype) for leaf in leaves], axis=1)
    return leaves, treedef, flat, acc_dtype


def _flatten_base(w_base, acc_dtype):
    return jnp.concatenate([
        leaf.reshape(-1).astype(acc_dtype)
        for leaf in jax.tree_util.tree_leaves(w_base)])


def _unflatten_like(flat, leaves, treedef):
    out, off = [], 0
    for leaf in leaves:
        size = int(leaf[0].size)
        out.append(flat[off:off + size].reshape(leaf.shape[1:])
                   .astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def quantized_aggregate_stack_tree(w_base, trees, weights, client_ids, key,
                                   noise_std, bits, k,
                                   use_pallas: bool | None = None):
    """Quantized-transport eq. (10) over a client-stacked pytree.

    ``trees``: leading client/slot axis C (N dense, K sparse) on every leaf;
    ``client_ids`` [C]: each row's GLOBAL client index (the quantization
    stream address); ``weights`` [C]: mask/gain entries, 0 for gated slots.
    Computes w̄ + (Σ_c w_c·Q(tree_c − w̄) + σz)/k with the AWGN drawn via the
    per-leaf discipline of the analog paths (``flat_awgn`` on ``key``), so
    bits→∞ recovers the analog aggregate with the identical noise
    realization.
    """
    leaves, treedef, flat, acc_dtype = _flatten_stack(trees)
    base_flat = _flatten_base(w_base, acc_dtype)
    delta = flat - base_flat[None, :]
    if isinstance(noise_std, (int, float)) and noise_std == 0:
        z = None
    else:
        z = flat_awgn(key, leaves, dtype=acc_dtype)
    new_flat = quantized_aggregate_flat_rows(
        base_flat, delta, weights, client_ids, key, noise_std, bits, k, z=z,
        use_pallas=use_pallas)
    return _unflatten_like(new_flat, leaves, treedef)


def quantized_aggregate_psum_tree(w_base, trees_local, weights_local,
                                  client_ids_local, key, noise_std, bits, k,
                                  axis_name: str = "clients"):
    """Population-sharded quantized eq. (10): local quantized partial-sum +
    ``psum`` + replicated AWGN + 1/k + w̄.

    ``client_ids_local`` are GLOBAL indices of this shard's rows, so each
    row's stochastic-rounding stream is identical to the dense program's —
    the sharded aggregate differs from dense only in the cross-shard
    summation order (the same contract as ``aircomp_psum_tree``).
    """
    leaves, treedef, flat, acc_dtype = _flatten_stack(trees_local)
    base_flat = _flatten_base(w_base, acc_dtype)
    delta = flat - base_flat[None, :]
    q, _ = quantize_rows(delta, client_ids_local, key, bits)
    partial = jnp.einsum("cp,c->p", q, weights_local.astype(acc_dtype))
    total = jax.lax.psum(partial, axis_name)
    if not (isinstance(noise_std, (int, float)) and noise_std == 0):
        total = total + noise_std * flat_awgn(key, leaves, dtype=acc_dtype)
    return _unflatten_like(base_flat + total / k, leaves, treedef)


# ---------------------------------------------------------------------------
# Sparse (error-feedback top-k) aggregation — dense/gathered-K/psum
# ---------------------------------------------------------------------------


def sparse_k_coords(density: float, model_size: int) -> int:
    """STATIC kept-coordinate count k = clip(round(density·P), 1, P).

    ``density`` here is the structural ``FLConfig.sparse_density`` (a Python
    float — it bakes the compiled ``top_k`` width), NOT the traced
    ``TransportParams.density`` copy the energy ledger prices with.
    """
    return max(1, min(int(round(density * model_size)), model_size))


def sparse_thresholds(v_rows: jnp.ndarray, k_coords: int) -> jnp.ndarray:
    """Per-row top-k magnitude separator, [C].

    The compression mask is ``|v| >= thr`` and keeps EXACTLY the row's k
    largest-|coordinate| set: ``thr`` is the shortest bit-prefix separating
    the k-th from the (k+1)-th largest magnitude (any value in that gap
    selects the same support), falling back to the k-th largest value
    itself when magnitude ties make an exact-k separator impossible — then
    every tied coordinate rides along (a superset of k; the energy ledger
    prices the nominal density, documented conservative). A DETERMINISTIC
    within-row property either way, so the dense [N], gathered [K] and
    population-sharded layouts select bit-identical supports with no
    per-client randomness stream. An all-zero row gets thr = 0, selects
    itself entirely and contributes exact zeros.
    """
    mags = jnp.abs(v_rows)
    if jnp.dtype(mags.dtype).itemsize > 4:
        # radix select below is f32-bit-pattern based; wider dtypes take the
        # (rare, correctness-only) top_k route with full precision
        return jax.lax.top_k(mags, k_coords)[0][..., -1]
    # MSB-first radix select on the f32 bit pattern: nonnegative floats
    # order exactly like their int32 bits, so growing the largest prefix t
    # with count(bits >= t) >= k converges on the k-th largest magnitude in
    # at most 31 compare-and-count passes — no sort/top_k primitive (XLA's
    # CPU sort is ~15x slower on the [K, P] payload stack; see BENCH_perf's
    # sparse_vs_analog floor). Early exit: a row freezes at its FIRST
    # prefix counting EXACTLY k — that prefix already separates the k-th
    # from the (k+1)-th coordinate, deeper bits cannot change the kept set
    # (the count(>= prefix) >= k invariant only tightens), and typical
    # payloads resolve in ~half the passes; tied/degenerate rows never hit
    # an exact-k count and fall through to the full 31, landing on the k-th
    # largest value itself. The count is phrased as a dot with ones so XLA
    # lowers it through the gemv path rather than a scalar reduce loop.
    bits = jax.lax.bitcast_convert_type(mags.astype(jnp.float32), jnp.int32)
    ones = jnp.ones((bits.shape[-1],), jnp.float32)
    kf = jnp.float32(k_coords)

    def _cond(carry):
        i, _, cnt = carry
        return (i < 31) & jnp.any(cnt != kf)

    def _bit(carry):
        i, prefix, cnt = carry
        cand = prefix | (jnp.int32(1) << (jnp.int32(30) - i))
        cnt_cand = jnp.dot((bits >= cand[..., None]).astype(jnp.float32),
                           ones)
        # freeze a row at its FIRST exact-k prefix: the frozen value is a
        # pure per-row function (independent of how long slower rows keep
        # the loop alive), so every layout computes the identical threshold
        take = (cnt != kf) & (cnt_cand >= kf)
        return (i + 1, jnp.where(take, cand, prefix),
                jnp.where(take, cnt_cand, cnt))

    shape = mags.shape[:-1]
    _, prefix, _ = jax.lax.while_loop(
        _cond, _bit, (jnp.int32(0), jnp.zeros(shape, jnp.int32),
                      jnp.full(shape, jnp.float32(mags.shape[-1]))))
    return jax.lax.bitcast_convert_type(prefix, jnp.float32)


def sparse_compress_rows(v_rows: jnp.ndarray, k_coords: int):
    """Top-k compress payload rows [C, P]; returns ``(c_rows, thr)``.

    The pure-jnp reference of the fused sparse kernel: ``c = v · 1{|v| ≥
    thr}`` with ``thr`` from :func:`sparse_thresholds`. The error-feedback
    residual update ``v − c`` recomputes this exact mask (same f32
    compare), so telescoping Σc + residual == Σv holds bitwise per round.
    """
    thr = sparse_thresholds(v_rows, k_coords)
    c = jnp.where(jnp.abs(v_rows) >= thr[..., None], v_rows, 0.0)
    return c, thr


def sparse_aggregate_flat_rows(base_flat, delta_rows, resid_rows, weights,
                               key, noise_std, k_coords: int, k, z=None,
                               use_pallas: bool | None = None):
    """Fused sparse eq. (10) over flat delta rows with error feedback:
    ``(base + (Σ_c w_c·C(Δ_c + r_c) + σz)/k, r')``.

    ``delta_rows`` [C, P] are per-client payloads, ``resid_rows`` [C, P] the
    carried error-feedback memory. Each client compresses v = Δ + r to its
    top-``k_coords`` coordinates; the kept values aggregate in ONE fused
    compress-scale-sum-AWGN-normalize pass (``sparse_aircomp_flat``: Pallas
    on TPU, jnp elsewhere) and the dropped mass v − C(v) becomes the new
    residual. Gated slots (weight 0) transmit nothing and KEEP their old
    residual — their v never left the device. ``key`` is accepted for
    signature symmetry with the quantized path (compression is
    deterministic; the AWGN ``z`` is pre-drawn by the caller).
    """
    del key  # deterministic compression — no per-client stream consumed
    v = delta_rows + resid_rows.astype(delta_rows.dtype)
    thr = sparse_thresholds(v, k_coords)
    if z is None:
        z = jnp.zeros((delta_rows.shape[-1],), delta_rows.dtype)
        noise_std = 0.0
    agg = sparse_aircomp_flat(v, weights, thr, z, noise_std=noise_std, k=k,
                              use_pallas=use_pallas)
    c = jnp.where(jnp.abs(v) >= thr[..., None], v, 0.0)
    sent = (weights > 0)[..., None]
    new_resid = jnp.where(sent, (v - c).astype(resid_rows.dtype), resid_rows)
    return base_flat + agg, new_resid


def sparse_aggregate_stack_tree(w_base, trees, weights, key, noise_std,
                                k_coords: int, k, resid_rows,
                                use_pallas: bool | None = None):
    """Sparse-transport eq. (10) over a client-stacked pytree.

    ``trees``: leading client/slot axis C (N dense, K gathered) on every
    leaf; ``resid_rows`` [C, P]: those clients' error-feedback rows (the
    caller gathers/scatters them against the global ``ef_resid`` leaf by
    client id). Returns ``(new_tree, new_resid_rows)``. AWGN keeps the
    per-leaf discipline of the analog paths (``flat_awgn`` on ``key``), so
    density→1 recovers the analog aggregate with the identical noise
    realization.
    """
    leaves, treedef, flat, acc_dtype = _flatten_stack(trees)
    base_flat = _flatten_base(w_base, acc_dtype)
    delta = flat - base_flat[None, :]
    if isinstance(noise_std, (int, float)) and noise_std == 0:
        z = None
    else:
        z = flat_awgn(key, leaves, dtype=acc_dtype)
    new_flat, new_resid = sparse_aggregate_flat_rows(
        base_flat, delta, resid_rows, weights, key, noise_std, k_coords, k,
        z=z, use_pallas=use_pallas)
    return _unflatten_like(new_flat, leaves, treedef), new_resid


def sparse_aggregate_psum_tree(w_base, trees_local, weights_local, key,
                               noise_std, k_coords: int, k, resid_local,
                               axis_name: str = "clients"):
    """Population-sharded sparse eq. (10): local compressed partial-sum +
    ``psum`` + replicated AWGN + 1/k + w̄; returns ``(new_tree,
    new_resid_local)``.

    Compression is a within-row magnitude threshold, so each shard's rows
    compress bit-identically to the dense program's (no client-id streams
    needed) and the sharded aggregate differs from dense only in the
    cross-shard summation order — the same contract as
    ``quantized_aggregate_psum_tree``. Residual rows stay SHARD-LOCAL:
    each device updates only its own clients' memory.
    """
    leaves, treedef, flat, acc_dtype = _flatten_stack(trees_local)
    base_flat = _flatten_base(w_base, acc_dtype)
    delta = flat - base_flat[None, :]
    v = delta + resid_local.astype(acc_dtype)
    c, _ = sparse_compress_rows(v, k_coords)
    partial = jnp.einsum("cp,c->p", c, weights_local.astype(acc_dtype))
    total = jax.lax.psum(partial, axis_name)
    if not (isinstance(noise_std, (int, float)) and noise_std == 0):
        total = total + noise_std * flat_awgn(key, leaves, dtype=acc_dtype)
    sent = (weights_local > 0)[..., None]
    new_resid = jnp.where(sent, (v - c).astype(resid_local.dtype),
                          resid_local)
    return _unflatten_like(base_flat + total / k, leaves, treedef), new_resid
