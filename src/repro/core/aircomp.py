"""Over-the-air (AirComp) model aggregation (paper eqs. 1 and 10).

With channel-inversion power control, each selected client pre-scales its
analog symbols by 1/h so the superposed signal received by the PS is the plain
sum of the K transmitted models plus receiver noise:

    w̄^(t+1) = ( Σ_{i∈D} w_i^(t+1) + z^(t) ) / K            (eq. 10)

On TPU the multiple-access superposition maps onto the ICI all-reduce; the
AWGN z is injected from a PRNG key to preserve the algorithm's statistics
(DESIGN.md §2).

Two implementations:

  - :func:`aircomp_aggregate_tree` — the per-leaf REFERENCE path (one masked
    sum + noise draw per pytree leaf, per-leaf key splits). The dense
    simulator path and the differential tests pin against it.
  - :func:`aircomp_aggregate_stack_tree` — the fused hot path: the [K, ...]
    stacked pytree is raveled once into a single contiguous [K, P] buffer and
    the whole eq. (10) (weighted sum + AWGN + 1/K) is one fused pass over it,
    dispatched to the Pallas kernel (``repro.kernels.aircomp``) on TPU and a
    fused jnp einsum elsewhere. The AWGN is drawn with the SAME per-leaf key
    discipline as the reference path, so the two paths inject bit-identical
    noise and differ only in summation order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.aircomp.ops import aircomp_aggregate_flat


def aircomp_aggregate(
    stacked: jnp.ndarray,
    mask: jnp.ndarray,
    key,
    noise_std: float = 0.0,
    k: float | jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Aggregate stacked per-client tensors [N, ...] under participation mask.

    Returns (Σ_i mask_i·x_i + z)/K where K defaults to Σ mask (the paper uses
    the fixed K since the selected set always has size K).
    """
    if k is None:
        k = jnp.sum(mask)
    mshape = (-1,) + (1,) * (stacked.ndim - 1)
    summed = jnp.sum(stacked * mask.reshape(mshape), axis=0)
    # noise_std may be a traced scalar (sweep engine vmaps it); only skip the
    # draw when it is a *static* zero — a traced 0.0 adds exactly 0.
    if not (isinstance(noise_std, (int, float)) and noise_std == 0):
        summed = summed + noise_std * jax.random.normal(key, summed.shape, summed.dtype)
    return summed / k


def aircomp_aggregate_tree(trees, mask, key, noise_std: float = 0.0, k=None):
    """Pytree form: `trees` has leading client axis N on every leaf.

    The per-leaf reference implementation: one masked sum and one noise draw
    per leaf, with a per-leaf key split. Kept as the oracle the fused
    flat-buffer path (:func:`aircomp_aggregate_stack_tree`) is pinned
    against.
    """
    if k is None:
        k = jnp.sum(mask)
    leaves, treedef = jax.tree_util.tree_flatten(trees)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, kk in zip(leaves, keys, strict=True):
        out.append(aircomp_aggregate(leaf, mask, kk, noise_std, k))
    return jax.tree_util.tree_unflatten(treedef, out)


def stack_accum_dtype(leaves):
    """Accumulation dtype of the fused flat buffer: the widest leaf dtype,
    never narrower than f32.

    The flat path used to ravel EVERY leaf through float32, silently
    degrading float64 models the per-leaf reference aggregates at native
    precision (and needlessly up-casting nothing for bf16, which still wants
    f32 accumulation). ``result_type`` over the leaf dtypes + f32 gives f64
    when any leaf is f64 and f32 otherwise — so bf16/f32 models keep the f32
    fused pass and f64 models stop losing half their mantissa.
    """
    return jnp.result_type(jnp.float32, *[leaf.dtype for leaf in leaves])


def flat_awgn(key, leaves, dtype=jnp.float32) -> jnp.ndarray:
    """Receiver-noise vector z [P] for a flat model buffer.

    Drawn leaf-by-leaf with exactly the key discipline of
    :func:`aircomp_aggregate_tree` (split ``key`` into one subkey per leaf,
    normal of the leaf's per-client shape/dtype), then raveled into the
    accumulation ``dtype`` — so the fused path injects bit-identical noise
    to the per-leaf reference and differential tests only see
    summation-order differences.

    ``leaves``: the flattened leaves of the STACKED tree (leading client
    axis); the noise shape is each leaf's shape without that axis.
    """
    keys = jax.random.split(key, len(leaves))
    return jnp.concatenate([
        jax.random.normal(kk, leaf.shape[1:], leaf.dtype)
        .reshape(-1).astype(dtype)
        for leaf, kk in zip(leaves, keys, strict=True)
    ])


def aircomp_aggregate_stack_tree(trees, weights, key, noise_std=0.0, k=None,
                                 use_pallas: bool | None = None):
    """Fused flat-buffer eq. (10) over a stacked pytree (the hot path).

    ``trees``: pytree with a leading client/slot axis (size K on the sparse
    hot path, N on dense callers) on every leaf; ``weights`` [K]: per-slot
    mask/gain entries (0 for availability/battery-gated slots). The stack is
    raveled ONCE into a contiguous [K, P] buffer and the whole masked-sum +
    AWGN + 1/K pass runs fused over it — the Pallas kernel on TPU, a jnp
    einsum elsewhere (see ``repro.kernels.aircomp.ops``). Accumulation runs
    at the widest leaf dtype (:func:`stack_accum_dtype`), so float64 models
    aggregate at native precision like the per-leaf reference; the Pallas
    kernel is f32-only and the dispatcher falls back to the jnp path for
    wider buffers.
    """
    if k is None:
        k = jnp.sum(weights)
    leaves, treedef = jax.tree_util.tree_flatten(trees)
    kk = leaves[0].shape[0]
    acc_dtype = stack_accum_dtype(leaves)
    flat = jnp.concatenate(
        [leaf.reshape(kk, -1).astype(acc_dtype) for leaf in leaves], axis=1)
    if isinstance(noise_std, (int, float)) and noise_std == 0:
        # statically noise-free: skip the model-sized Gaussian draw entirely
        z = jnp.zeros((flat.shape[1],), acc_dtype)
    else:
        z = flat_awgn(key, leaves, dtype=acc_dtype)
    agg = aircomp_aggregate_flat(flat, weights, z, noise_std=noise_std, k=k,
                                 use_pallas=use_pallas)
    out, off = [], 0
    for leaf in leaves:
        size = int(leaf[0].size)
        out.append(agg[off:off + size].reshape(leaf.shape[1:])
                   .astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def aircomp_psum_tree(trees_local, weights_local, key, noise_std=0.0, k=None,
                      axis_name: str = "clients"):
    """Population-sharded eq. (10): local weighted partial-sum + ``psum``.

    ``trees_local``: the [n_local, ...] stacked updates of THIS shard's
    clients; ``weights_local`` [n_local]: their mask/gain entries. Each leaf
    is partially summed over the local clients, all-reduced across the
    ``clients`` mesh axis — the over-the-air multiple-access superposition
    IS this all-reduce (module docstring) — then the replicated AWGN is
    added and the 1/K applied. The noise uses the per-leaf key discipline of
    :func:`aircomp_aggregate_tree` with the same (replicated) key on every
    device, so the sharded aggregate differs from the dense reference only
    in the cross-shard summation order of the partial sums.

    ``k`` must be the GLOBAL scheduled count (computed from the replicated
    full-N mask); it is not derivable from ``weights_local`` alone.
    """
    if k is None:
        k = jax.lax.psum(jnp.sum(weights_local), axis_name)
    leaves, treedef = jax.tree_util.tree_flatten(trees_local)
    keys = jax.random.split(key, len(leaves))
    static_noise_free = isinstance(noise_std, (int, float)) and noise_std == 0
    out = []
    for leaf, kk in zip(leaves, keys, strict=True):
        mshape = (-1,) + (1,) * (leaf.ndim - 1)
        total = jax.lax.psum(
            jnp.sum(leaf * weights_local.reshape(mshape), axis=0), axis_name)
        if not static_noise_free:
            total = total + noise_std * jax.random.normal(
                kk, total.shape, total.dtype)
        out.append(total / k)
    return jax.tree_util.tree_unflatten(treedef, out)
