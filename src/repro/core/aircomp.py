"""Over-the-air (AirComp) model aggregation (paper eqs. 1 and 10).

With channel-inversion power control, each selected client pre-scales its
analog symbols by 1/h so the superposed signal received by the PS is the plain
sum of the K transmitted models plus receiver noise:

    w̄^(t+1) = ( Σ_{i∈D} w_i^(t+1) + z^(t) ) / K            (eq. 10)

On TPU the multiple-access superposition maps onto the ICI all-reduce; the
AWGN z is injected from a PRNG key to preserve the algorithm's statistics
(DESIGN.md §2). Both a stacked-tensor form (simulator tier) and a pytree form
(production tier) are provided. The Pallas kernel in
``repro.kernels.aircomp`` implements the fused stacked form for TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def aircomp_aggregate(
    stacked: jnp.ndarray,
    mask: jnp.ndarray,
    key,
    noise_std: float = 0.0,
    k: float | jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Aggregate stacked per-client tensors [N, ...] under participation mask.

    Returns (Σ_i mask_i·x_i + z)/K where K defaults to Σ mask (the paper uses
    the fixed K since the selected set always has size K).
    """
    if k is None:
        k = jnp.sum(mask)
    mshape = (-1,) + (1,) * (stacked.ndim - 1)
    summed = jnp.sum(stacked * mask.reshape(mshape), axis=0)
    # noise_std may be a traced scalar (sweep engine vmaps it); only skip the
    # draw when it is a *static* zero — a traced 0.0 adds exactly 0.
    if not (isinstance(noise_std, (int, float)) and noise_std == 0):
        summed = summed + noise_std * jax.random.normal(key, summed.shape, summed.dtype)
    return summed / k


def aircomp_aggregate_tree(trees, mask, key, noise_std: float = 0.0, k=None):
    """Pytree form: `trees` has leading client axis N on every leaf."""
    if k is None:
        k = jnp.sum(mask)
    leaves, treedef = jax.tree_util.tree_flatten(trees)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, kk in zip(leaves, keys):
        out.append(aircomp_aggregate(leaf, mask, kk, noise_std, k))
    return jax.tree_util.tree_unflatten(treedef, out)
