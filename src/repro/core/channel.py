"""Wireless channel model (paper §II and §IV-A).

i.i.d. block flat-fading Rayleigh channel h ~ CN(0, 1) per sub-carrier,
truncated at |h| >= 0.05, coherent for exactly one communication round (the
paper's most challenging scenario). The effective channel collapses the
per-sub-carrier channel-inversion powers by the harmonic mean (eq. 6):

    1/|h_i|^2 = (1/N_sc) * sum_b 1/|h_{i,b}|^2
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def draw_channels(
    key,
    num_clients: int,
    num_subcarriers: int,
    floor: float = 0.05,
    flat: bool = True,
):
    """Draw |h_{i,b}| magnitudes, shape [num_clients, num_subcarriers].

    |CN(0,1)| is Rayleigh with sigma = 1/sqrt(2) (unit mean-square). The
    truncation h >= floor is applied by clipping; the clipped mass is
    P(|h| < 0.05) = 1 - exp(-0.0025) ~= 0.25%, statistically negligible
    (documented deviation from resampling-style truncation).

    flat=True is the paper's §IV-A setting ("flat-fading Rayleigh channel
    block"): one coefficient per client per coherence block, identical across
    sub-carriers — eq. (6) then reduces to |h_i|. flat=False gives an
    independent frequency-selective draw per sub-carrier (ablation; the
    harmonic mean concentrates and the client-to-client energy spread —
    hence the achievable savings — shrinks).
    """
    draw_sc = 1 if flat else num_subcarriers
    re, im = jax.random.normal(key, (2, num_clients, draw_sc)) / jnp.sqrt(2.0)
    mag = jnp.sqrt(re**2 + im**2)
    mag = jnp.broadcast_to(mag, (num_clients, num_subcarriers)) if flat else mag
    return jnp.maximum(mag, floor)


def effective_channel(h_mag: jnp.ndarray) -> jnp.ndarray:
    """Effective channel |h_i| per eq. (6): sqrt of the harmonic mean of |h_b|^2.

    h_mag: [..., num_subcarriers] -> [...]
    """
    inv_sq = jnp.mean(1.0 / jnp.square(h_mag), axis=-1)
    return 1.0 / jnp.sqrt(inv_sq)
