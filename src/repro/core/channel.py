"""Wireless channel model (paper §II and §IV-A) + parameterized scenarios.

i.i.d. block flat-fading Rayleigh channel h ~ CN(0, 1) per sub-carrier,
truncated at |h| >= 0.05, coherent for exactly one communication round (the
paper's most challenging scenario). The effective channel collapses the
per-sub-carrier channel-inversion powers by the harmonic mean (eq. 6):

    1/|h_i|^2 = (1/N_sc) * sum_b 1/|h_{i,b}|^2

``ChannelScenario`` packages the physical-layer knobs as a pytree whose
*data* fields (truncation floor, receiver noise, psi/tau, shadowing,
per-client pathloss) are traced scalars/vectors, so a whole family of
scenarios can ride one ``vmap`` axis of the sweep engine
(``repro.core.sweep``) under a single compilation. Structural fields that
change the program itself (``flat``) are pytree *metadata*: scenarios that
differ in them land in different compilation groups.

With the default scenario, ``draw_channels_scenario`` consumes the PRNG key
identically to ``draw_channels`` and multiplies by exactly 1.0, so the
parameterized path reproduces the paper's setup bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.energy import TRUNCATION_FLOOR


def draw_channels(
    key,
    num_clients: int,
    num_subcarriers: int,
    floor: float = TRUNCATION_FLOOR,
    flat: bool = True,
):
    """Draw |h_{i,b}| magnitudes, shape [num_clients, num_subcarriers].

    |CN(0,1)| is Rayleigh with sigma = 1/sqrt(2) (unit mean-square). The
    truncation h >= floor is applied by clipping; the clipped mass is
    P(|h| < 0.05) = 1 - exp(-0.0025) ~= 0.25%, statistically negligible
    (documented deviation from resampling-style truncation).

    flat=True is the paper's §IV-A setting ("flat-fading Rayleigh channel
    block"): one coefficient per client per coherence block, identical across
    sub-carriers — eq. (6) then reduces to |h_i|. flat=False gives an
    independent frequency-selective draw per sub-carrier (ablation; the
    harmonic mean concentrates and the client-to-client energy spread —
    hence the achievable savings — shrinks).
    """
    draw_sc = 1 if flat else num_subcarriers
    re, im = jax.random.normal(key, (2, num_clients, draw_sc)) / jnp.sqrt(2.0)
    mag = jnp.sqrt(re**2 + im**2)
    mag = jnp.broadcast_to(mag, (num_clients, num_subcarriers)) if flat else mag
    return jnp.maximum(mag, floor)


def effective_channel(h_mag: jnp.ndarray) -> jnp.ndarray:
    """Effective channel |h_i| per eq. (6): sqrt of the harmonic mean of |h_b|^2.

    h_mag: [..., num_subcarriers] -> [...]
    """
    inv_sq = jnp.mean(1.0 / jnp.square(h_mag), axis=-1)
    return 1.0 / jnp.sqrt(inv_sq)


@dataclass(frozen=True)
class ChannelScenario:
    """Physical-layer scenario: traced knobs + structural metadata.

    Data fields accept Python floats or (possibly vmapped) jnp scalars;
    ``pathloss`` is a scalar or per-client [N] amplitude gain. ``flat`` is
    pytree metadata (static) because it changes the shape of the random draw.
    """

    floor: Any = TRUNCATION_FLOOR  # truncation |h| >= floor
    noise_std: Any = 0.0       # receiver AWGN std of eq. (10)
    psi: Any = 0.5e-3          # power-scaling factor (eq. 5)
    tau: Any = 1e-3            # symbol period
    shadowing_std: Any = 0.0   # log-normal shadowing std per coherence block
    pathloss: Any = 1.0        # large-scale amplitude gain, scalar or [N]
    flat: bool = True


jax.tree_util.register_dataclass(
    ChannelScenario,
    data_fields=["floor", "noise_std", "psi", "tau", "shadowing_std",
                 "pathloss"],
    meta_fields=["flat"],
)


def scenario_from_config(fl: FLConfig) -> ChannelScenario:
    """Build the traced scenario pytree from a (static) ``FLConfig``.

    ``pathloss_db_spread`` > 0 gives clients a deterministic large-scale gain
    profile spread uniformly (in dB) across ``[-spread/2, +spread/2]`` — the
    per-client energy heterogeneity the selection methods can exploit.
    """
    f32 = lambda v: jnp.asarray(v, jnp.float32)  # noqa: E731
    if fl.pathloss_db_spread:
        db = jnp.linspace(-fl.pathloss_db_spread / 2, fl.pathloss_db_spread / 2,
                          fl.num_clients, dtype=jnp.float32)
        pathloss = 10.0 ** (db / 20.0)
    else:
        pathloss = jnp.ones((fl.num_clients,), jnp.float32)
    return ChannelScenario(
        floor=f32(fl.channel_floor),
        noise_std=f32(fl.noise_std),
        psi=f32(fl.psi),
        tau=f32(fl.tau),
        shadowing_std=f32(fl.shadowing_std),
        pathloss=pathloss,
        flat=fl.flat_fading,
    )


def compose_channel(mag: jnp.ndarray, key, scenario: ChannelScenario,
                    num_clients: int, walk_gain=None) -> jnp.ndarray:
    """Large-scale composition: mag × i.i.d. shadow × pathloss, floor-clipped.

    THE single definition of the scenario's large-scale effects, shared by
    the static draw below and by ``dynamics.evolve_fading`` (whose shadowing
    random walk rides in as ``walk_gain``) — so the static and temporal
    paths cannot drift apart. The per-round i.i.d. shadow uses fold-in
    stream 1 of ``key``; `shadowing_std == 0` (and `pathloss == 1`,
    `walk_gain == 1`) multiplies by exactly 1.0, the identity.
    """
    shadow = jnp.exp(
        scenario.shadowing_std
        * jax.random.normal(jax.random.fold_in(key, 1), (num_clients, 1))
    )
    if walk_gain is not None:
        shadow = shadow * walk_gain
    pathloss = jnp.asarray(scenario.pathloss)
    if pathloss.ndim == 1:
        pathloss = pathloss[:, None]
    return jnp.maximum(mag * shadow * pathloss, scenario.floor)


def draw_channels_scenario(key, scenario: ChannelScenario, num_clients: int,
                           num_subcarriers: int) -> jnp.ndarray:
    """Scenario-parameterized channel draw, shape [num_clients, num_subcarriers].

    The Rayleigh small-scale draw consumes ``key`` exactly like
    ``draw_channels`` (same shapes, same stream); see ``compose_channel``
    for the large-scale key/identity discipline.
    """
    draw_sc = 1 if scenario.flat else num_subcarriers
    re, im = jax.random.normal(key, (2, num_clients, draw_sc)) / jnp.sqrt(2.0)
    mag = jnp.sqrt(re**2 + im**2)
    if scenario.flat:
        mag = jnp.broadcast_to(mag, (num_clients, num_subcarriers))
    return compose_channel(mag, key, scenario, num_clients)


# ---------------------------------------------------------------------------
# Content-addressed per-client draws (the control_plane="sharded" discipline).
#
# Every per-client random quantity is drawn from a stream addressed by the
# client's GLOBAL id: stream_i = fold_in(stream_key, id_i). A device holding
# rows ids=[d·n/D, ...) therefore draws exactly its own N/D rows — no full-[N]
# array ever exists — and any two devices (or the unsharded reference with
# ids=arange(N)) produce bit-identical values for the same client. This is
# the trick the quantizer's `_client_uniforms` (core/transport.py) already
# proves bit-stable across dense/gathered/sharded paths.
#
# RULE for adding new per-client randomness under this discipline: derive a
# fresh stream key (a new fold_in stream of the round's key split — never
# re-split a key an existing path consumes), then draw per client via
# client_keys(stream_key, ids). Keep the fold_in vmap SEPARATE from the draw
# vmap (fusing both into one vmapped closure lowers ~50× slower on CPU).
# ---------------------------------------------------------------------------


def client_keys(key, ids: jnp.ndarray):
    """One PRNG key per GLOBAL client id: keys[c] = fold_in(key, ids[c])."""
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, ids)


def client_normals(key, ids: jnp.ndarray, shape=()) -> jnp.ndarray:
    """[n, *shape] standard normals, content-addressed by client id."""
    keys = client_keys(key, ids)
    return jax.vmap(lambda k: jax.random.normal(k, shape))(keys)


def client_uniforms(key, ids: jnp.ndarray, shape=()) -> jnp.ndarray:
    """[n, *shape] U[0,1) draws, content-addressed by client id."""
    keys = client_keys(key, ids)
    return jax.vmap(lambda k: jax.random.uniform(k, shape))(keys)


def compose_channel_ids(mag: jnp.ndarray, key, scenario: ChannelScenario,
                        ids: jnp.ndarray, walk_gain=None) -> jnp.ndarray:
    """Per-id large-scale composition: mag × shadow × pathloss, floor-clipped.

    The ``control_plane="sharded"`` counterpart of :func:`compose_channel`:
    the per-round i.i.d. shadow is content-addressed on stream 1 of ``key``
    (one scalar normal per client id), and a per-client [N] ``pathloss`` is
    indexed by ``ids`` — an O(N) *input* is still fine, it is the O(N)
    *draws* this discipline eliminates.
    """
    shadow = jnp.exp(
        scenario.shadowing_std
        * client_normals(jax.random.fold_in(key, 1), ids)
    )[:, None]
    if walk_gain is not None:
        shadow = shadow * walk_gain
    pathloss = jnp.asarray(scenario.pathloss)
    if pathloss.ndim == 1:
        pathloss = pathloss[ids]
    pathloss = jnp.reshape(pathloss, (-1, 1)) if pathloss.ndim else pathloss
    return jnp.maximum(mag * shadow * pathloss, scenario.floor)


def rayleigh_mag_ids(key, scenario: ChannelScenario, ids: jnp.ndarray,
                     num_subcarriers: int) -> jnp.ndarray:
    """Per-id small-scale |CN(0,1)| magnitudes, [n, num_subcarriers]."""
    draw_sc = 1 if scenario.flat else num_subcarriers
    re_im = client_normals(key, ids, (2, draw_sc)) / jnp.sqrt(2.0)
    mag = jnp.sqrt(re_im[:, 0] ** 2 + re_im[:, 1] ** 2)  # [n, draw_sc]
    if scenario.flat:
        mag = jnp.broadcast_to(mag, (ids.shape[0], num_subcarriers))
    return mag


def draw_channels_scenario_ids(key, scenario: ChannelScenario,
                               ids: jnp.ndarray,
                               num_subcarriers: int) -> jnp.ndarray:
    """Content-addressed channel draw for the clients in ``ids``.

    Returns [n, num_subcarriers] magnitudes where row c depends only on
    ``(key, ids[c])`` — NOT on which device draws it or which other ids ride
    along — so sharded and unsharded programs of the ``"sharded"`` control
    plane see bit-identical channels per client.
    """
    mag = rayleigh_mag_ids(key, scenario, ids, num_subcarriers)
    return compose_channel_ids(mag, key, scenario, ids)


# ---------------------------------------------------------------------------
# Scenario registry: named FLConfig overrides. Adding a scenario is one entry
# here — the sweep engine (repro.core.sweep.expand_grid) crosses these with
# method/hyperparameter variants, and any number of entries that share the
# same structural fields (e.g. flat_fading) share one compilation.
# ---------------------------------------------------------------------------

SCENARIOS: dict[str, dict] = {
    # the paper's §IV-A setup: flat block Rayleigh fading, clean receiver
    "default": {},
    # independent per-sub-carrier fading (eq. 6 harmonic mean concentrates)
    "freq_selective": {"flat_fading": False},
    # receiver AWGN on the aggregated signal (eq. 10 z-term)
    "noisy_uplink": {"noise_std": 1e-2},
    # log-normal shadowing on top of fast fading, redrawn per coherence block
    "deep_shadowing": {"shadowing_std": 0.5},
    # deterministic 12 dB spread of large-scale gains across clients
    "heterogeneous_pathloss": {"pathloss_db_spread": 12.0},
    # harsher truncation: the worst channels are clipped up, shrinking the
    # client-to-client energy spread CA-AFL exploits
    "high_floor": {"channel_floor": 0.2},
    # ---- temporal scenarios (repro.core.dynamics ChannelProcess) ----------
    # Gauss-Markov correlated block fading: a client's channel (hence its
    # upload energy) persists across rounds, so greedy/CA-AFL selection keeps
    # hitting the same lucky clients — the starvation regime AFL's λ fights
    "markov_fading": {"temporal": True, "rho_fading": 0.9},
    # commuters: strongly correlated fading + a slow shadowing walk (moving
    # through the cell) + clients leaving/rejoining coverage
    "commuter_mobility": {"temporal": True, "rho_fading": 0.85,
                          "rho_shadow": 0.98, "shadow_walk_std": 0.08,
                          "p_dropout": 0.08, "p_return": 0.3},
    # finite per-client battery budgets (Sun et al.-style): uploads deplete
    # eqs. (3-6) energy; exhausted clients drop out of the schedulable pool
    "battery_constrained": {"temporal": True, "battery_init": 0.01},
}
