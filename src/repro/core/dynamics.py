"""Temporal scenario dynamics: the ``ChannelProcess`` layer.

PR 1's :class:`~repro.core.channel.ChannelScenario` parameterizes a *static*
physical layer — every round redraws the channel i.i.d. (the paper's §IV-A
block-fading). This module turns that draw into a stateful temporal process,
the regime of Sun et al. (battery-constrained dynamic scheduling) and Yang et
al. (device selection under realistic fading):

  - **Gauss-Markov fading** — the complex small-scale coefficients evolve as
    ``g_t = ρ·g_{t-1} + sqrt(1-ρ²)·ε_t`` (Jakes-style first-order model; the
    correlation coefficient ``rho_fading`` is a traced, sweepable knob). At
    ρ=0 the update is exactly the i.i.d. redraw.
  - **Shadowing random walk** — a slow AR(1) walk in the log domain on top of
    (and independent from) the scenario's per-round i.i.d. shadowing.
  - **Availability** — a per-client two-state Markov chain
    (available ⇄ unavailable, rates ``p_dropout`` / ``p_return``). An
    unavailable client cannot be scheduled by ANY selection method and does
    not participate in the ascent set.
  - **Battery budgets** — each client starts with ``battery_init`` Joules;
    every upload depletes it by the eqs. (3-6) transmit energy, and a client
    that cannot afford this round's upload is excluded from selection (so
    batteries never go negative).

Carry / compilation contract
----------------------------
``ChannelProcess`` is a pytree whose *data* fields are traced scalars (they
ride a ``vmap`` axis of the sweep engine like every other knob) and whose
single *structural* field ``temporal`` is pytree metadata. ``temporal`` is
part of the sweep compilation-group signature (``sweep.STATIC_FIELDS``):

  - ``temporal=False`` compiles to exactly today's stateless program — the
    scan carry gains only an empty ``chan_state = ()`` leaf-less slot, and
    the per-round key consumption is untouched, so default scenarios are
    bit-for-bit identical to PR 1.
  - ``temporal=True`` carries a :class:`ChanState` through the scan. Any
    number of dynamic scenarios (Markov fading, mobility, battery, or all
    knobs zeroed into a degenerate i.i.d. process) share ONE compilation per
    selection method, and the degenerate process reproduces the static
    trajectories bit-for-bit (pinned by ``tests/test_dynamics.py``).

Key discipline: all process draws derive from ``fold_in``s of the round's
``k_chan`` (streams 1/2/3), so the static path's streams are never perturbed.
Future scenarios must extend :class:`ChanState` (a new carry leaf), keep
their knobs as data fields, and reserve new ``fold_in`` streams — never
re-split a key the static path consumes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.channel import (client_normals, client_uniforms,
                                compose_channel, compose_channel_ids,
                                effective_channel)
from repro.core.transport import downlink_energy, uplink_energy


@dataclass(frozen=True)
class ChannelProcess:
    """Temporal-process knobs: traced data + the structural ``temporal`` flag."""

    rho_fading: Any = 0.0       # Gauss-Markov correlation of fast fading
    rho_shadow: Any = 0.0       # AR(1) coefficient of the log-shadow walk
    shadow_walk_std: Any = 0.0  # per-round innovation std of the walk
    p_dropout: Any = 0.0        # P(available -> unavailable) per round
    p_return: Any = 1.0         # P(unavailable -> available) per round
    battery_init: Any = jnp.inf  # per-client budget (Joules); inf = unlimited
    temporal: bool = False


jax.tree_util.register_dataclass(
    ChannelProcess,
    data_fields=["rho_fading", "rho_shadow", "shadow_walk_std", "p_dropout",
                 "p_return", "battery_init"],
    meta_fields=["temporal"],
)


class ChanState(NamedTuple):
    """Per-round carry of the temporal process (the ``chan_state`` leaf)."""

    fast: jnp.ndarray        # [2, N, draw_sc] complex fading state (re, im)
    log_shadow: jnp.ndarray  # [N] shadowing-walk state (log domain)
    avail: jnp.ndarray       # [N] 0/1 availability
    battery: jnp.ndarray     # [N] remaining Joules


def process_from_config(fl: FLConfig) -> ChannelProcess:
    """Promote the ``FLConfig`` process knobs to f32 traced scalars."""
    f32 = lambda v: jnp.asarray(v, jnp.float32)  # noqa: E731
    return ChannelProcess(
        rho_fading=f32(fl.rho_fading),
        rho_shadow=f32(fl.rho_shadow),
        shadow_walk_std=f32(fl.shadow_walk_std),
        p_dropout=f32(fl.p_dropout),
        p_return=f32(fl.p_return),
        battery_init=f32(fl.battery_init),
        temporal=fl.temporal,
    )


def init_chan_state(process: ChannelProcess, key, num_clients: int,
                    num_subcarriers: int, flat: bool) -> ChanState:
    """Stationary initial state: fading at its CN(0,1) stationary law, the
    shadow walk at its log-domain mean, everyone available, batteries full."""
    draw_sc = 1 if flat else num_subcarriers
    fast = jax.random.normal(key, (2, num_clients, draw_sc)) / jnp.sqrt(2.0)
    return ChanState(
        fast=fast,
        log_shadow=jnp.zeros((num_clients,), jnp.float32),
        avail=jnp.ones((num_clients,), jnp.float32),
        battery=jnp.broadcast_to(
            jnp.asarray(process.battery_init, jnp.float32), (num_clients,)),
    )


def init_chan_state_ids(process: ChannelProcess, key, ids,
                        num_subcarriers: int, flat: bool) -> ChanState:
    """Content-addressed stationary init for the clients in ``ids`` (the
    ``control_plane="sharded"`` discipline, ``core/channel.py``): each row of
    the fading state depends only on (key, id), so a device initializes only
    its own N/D rows and any sharding of the population agrees bit-for-bit
    per client."""
    draw_sc = 1 if flat else num_subcarriers
    n = ids.shape[0]
    fast = jnp.moveaxis(
        client_normals(key, ids, (2, draw_sc)) / jnp.sqrt(2.0), 0, 1)
    return ChanState(
        fast=fast,
        log_shadow=jnp.zeros((n,), jnp.float32),
        avail=jnp.ones((n,), jnp.float32),
        battery=jnp.broadcast_to(
            jnp.asarray(process.battery_init, jnp.float32), (n,)),
    )


def evolve_fading(key, scenario, process: ChannelProcess, state: ChanState,
                  num_clients: int, num_subcarriers: int):
    """One Gauss-Markov step; returns (h_mag [N, N_sc], fast', log_shadow').

    Consumes ``key`` exactly like ``channel.draw_channels_scenario`` (the
    innovation draw uses ``key`` itself, per-round i.i.d. shadowing uses
    stream 1) and adds the walk innovation on stream 2 — so the degenerate
    process (ρ=0, walk std 0) reproduces the static draw bit-for-bit.
    """
    flat = scenario.flat
    draw_sc = 1 if flat else num_subcarriers
    eps = jax.random.normal(key, (2, num_clients, draw_sc)) / jnp.sqrt(2.0)
    rho = process.rho_fading
    fast = rho * state.fast + jnp.sqrt(jnp.clip(1.0 - jnp.square(rho), 0.0)) * eps
    mag = jnp.sqrt(fast[0] ** 2 + fast[1] ** 2)
    if flat:
        mag = jnp.broadcast_to(mag, (num_clients, num_subcarriers))
    log_shadow = (
        process.rho_shadow * state.log_shadow
        + process.shadow_walk_std
        * jax.random.normal(jax.random.fold_in(key, 2), (num_clients,))
    )
    h_mag = compose_channel(mag, key, scenario, num_clients,
                            walk_gain=jnp.exp(log_shadow)[:, None])
    return h_mag, fast, log_shadow


def evolve_fading_ids(key, scenario, process: ChannelProcess,
                      state: ChanState, ids, num_subcarriers: int):
    """Content-addressed Gauss-Markov step for the clients in ``ids``.

    Stream layout mirrors :func:`evolve_fading` exactly — innovation on
    ``key`` itself, i.i.d. shadow on stream 1, walk innovation on stream 2 —
    but every draw is per-client fold_in(stream, id), so a device evolves
    only its own rows of ``state`` and the values per client are independent
    of the sharding.
    """
    flat = scenario.flat
    draw_sc = 1 if flat else num_subcarriers
    n = ids.shape[0]
    eps = jnp.moveaxis(
        client_normals(key, ids, (2, draw_sc)) / jnp.sqrt(2.0), 0, 1)
    rho = process.rho_fading
    fast = rho * state.fast + jnp.sqrt(jnp.clip(1.0 - jnp.square(rho), 0.0)) * eps
    mag = jnp.sqrt(fast[0] ** 2 + fast[1] ** 2)
    if flat:
        mag = jnp.broadcast_to(mag, (n, num_subcarriers))
    log_shadow = (
        process.rho_shadow * state.log_shadow
        + process.shadow_walk_std
        * client_normals(jax.random.fold_in(key, 2), ids)
    )
    h_mag = compose_channel_ids(mag, key, scenario, ids,
                                walk_gain=jnp.exp(log_shadow)[:, None])
    return h_mag, fast, log_shadow


def evolve_availability(key, process: ChannelProcess,
                        avail: jnp.ndarray, ids=None) -> jnp.ndarray:
    """One step of the per-client availability Markov chain (0/1 mask [N]).

    ``ids`` (control_plane="sharded"): per-client content-addressed uniforms
    instead of one full-[N] draw; ``avail`` then holds only those rows."""
    if ids is None:
        # lint: allow(sharded-randomness): replicated-discipline branch — ids is None draws the full [N] chain in one stream
        u = jax.random.uniform(key, avail.shape)
    else:
        u = client_uniforms(key, ids)
    stays = (u >= process.p_dropout).astype(jnp.float32)
    returns = (u < process.p_return).astype(jnp.float32)
    return jnp.where(avail > 0, stays, returns)


class ProcessStep(NamedTuple):
    """One pre-selection tick of the temporal process (both tiers use this)."""

    h: jnp.ndarray         # [N] effective channel (eq. 6)
    e_need: jnp.ndarray    # [N] eqs. (3-6) upload cost at this channel
    avail: jnp.ndarray     # [N] availability after the Markov step
    eligible: jnp.ndarray  # [N] recv ∧ can-afford-both: the schedulable pool
    fast: jnp.ndarray      # fading state to carry forward
    log_shadow: jnp.ndarray
    # downlink side (transport.downlink_energy): e_dl is the scalar
    # per-receiver broadcast cost this round, recv the [N] 0/1 mask of
    # clients that actually listen (available ∧ can afford the receive).
    # Both are exact zeros / equal to `avail` when dl_power = 0, keeping
    # the pre-downlink programs' values bit-for-bit.
    e_dl: jnp.ndarray = jnp.float32(0.0)
    recv: jnp.ndarray = jnp.float32(1.0)


def step_process(k_chan, scenario, process: ChannelProcess, state: ChanState,
                 num_clients: int, num_subcarriers: int, model_size: int,
                 scheme: str = "analog", tp=None, ids=None,
                 dl_num_tx: int = 1) -> ProcessStep:
    """Evolve fading + availability and price this round's uploads + the
    broadcast receive.

    The SINGLE implementation of the per-round process tick — the simulator's
    scan body and ``ParameterServer.step`` both call it, so the two tiers
    cannot drift in key streams or gating order. Selection happens between
    this and :func:`commit_process` (which depletes the transmitters' — and
    receivers' — batteries into the next carry).

    ``scheme``/``tp`` (``repro.core.transport``): uploads are priced under
    the configured uplink transport, so battery gating sees the scheme's
    actual cost — quantized clients afford more rounds at low ``bits``,
    digital clients pay the OFDMA rate/latency bill. The analog default is
    eqs. (3-6) verbatim. The downlink broadcast is priced too
    (``transport.downlink_energy``, ``dl_num_tx`` = the scheduled-set size
    bounding a sparse broadcast's support): a client RECEIVES iff it is
    available and can afford the listen, and is SCHEDULABLE iff it received
    and can additionally afford the upload — so batteries still never go
    negative. At the default ``dl_power = 0`` the receive is free,
    ``recv == avail`` and every gate/depletion value is bit-for-bit the
    pre-downlink program's (x + 0 = x, x − 0 = x).

    ``ids`` (control_plane="sharded"): ``state`` holds only these clients'
    rows and every draw is content-addressed by global id — the SAME stream
    roles (innovation on ``k_chan``, walk on stream 2, availability on
    stream 3), just addressed per client instead of per full-[N] array.
    """
    if ids is None:
        h_mag, fast, log_shadow = evolve_fading(
            k_chan, scenario, process, state, num_clients, num_subcarriers)
    else:
        h_mag, fast, log_shadow = evolve_fading_ids(
            k_chan, scenario, process, state, ids, num_subcarriers)
    h = effective_channel(h_mag)
    avail = evolve_availability(jax.random.fold_in(k_chan, 3), process,
                                state.avail, ids=ids)
    e_need = uplink_energy(scheme, tp, h, model_size, scenario)
    # tp=None is the bare-analog calling convention of older tests/tools:
    # analog pricing never reads the knobs, and a knob-less call gets the
    # free (pre-downlink) broadcast
    e_dl = (jnp.float32(0.0) if tp is None else
            downlink_energy(scheme, tp, model_size, scenario,
                            num_tx=dl_num_tx))
    recv = avail * (state.battery >= e_dl).astype(jnp.float32)
    eligible = recv * (state.battery >= e_need + e_dl).astype(jnp.float32)
    return ProcessStep(h=h, e_need=e_need, avail=avail, eligible=eligible,
                       fast=fast, log_shadow=log_shadow, e_dl=e_dl,
                       recv=recv)


def commit_process(step: ProcessStep, state: ChanState,
                   mask: jnp.ndarray) -> ChanState:
    """Post-selection: deplete the transmitters' (upload) and receivers'
    (broadcast listen) batteries."""
    return ChanState(fast=step.fast, log_shadow=step.log_shadow,
                     avail=step.avail,
                     battery=(state.battery - mask * step.e_need
                              - step.recv * step.e_dl))
