"""Distributionally-robust min-max machinery (paper P1 + Alg. 1 lines 10-15).

The ascent step updates the simplex weights with stochastic per-client losses
on K uniformly sampled clients, then projects back onto the simplex:

    λ~_i = λ_i + γ f_i(w̄; ξ~_i)   for i in U^(t)
    λ    = Π_Δ(λ~)

Two projections implement Π_Δ:

  - :func:`project_simplex` — the sort-based Held-Wolfe-Crowder / Duchi
    reference, O(N log N) and inherently global (the cumulative sum couples
    every coordinate). The replicated control plane uses it, and it is the
    small-N differential oracle the distributed projection is pinned against.
  - ``sharding.project_simplex_sharded`` — bisection on the water level θ
    (the root of the monotone g(θ) = Σ max(vᵢ − θ, 0) − 1): each device sums
    its own rows, one ``psum`` per iteration yields the global g, O(N/D +
    iters) per device with no gather and no sort. The
    ``control_plane="sharded"`` discipline routes here on BOTH tiers
    (simulator round and ``ParameterServer``), keeping the cross-tier λ
    contract intact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def project_simplex(v: jnp.ndarray) -> jnp.ndarray:
    """Euclidean projection of v onto the probability simplex (sort-based,
    Held-Wolfe-Crowder / Duchi et al. algorithm; O(N log N)).

    The cumulative sum and the θ reduction accumulate at f64 internally
    (cast back to the input dtype on exit): an f32 ``cumsum`` over N=10^6
    near-uniform entries drifts by ~N·ulp — enough to flip the support-size
    predicate ``u_k + (1 - css_k)/k > 0`` near ties and pick the wrong ρ,
    which moves probability mass between clients. ``canonicalize_dtype``
    keeps the promotion a no-op under the engine's default x64-disabled
    mode (bit-for-bit today's program); with ``jax_enable_x64`` on, the
    projection matches the f64 oracle at any N
    (``tests/test_lambda_control.py``).
    """
    n = v.shape[0]
    acc_dt = jax.dtypes.canonicalize_dtype(np.float64)
    u = jnp.sort(v)[::-1].astype(acc_dt)
    css = jnp.cumsum(u)
    k = jnp.arange(1, n + 1, dtype=acc_dt)
    cond = u + (1.0 - css) / k > 0
    rho = jnp.max(jnp.where(cond, k, 0.0))
    theta = ((jnp.sum(jnp.where(cond, u, 0.0)) - 1.0) / rho).astype(v.dtype)
    return jnp.maximum(v - theta, 0.0)


def lambda_ascent(
    lam: jnp.ndarray,
    losses: jnp.ndarray,
    ascent_mask: jnp.ndarray,
    gamma: float,
    *,
    local_rows: bool = False,
    axis_name: str | None = None,
) -> jnp.ndarray:
    """One ascent step of Alg. 1: update entries in U^(t), project to simplex.

    losses: [N] per-client stochastic losses f_i(w̄; ξ~) (only entries where
    ascent_mask==1 are used).

    ``local_rows`` / ``axis_name`` select the projection by row discipline
    (the ``control_plane="sharded"`` flag, ISSUE 8): when either is set,
    ``lam``/``losses``/``ascent_mask`` hold only this device's client rows
    and the projection is the psum-bisection
    ``sharding.project_simplex_sharded`` — ``axis_name`` names the clients
    mesh axis (None = all rows on one device, the unsharded reference
    program of the same discipline, used by ``ParameterServer``). The
    default routes to the sort-based :func:`project_simplex`, bit-for-bit
    the replicated-discipline program.
    """
    lam_tilde = lam + gamma * ascent_mask * losses
    if local_rows or axis_name is not None:
        from repro.core.sharding import project_simplex_sharded  # no cycle
        return project_simplex_sharded(lam_tilde, axis_name=axis_name)
    return project_simplex(lam_tilde)


def lambda_summary(lam: jnp.ndarray, axis_name: str | None = None):
    """O(1) λ diagnostics from (possibly sharded) rows: ``(max, entropy,
    effective support size)``.

    Computed as psum/pmax-of-local-rows — the distributed-projection rule
    (README "sharding contract"): never gather-then-reduce. The effective
    support size is the participation ratio 1/Σλ² (N for uniform λ, 1 for a
    point mass) — a smooth statistic, unlike a strict positive-count, so the
    mesh and unsharded programs agree to ulps rather than flipping on
    coordinates that sit exactly at the water level. Entropy uses the
    0·log 0 = 0 convention via a safe log.
    """
    lmax = jnp.max(lam)
    plogp = lam * jnp.log(jnp.where(lam > 0, lam, 1.0))
    ent = -jnp.sum(plogp)
    sq = jnp.sum(jnp.square(lam))
    if axis_name is not None:
        lmax = jax.lax.pmax(lmax, axis_name)
        ent = jax.lax.psum(ent, axis_name)
        sq = jax.lax.psum(sq, axis_name)
    return lmax, ent, 1.0 / jnp.maximum(sq, jnp.finfo(lam.dtype).tiny)
