"""Distributionally-robust min-max machinery (paper P1 + Alg. 1 lines 10-15).

The ascent step updates the simplex weights with stochastic per-client losses
on K uniformly sampled clients, then projects back onto the simplex:

    λ~_i = λ_i + γ f_i(w̄; ξ~_i)   for i in U^(t)
    λ    = Π_Δ(λ~)
"""
from __future__ import annotations

import jax.numpy as jnp


def project_simplex(v: jnp.ndarray) -> jnp.ndarray:
    """Euclidean projection of v onto the probability simplex (sort-based,
    Held-Wolfe-Crowder / Duchi et al. algorithm; O(N log N))."""
    n = v.shape[0]
    u = jnp.sort(v)[::-1]
    css = jnp.cumsum(u)
    k = jnp.arange(1, n + 1, dtype=v.dtype)
    cond = u + (1.0 - css) / k > 0
    rho = jnp.max(jnp.where(cond, k, 0.0))
    theta = (jnp.sum(jnp.where(cond, u, 0.0)) - 1.0) / rho
    return jnp.maximum(v - theta, 0.0)


def lambda_ascent(
    lam: jnp.ndarray,
    losses: jnp.ndarray,
    ascent_mask: jnp.ndarray,
    gamma: float,
) -> jnp.ndarray:
    """One ascent step of Alg. 1: update entries in U^(t), project to simplex.

    losses: [N] per-client stochastic losses f_i(w̄; ξ~) (only entries where
    ascent_mask==1 are used).
    """
    lam_tilde = lam + gamma * ascent_mask * losses
    return project_simplex(lam_tilde)
