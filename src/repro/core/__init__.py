"""The paper's primary contribution: channel-aware, energy-efficient,
distributionally-robust client selection (CA-AFL) + over-the-air aggregation."""
from repro.core.channel import (SCENARIOS, ChannelScenario, draw_channels,
                                draw_channels_scenario, effective_channel,
                                scenario_from_config)
from repro.core.dynamics import (ChannelProcess, ChanState, commit_process,
                                 evolve_availability, evolve_fading,
                                 init_chan_state, process_from_config,
                                 step_process)
from repro.core.energy import transmit_energy, round_energy
from repro.core.poe import energy_expert_pmf, product_of_experts, ca_afl_pmf
from repro.core.selection import (EXACT_K_METHODS, select_clients,
                                  select_clients_sparse, gumbel_topk_mask)
from repro.core.dro import project_simplex, lambda_ascent
from repro.core.aircomp import (aircomp_aggregate, aircomp_aggregate_tree,
                                aircomp_aggregate_stack_tree,
                                aircomp_psum_tree)
from repro.core.sharding import (cell_mesh, client_mesh, distributed_top_k,
                                 population_device_count)
from repro.core.sweep import (SweepPoint, SweepResult, expand_grid, run_sweep,
                              sweep_point_from_config)
