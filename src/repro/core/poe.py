"""Product-of-experts client-selection PMF (paper Prop. 1 + eqs. 7-9).

Two "experts" (PMFs over the N clients):
  - energy expert  y_i ∝ |h_i|^C   (Prop. 1; C = energy-conservation factor)
  - robustness expert = the AFL simplex weights λ_i
combined by the PoE rule (eq. 8):

    ρ_i = λ_i · y_i / Σ_j λ_j · y_j  =  λ_i |h_i|^C / Σ_j λ_j |h_j|^C   (eq. 9)

All computations are done in log space (a softmax over C·log|h| + log λ) so
that C up to hundreds stays finite; at C→∞ the PMF provably collapses onto the
argmax channel (Prop. 2), which the log-space form reproduces exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def energy_expert_pmf(h_eff: jnp.ndarray, C: float) -> jnp.ndarray:
    """y_i = |h_i|^C / Σ_j |h_j|^C, computed as softmax(C log|h|)."""
    logits = C * jnp.log(h_eff)
    return jax.nn.softmax(logits)


def product_of_experts(*pmfs: jnp.ndarray) -> jnp.ndarray:
    """Normalized elementwise product of expert PMFs (Hinton-style PoE)."""
    log_p = sum(jnp.log(jnp.clip(p, 1e-38)) for p in pmfs)
    return jax.nn.softmax(log_p)


def ca_afl_logits(lam: jnp.ndarray, h_eff: jnp.ndarray, C: float) -> jnp.ndarray:
    """log(λ_i) + C·log|h_i| — unnormalized log of eq. (9)."""
    return jnp.log(jnp.clip(lam, 1e-38)) + C * jnp.log(h_eff)


def ca_afl_pmf(lam: jnp.ndarray, h_eff: jnp.ndarray, C: float) -> jnp.ndarray:
    """ρ^(t) of eq. (9)."""
    return jax.nn.softmax(ca_afl_logits(lam, h_eff, C))
