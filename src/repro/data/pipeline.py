"""Per-client data pipeline: shard ownership + deterministic batch iterators."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass
class ClientDataset:
    """One client's local shard."""

    x: np.ndarray
    y: np.ndarray

    def __len__(self) -> int:
        return len(self.x)

    def batch(self, batch_size: int, rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
        idx = rng.choice(len(self.x), size=batch_size, replace=len(self.x) < batch_size)
        return self.x[idx], self.y[idx]


def client_batch_iterator(
    ds: ClientDataset, batch_size: int, seed: int = 0
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Infinite deterministic batch stream for one client."""
    rng = np.random.default_rng(seed)
    while True:
        yield ds.batch(batch_size, rng)
