from repro.data.synthetic import make_fmnist_like, make_lm_tokens
from repro.data.pipeline import ClientDataset, client_batch_iterator
