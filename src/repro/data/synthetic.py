"""Offline synthetic datasets.

The container has no network access, so the paper's Fashion-MNIST is replaced
by a *structurally equivalent* synthetic dataset: 10 classes, 784-dim inputs,
60k train / 10k test, with overlapping class prototypes so that logistic
regression saturates below 100% (mimicking FMNIST's ~84% linear separability).
All of the paper's mechanisms (sorted-label sharding, heterogeneity, DRO
dynamics, energy accounting) are dataset-agnostic; EXPERIMENTS.md validates
the paper's *claims* (energy ratios, worst-client orderings) on this proxy.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_fmnist_like(
    num_train: int = 60_000,
    num_test: int = 10_000,
    num_classes: int = 10,
    dim: int = 784,
    noise: float = 0.30,
    difficulty_spread: float = 1.0,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (x_train, y_train, x_test, y_test), x in float32, y in int32.

    Class prototypes are drawn on a sphere with pairwise overlaps; `noise` is
    the per-dimension noise std and controls the Bayes error. Classes are
    *asymmetrically* difficult (class c gets noise multiplier in
    [1-spread/2, 1+spread/2]), mirroring FMNIST where shirt/pullover/coat are
    much harder than sandal/bag — this asymmetry is what DRO exploits, and it
    is required to reproduce the paper's ~10% worst-client-accuracy gap
    between AFL-style methods and FedAvg (Fig. 2b). The default noise is
    calibrated so logistic regression converges to ~80% average test accuracy
    (Fig. 2a).
    """
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(num_classes, dim)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    # overlap structure: each class leans towards its neighbour (like
    # shirt/pullover/coat confusions in FMNIST), with *increasing* overlap for
    # later classes so the hard classes form confusable pairs whose shared
    # decision boundary placement matters — the structure DRO exploits.
    overlap = 0.1 + 0.35 * np.arange(num_classes) / max(num_classes - 1, 1)
    protos = (1 - overlap[:, None]) * protos + overlap[:, None] * np.roll(protos, 1, axis=0)
    cls_noise = noise * (1.0 + difficulty_spread * (
        np.arange(num_classes) / max(num_classes - 1, 1) - 0.5
    )).astype(np.float32)

    def _draw(n, seed_off):
        r = np.random.default_rng(seed + seed_off)
        y = np.repeat(np.arange(num_classes), n // num_classes).astype(np.int32)
        r.shuffle(y)
        x = protos[y] + cls_noise[y][:, None] * r.normal(size=(n, dim)).astype(np.float32)
        return x.astype(np.float32), y

    x_tr, y_tr = _draw(num_train, 1)
    x_te, y_te = _draw(num_test, 2)
    return x_tr, y_tr, x_te, y_te


def make_lm_tokens(
    num_clients: int,
    tokens_per_client: int,
    vocab_size: int,
    heterogeneity: float = 0.9,
    seed: int = 0,
) -> np.ndarray:
    """Synthetic LM corpus: [num_clients, tokens_per_client] int32.

    Each client samples from a client-specific Zipf-permuted unigram mixture;
    `heterogeneity` in [0,1] interpolates uniform-shared -> fully client-local
    token distributions (the LM analogue of sorted-label sharding).
    """
    rng = np.random.default_rng(seed)
    base = 1.0 / np.arange(1, vocab_size + 1) ** 1.1  # zipf
    base /= base.sum()
    out = np.empty((num_clients, tokens_per_client), dtype=np.int32)
    for c in range(num_clients):
        perm = np.random.default_rng(seed + 1000 + c).permutation(vocab_size)
        local = base[perm]
        mix = (1 - heterogeneity) * base + heterogeneity * local
        mix /= mix.sum()
        out[c] = rng.choice(vocab_size, size=tokens_per_client, p=mix).astype(np.int32)
    return out
