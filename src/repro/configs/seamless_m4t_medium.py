"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596].

12L (12 encoder + 12 decoder) d_model=1024 16H (kv=16, MHA) d_ff=4096
vocab=256206. The mel-spectrogram + conv feature extractor frontend is STUBBED
per the assignment carve-out — ``input_specs()`` provides precomputed frame
embeddings of shape (batch, num_audio_frames, d_model).

long_500k is SKIPPED for this arch (see DESIGN.md §Shape skips): an
encoder-decoder speech model has no meaningful 524k-token autoregressive decode.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596",
    num_layers=12,
    encoder_layers=12,
    decoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    rope_theta=10_000.0,
    num_audio_frames=1024,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, encoder_layers=2, decoder_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512,
        num_audio_frames=32,
    )
