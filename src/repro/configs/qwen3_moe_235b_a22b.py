"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

94L d_model=4096 64H (GQA kv=4, head_dim=128) d_ff=1536/expert vocab=151936,
MoE 128e top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    rope_theta=1e6,
    num_experts=128,
    experts_per_token=8,
    window=8192,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=128, vocab_size=512, num_experts=4, experts_per_token=2, window=64,
    )
