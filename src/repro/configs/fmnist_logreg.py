"""The paper's own model: logistic regression on Fashion-MNIST, M = 7850.

784-dim inputs, 10 classes -> 784*10 + 10 = 7850 parameters, exactly the M
used in the paper's energy model (Section IV-A).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="fmnist-logreg",
    family="logreg",
    source="paper §IV-A",
    d_model=784,
    vocab_size=10,  # num classes
    dtype="float32",
)


def reduced() -> ModelConfig:
    return CONFIG
