"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4, head_dim=128) d_ff=768/expert vocab=151936,
MoE 128e top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    rope_theta=1e6,
    num_experts=128,
    experts_per_token=8,
    window=8192,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=128, vocab_size=512, num_experts=4, experts_per_token=2, window=64,
    )
