"""qwen2-0.5b [dense] — GQA, QKV bias [arXiv:2407.10671].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    window=8192,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, d_ff=512,
        vocab_size=512, window=64,
    )
