"""llama-3.2-vision-11b [vlm] — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256. A cross-attention
(image) layer every 5 self-attention layers; the ViT vision encoder + projector
is STUBBED per the assignment carve-out — ``input_specs()`` provides precomputed
patch embeddings of shape (batch, num_image_tokens, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    num_image_tokens=1601,
    window=8192,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, d_ff=512,
        vocab_size=512, cross_attn_every=2, num_image_tokens=16, window=64,
    )
