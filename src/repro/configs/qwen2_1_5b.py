"""qwen2-1.5b [dense] — GQA, QKV bias [arXiv:2407.10671].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    window=8192,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, d_ff=512,
        vocab_size=512, window=64,
    )
