"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

38L d_model=2048 32H (GQA kv=32 -> MHA shared block) d_ff=8192 vocab=32000,
ssm_state=64. One *shared* (single param set) attention+MLP block applied every
6 Mamba2 layers, as in the Zamba family.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    rope_theta=10_000.0,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    shared_attn_every=6,
    window=8192,  # the shared attention block runs sliding-window at 500k
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
        vocab_size=512, ssm_state=16, ssm_headdim=32, ssm_chunk=32,
        shared_attn_every=2, window=64,
    )
