"""granite-34b [dense] — llama-arch code model [arXiv:2405.04324].

88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=10_000.0,
    window=8192,  # sliding-window variant used only for long_500k
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=1, d_ff=512,
        vocab_size=512, window=64,
    )
