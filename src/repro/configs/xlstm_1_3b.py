"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H (kv=4) d_ff=0 (block-internal up-projection) vocab=50304.
Block layout: one sLSTM block per group of 8 (7 mLSTM + 1 sLSTM), scanned over
6 homogeneous super-blocks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_expand=2,
    slstm_group=8,
)


def reduced() -> ModelConfig:
    # 2 super-blocks of (1 mLSTM + 1 sLSTM) = 4 layers, d_model 256
    return CONFIG.with_(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
        vocab_size=512, slstm_group=2,
    )
