"""Config dataclasses for model architectures, input shapes and FL runs.

Every assigned architecture gets one module in this package defining a
``CONFIG`` (full production scale, exercised only via the dry-run) and a
``reduced()`` smoke variant (<=2 layers, d_model<=512, <=4 experts) that runs
a real forward/train step on CPU.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple, Optional


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation bracket from the assignment

    # transformer backbone
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # sliding-window attention variant (used for long_500k on attention archs)
    window: Optional[int] = None
    # serving uses the rolling window cache only at/beyond this many positions
    # (decode_32k stays exact full-attention; long_500k goes sub-quadratic)
    long_context_threshold: int = 131072

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_aux_coef: float = 1e-2
    moe_capacity_factor: float = 1.25  # tokens/expert cap = S*k*cf/E

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # hybrid (zamba2): one *shared* attention block applied every k ssm layers
    shared_attn_every: int = 0

    # xLSTM: one sLSTM block per `slstm_group` layers (rest mLSTM)
    slstm_group: int = 0

    # VLM: a cross-attention (image) layer every k self-attn layers
    cross_attn_every: int = 0
    num_image_tokens: int = 1601  # ViT patch-embedding count (stubbed frontend)

    # audio / encoder-decoder
    encoder_layers: int = 0
    decoder_layers: int = 0
    num_audio_frames: int = 1024  # stubbed conv-codec frontend output length

    # numerics
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_encdec(self) -> bool:
        return self.family == "audio"

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm" or self.name.startswith("zamba")

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


class GCAParams(NamedTuple):
    """GCA [10] selection knobs. Plain floats in a config; the sweep engine
    promotes them to traced scalars so a whole GCA hyperparameter grid rides
    one vmap axis (re-exported from ``repro.core.selection`` for back-compat).
    """

    lambda_E: float = 0.5
    lambda_V: float = 0.5
    rho1: float = 0.5
    rho2: float = 0.5
    sigma_t: float = 1.0
    alpha: float = 1500.0


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning run configuration (paper's Section IV defaults)."""

    num_clients: int = 100          # N
    clients_per_round: int = 40     # K
    rounds: int = 500               # T
    batch_size: int = 50
    lr0: float = 0.1                # eta^(0)
    lr_decay: float = 0.998
    ascent_lr: float = 8e-3         # gamma
    energy_C: float = 8.0           # energy-conservation tuning factor C
    local_steps: int = 1
    # Full N-client test-set eval cadence (STRUCTURAL: joins the sweep
    # compilation-group signature). 1 = the paper's per-round eval; E > 1
    # evaluates every E-th round and forward-fills the accuracy metrics in
    # between, so the O(N · test-set) eval stops dominating long runs where
    # only the selected K clients do model-sized descent work per round.
    eval_every: int = 1
    # λ-history recording cadence (STRUCTURAL: joins the sweep compilation-
    # group signature, following the `eval_every` precedent). 1 = the dense
    # per-round [T, N] ``SimHistory.lam`` — today's programs bit-for-bit.
    # E > 1 records strided [ceil(T/E), N] snapshots (rounds t % E == 0) via
    # a fixed-size scan-carry buffer; 0 drops the λ history leaf entirely
    # (the leaf-less ``()``), so an N=10^6 × T=500 run stops costing 2 GB of
    # history. The O(T) λ summary leaves (max / entropy / effective support
    # size) are recorded per round at EVERY setting.
    record_lambda_every: int = 1
    # channel / physical layer
    num_subcarriers: int = 64       # N_sc
    flat_fading: bool = True        # paper §IV-A: flat-fading channel block
    channel_floor: float = 0.05     # truncation h >= 0.05
    psi: float = 0.5e-3             # scaling factor psi = 0.5 mW
    tau: float = 1e-3               # symbol period (LTE, 1 ms)
    noise_std: float = 0.0          # AWGN std on the aggregated signal (eq. 10)
    # scenario heterogeneity beyond the paper (0 => the paper's i.i.d. setup)
    shadowing_std: float = 0.0      # log-normal shadowing std per coherence block
    pathloss_db_spread: float = 0.0  # per-client large-scale gain spread (dB)
    # uplink transport scheme (repro.core.transport). `transport` is
    # STRUCTURAL: it selects the aggregation/energy program (analog AirComp /
    # stochastic-rounding quantized AirComp / digital OFDMA) and joins the
    # sweep compilation-group signature; the knobs below it are traced
    # (sweepable) TransportParams data. "analog" compiles to exactly the
    # pre-transport program.
    transport: str = "analog"       # analog | quantized | digital | sparse
    quant_bits: float = 8.0         # payload precision (bits per parameter)
    tx_power: float = 0.1           # digital uplink transmit power P (W)
    ofdma_bandwidth: float = 1e5    # digital per-client OFDMA subband B (Hz)
    rx_noise: float = 1e-2          # digital receiver noise+interference (W)
    # sparse (error-feedback top-k) transport. `sparse_density` is STRUCTURAL:
    # it bakes the static per-row coordinate count k = max(1, round(d·P)) into
    # the compiled top-k, so it joins STATIC_FIELDS like `transport` itself.
    sparse_density: float = 0.05    # kept fraction of coordinates per upload
    # downlink broadcast receive power (W) — prices the per-round global-model
    # broadcast in transport.downlink_energy. Traced knob; the default 0.0
    # keeps every pre-downlink ledger/battery trajectory bit-for-bit (x−0=x).
    dl_rx_power: float = 0.0
    # temporal scenario dynamics (repro.core.dynamics). `temporal` is
    # STRUCTURAL: it switches the simulator/server onto the stateful
    # ChannelProcess path and joins the sweep compilation-group signature;
    # everything below it is a traced (sweepable) knob of that path. All
    # defaults keep the paper's i.i.d. per-round block-fading setup.
    temporal: bool = False          # enable the ChannelProcess carry
    rho_fading: float = 0.0         # Gauss-Markov (Jakes) fast-fading correlation
    rho_shadow: float = 0.0         # AR(1) coefficient of the shadowing walk
    shadow_walk_std: float = 0.0    # per-round innovation std of the log-shadow walk
    p_dropout: float = 0.0          # P(available -> unavailable) per round
    p_return: float = 1.0           # P(unavailable -> available) per round
    battery_init: float = float("inf")  # per-client battery budget (Joules)
    method: str = "ca_afl"          # ca_afl | afl | fedavg | greedy | gca
    gca: GCAParams = GCAParams()    # GCA hyperparameters (sweepable)
    # Control-plane randomness discipline (STRUCTURAL: selects the per-round
    # program and joins the sweep compilation-group signature).
    #   "replicated" — every [N]-shaped draw (channels, Gumbel, availability,
    #     batch indices) is a full-population array from one key; under a
    #     clients mesh each device draws all N rows and slices its own. This
    #     is the pre-ISSUE-7 program, byte-for-byte.
    #   "sharded"    — per-client draws are content-addressed by GLOBAL
    #     client id (fold_in streams, the quantizer's trick), so a device
    #     materializes only its N/D rows and exact-K selection runs as a
    #     hierarchical tree top-k. The mesh run is bit-identical to the
    #     single-device run of the SAME discipline; the million-client
    #     regime requires it (see core/sharding.py).
    control_plane: str = "replicated"  # replicated | sharded
    seed: int = 0
