"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

Arch ids match the assignment exactly (``--arch <id>`` on all launchers).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES, FLConfig

_MODULES: Dict[str, str] = {
    "granite-34b": "granite_34b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2-7b": "qwen2_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-0.5b": "qwen2_0_5b",
    "xlstm-1.3b": "xlstm_1_3b",
    "qwen2-1.5b": "qwen2_1_5b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "fmnist-logreg": "fmnist_logreg",
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "fmnist-logreg"]

# (arch, shape) pairs that are skipped, with the reason recorded in DESIGN.md.
SHAPE_SKIPS = {
    ("seamless-m4t-medium", "long_500k"):
        "enc-dec speech model: no meaningful 524k-token autoregressive decode",
}


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def get_shape(shape: str) -> InputShape:
    return INPUT_SHAPES[shape]


def all_pairs(include_skipped: bool = False):
    """Every (arch, shape) pair in the assignment, minus documented skips."""
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            if not include_skipped and (arch, shape) in SHAPE_SKIPS:
                continue
            yield arch, shape


__all__ = [
    "ModelConfig", "InputShape", "INPUT_SHAPES", "FLConfig",
    "ASSIGNED_ARCHS", "SHAPE_SKIPS",
    "get_config", "get_reduced", "get_shape", "all_pairs",
]
