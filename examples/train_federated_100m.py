"""End-to-end driver: federated training of a ~100M-parameter qwen2-family
model with CA-AFL selection, over-the-air aggregation and the energy ledger —
the production tier at a scale a CPU container can actually run.

~100M params: 12 layers, d_model=512, d_ff=2048, vocab 32k (padded). Eight
clients with heterogeneous synthetic corpora; the jit'd FL round is the same
code the multi-pod dry-run lowers at 34B/235B scale.

    PYTHONPATH=src python examples/train_federated_100m.py --rounds 200
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import FLConfig
from repro.data.synthetic import make_lm_tokens
from repro.federated.server import ParameterServer
from repro.launch.train import lm_batches
from repro.models.api import build_model
from repro.optim import sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--C", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("qwen2-0.5b").with_(
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=2, d_ff=2048,
        vocab_size=32000, dtype="float32", remat=False, window=None)
    model = build_model(cfg)
    fl = FLConfig(num_clients=args.clients, clients_per_round=args.k,
                  rounds=args.rounds, method="ca_afl", energy_C=args.C,
                  noise_std=1e-3, seed=args.seed)
    ps = ParameterServer(model, sgd(0.3), fl, seed=args.seed)
    state = ps.init_state(jax.random.PRNGKey(args.seed))
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(state.params))
    print(f"model: qwen2-family reduced, {n:,} params "
          f"(~{n / 1e6:.0f}M); N={args.clients} K={args.k} C={args.C}")

    corpus = make_lm_tokens(args.clients, 16 * args.seq, cfg.vocab_size,
                            seed=args.seed)
    t0 = time.time()
    state = ps.run(state, lm_batches(corpus, 2, args.seq, cfg, args.seed),
                   rounds=args.rounds,
                   log_every=max(args.rounds // 20, 1))
    dt = time.time() - t0
    losses = [h["loss"] for h in state.history]
    print(f"\n{args.rounds} rounds in {dt / 60:.1f} min "
          f"({dt / args.rounds:.2f} s/round)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(drop {losses[0] - losses[-1]:.3f})")
    lam = state.lam
    nz = lam[lam > 0]
    print(f"uplink energy: {state.energy_joules:.3e} J; "
          f"lambda: max={float(lam.max()):.3f}, "
          f"{int((lam == 0).sum())} clients projected to 0")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
