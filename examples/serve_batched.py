"""Batched serving example: prefill + greedy decode with the unified model
API — the code path the decode_32k / long_500k dry-run shapes lower at
production scale. Demonstrates three architectures (dense, SSM, hybrid)
including a rolling sliding-window cache for the dense model.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models.api import build_model, make_decode_step, make_prefill


def serve_rolling(arch: str, batch=2, steps=24):
    """Pure-decode serving with the O(window) rolling cache (the long_500k
    path): feed tokens one by one; the cache never exceeds `window` slots."""
    cfg = get_reduced(arch).with_(dtype="float32", remat=False, window=8,
                                  long_context_threshold=8)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    step = jax.jit(make_decode_step(model))
    cache = model.init_cache(batch, 1_000_000)  # rolling: allocates window=8
    tok = jnp.zeros((batch,), jnp.int32)
    t0 = time.time()
    for i in range(steps):
        tok, _, cache = step(params, cache, tok, jnp.asarray(i, jnp.int32))
    dt = time.time() - t0
    kv_slots = jax.tree_util.tree_leaves(cache)[0].shape
    print(f"  {arch:22s} {batch * steps:4d} tokens in {dt:5.1f}s  "
          f"cache leaf shape={tuple(kv_slots)} (O(window), not O(position))")


def serve(arch: str, batch=2, prompt=16, gen=16):
    cfg = get_reduced(arch).with_(dtype="float32", remat=False)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    b = {"tokens": jax.random.randint(key, (batch, prompt), 0,
                                      cfg.vocab_size)}
    if cfg.family == "vlm":
        b["images"] = jax.random.normal(
            key, (batch, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        b["audio"] = jax.random.normal(
            key, (batch, cfg.num_audio_frames, cfg.d_model))

    prefill = jax.jit(make_prefill(model, chunk=prompt))
    step = jax.jit(make_decode_step(model))
    t0 = time.time()
    logits, cache = prefill(params, b)
    # grow KV caches to prompt+gen (state caches pass through)
    from repro.launch.serve import pad_cache_for_decode
    cache = pad_cache_for_decode(model, cache, prompt, prompt + gen)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks = [tok]
    for i in range(gen - 1):
        tok, _, cache = step(params, cache, tok,
                             jnp.asarray(prompt + i, jnp.int32))
        toks.append(tok)
    dt = time.time() - t0
    ids = jnp.stack(toks, 1)
    print(f"  {arch:22s} {batch * gen:4d} tokens in {dt:5.1f}s  "
          f"ids[0,:8]={ids[0, :8].tolist()}")
    return ids


def main():
    print("batched greedy serving (reduced configs, CPU):")
    serve("qwen2-0.5b")
    serve("xlstm-1.3b")           # state cache, no KV growth
    serve("zamba2-1.2b")          # hybrid: SSM states + shared-attn KV
    print("long-context variant (rolling sliding-window cache):")
    serve_rolling("qwen2-0.5b")


if __name__ == "__main__":
    main()
