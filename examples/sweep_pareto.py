"""Energy-vs-robustness Pareto front across uplink transports.

The paper's central trade-off is energy efficiency (eq. 3-6 ledger) against
distributional robustness (worst-client accuracy) — and its headline 3×+
savings claim is against *transmission-scheme* baselines. This example
sweeps the CA-AFL energy-conservation factor C (plus the AFL and FedAvg
endpoints) across ALL FOUR uplink transports (``repro.core.transport``):

  - ``analog``    — the paper's channel-inversion AirComp (eq. 10);
  - ``quantized`` — b-bit stochastic-rounding AirComp (cheaper airtime,
                    added quantization error);
  - ``digital``   — orthogonal OFDMA (clean decode, rate/latency energy
                    bill — the comparison point the savings are measured
                    against);
  - ``sparse``    — top-k compressed AirComp with per-client error-feedback
                    memory (cheapest airtime; the dropped mass is deferred,
                    not lost).

The ledger prices BOTH directions: ``dl_rx_power`` is nonzero here, so every
round's model broadcast bills each receiver per-scheme downlink airtime
(full f32 for analog/digital, compressed for quantized/sparse) on top of the
uplink — the ``energy`` column is the total and ``dl_energy`` its broadcast
share. Everything runs in ONE ``run_sweep`` call: the transport scheme is
structural (one compilation per method × scheme), every scheme knob is
traced, and the analog cells compile to exactly the pre-transport program.
On the noise-free default scenario the digital round computes the identical
model update to analog, so the two transports sit at MATCHED accuracy and
the energy ratio between them is a pure transmission-scheme comparison —
the script asserts it exceeds 2×.

`PYTHONPATH=src python examples/sweep_pareto.py`
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.configs.base import FLConfig
from repro.core import sweep
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

C_GRID = (0.0, 2.0, 8.0, 32.0)
TRANSPORTS = ("analog", "quantized", "digital", "sparse")


def main():
    x, y, xt, yt = make_fmnist_like(3000, 800, dim=64, seed=0)
    xs, ys = sorted_label_shards(x, y, 24)
    xts, yts = sorted_label_shards(xt, yt, 24)
    data = (xs, ys, xts, yts)
    model = logistic_regression(64, 10)
    fl = FLConfig(num_clients=24, clients_per_round=10, rounds=100,
                  batch_size=24, lr0=0.3, lr_decay=0.995, ascent_lr=2e-2,
                  dl_rx_power=5e-5)  # price the broadcast: downlink ON

    variants = {}
    for tr in TRANSPORTS:
        for c in C_GRID:
            variants[f"{tr}:ca_afl_C{c:g}"] = {
                "method": "ca_afl", "energy_C": c, "transport": tr}
        variants[f"{tr}:afl"] = {"method": "afl", "transport": tr}
        variants[f"{tr}:fedavg"] = {"method": "fedavg", "transport": tr}

    # a harsh-noise uplink puts every transport's signature regime on the
    # table: quantized is cheapest, analog pays full airtime under the same
    # AWGN, digital pays the OFDMA bill but decodes CLEAN — the accuracy
    # ceiling at the energy ceiling. noise_std is a traced knob, so the
    # noisy cells share the default cells' executables.
    specs = sweep.expand_grid(fl, variants=variants,
                              scenarios=("default", ("noisy",
                                                     {"noise_std": 0.2})))
    sweep.reset_trace_log()
    result = sweep.run_sweep(model, data, specs, seeds=(0, 1, 2))
    print(f"{len(specs)} configs x 3 seeds -> "
          f"{sweep.trace_count()} compilations "
          "(one per method x transport)\n")

    # per-scenario fronts over the full three-transport grid (cross-scenario
    # dominance is meaningless: a noise-free cell "beats" every noisy one)
    summary = result.summary(window=10)
    fronts = {}
    for scen in ("default", "noisy"):
        labels = [lbl for lbl in result.labels
                  if (scen == "noisy") == lbl.endswith("@noisy")]
        costs = np.array([summary[lbl]["energy"] for lbl in labels])
        utils = np.array([summary[lbl]["worst_acc"] for lbl in labels])
        fronts[scen] = [labels[i] for i in sweep.pareto_indices(costs, utils)]
    front = fronts["default"] + fronts["noisy"]
    print(f"{'config':30s} {'energy (J)':>12s} {'worst acc':>10s} "
          f"{'avg acc':>9s}  on front?")
    for lbl in result.labels:
        row = summary[lbl]
        mark = "  *" if lbl in front else ""
        print(f"{lbl:30s} {row['energy']:12.3e} {row['worst_acc']:10.3f} "
              f"{row['avg_acc']:9.3f}{mark}")
    for scen, fr in fronts.items():
        spanned = sorted({lbl.split(":")[0] for lbl in fr})
        print(f"\n{scen} Pareto front (min energy, max worst acc): {fr}\n"
              f"  transports on it: {spanned}")
    # clean channel: quantized AirComp strictly dominates analog (identical
    # accuracy at bits/32 of the airtime — the Li et al. result), so the
    # cheap end is quantized; harsh noise: digital's orthogonal decode is
    # immune to the superposition AWGN and claims the accuracy ceiling, so
    # the front stretches across transports.
    assert len({lbl.split(":")[0] for lbl in fronts["noisy"]}) >= 2, \
        "expected the noisy-uplink front to span multiple transports"

    # the broadcast is priced in every cell: dl_energy is a strictly
    # positive share of the total, and the compressed schemes' share is
    # cheaper than the full-f32 broadcast the analog/digital cells pay
    for lbl in result.labels:
        row = summary[lbl]
        assert 0.0 < row["dl_energy"] < row["energy"], lbl
    for m in ["ca_afl_C8", "afl", "fedavg"]:
        assert (summary[f"sparse:{m}"]["dl_energy"]
                < summary[f"analog:{m}"]["dl_energy"]), m
        assert (summary[f"quantized:{m}"]["dl_energy"]
                < summary[f"analog:{m}"]["dl_energy"]), m

    # matched-accuracy transmission-scheme comparison: on the noise-free
    # default scenario the digital round computes the IDENTICAL update to
    # analog (weighted mean, no AWGN on either), so per method the accuracy
    # columns agree and the energy ratio isolates the transport
    seps = []
    for m in [f"ca_afl_C{c:g}" for c in C_GRID] + ["afl", "fedavg"]:
        a, d = summary[f"analog:{m}"], summary[f"digital:{m}"]
        assert abs(a["worst_acc"] - d["worst_acc"]) < 1e-6, m
        seps.append(d["energy"] / a["energy"])
        print(f"{m:12s}: digital/analog energy = {seps[-1]:.2f}x "
              f"at matched worst-acc {a['worst_acc']:.3f}")
    sep = float(np.min(seps))
    print(f"\nanalog AirComp saves >= {sep:.2f}x energy vs digital OFDMA "
          "at matched accuracy")
    assert sep >= 2.0, (
        f"expected >= 2x analog/digital energy separation, got {sep:.2f}x")

    out = Path(__file__).resolve().parent / "sweep_pareto.json"
    payload = result.to_dict(window=10)
    payload["digital_over_analog_energy_min"] = sep
    out.write_text(json.dumps(payload, indent=2))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
