"""Energy-vs-robustness Pareto front via the batched sweep engine.

The paper's central trade-off is energy efficiency (eq. 3-6 ledger) against
distributional robustness (worst-client accuracy). This example sweeps the
energy-conservation factor C of CA-AFL across a grid — plus the AFL and
FedAvg endpoints — over several seeds *in one jitted computation per
selection method*, then extracts the Pareto-optimal settings.

The whole C-grid rides a single vmap axis (C only enters eq. 9's logits as a
traced scalar), so adding another C value costs zero extra compilations.

`PYTHONPATH=src python examples/sweep_pareto.py`
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import FLConfig
from repro.core import sweep
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

C_GRID = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0)


def main():
    x, y, xt, yt = make_fmnist_like(3000, 800, dim=64, seed=0)
    xs, ys = sorted_label_shards(x, y, 24)
    xts, yts = sorted_label_shards(xt, yt, 24)
    data = (xs, ys, xts, yts)
    model = logistic_regression(64, 10)
    fl = FLConfig(num_clients=24, clients_per_round=10, rounds=100,
                  batch_size=24, lr0=0.3, lr_decay=0.995, ascent_lr=2e-2)

    variants = {f"ca_afl_C{c:g}": {"method": "ca_afl", "energy_C": c}
                for c in C_GRID}
    variants["afl"] = {"method": "afl"}
    variants["fedavg"] = {"method": "fedavg"}

    specs = sweep.expand_grid(fl, variants=variants)
    sweep.reset_trace_log()
    result = sweep.run_sweep(model, data, specs, seeds=(0, 1, 2))
    print(f"{len(specs)} configs x 3 seeds -> "
          f"{sweep.trace_count()} compilations\n")

    summary = result.summary(window=10)
    front = result.pareto_front(window=10)
    print(f"{'config':14s} {'energy (J)':>12s} {'worst acc':>10s} "
          f"{'avg acc':>9s}  on front?")
    for lbl in result.labels:
        row = summary[lbl]
        mark = "  *" if lbl in front else ""
        print(f"{lbl:14s} {row['energy']:12.3e} {row['worst_acc']:10.3f} "
              f"{row['avg_acc']:9.3f}{mark}")
    print(f"\nPareto front (min energy, max worst-client acc): {front}")

    out = Path(__file__).resolve().parent / "sweep_pareto.json"
    out.write_text(json.dumps(result.to_dict(window=10), indent=2))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
