"""Quickstart: CA-AFL vs AFL in 60 seconds on CPU.

Runs the paper's Algorithm 1 (N=20 clients, logistic regression, sorted-label
shards) against the non-channel-aware AFL baseline and prints the
energy/robustness trade-off the paper is about.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import FLConfig
from repro.core.simulator import run_simulation
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression


def main():
    x, y, xt, yt = make_fmnist_like(num_train=2000, num_test=500, dim=64)
    data = (*sorted_label_shards(x, y, 20), )
    xts, yts = sorted_label_shards(xt, yt, 20)
    data = (data[0], data[1], xts, yts)
    model = logistic_regression(dim=64, num_classes=10)

    print(f"{'method':12s} {'avg_acc':>8s} {'worst_acc':>10s} "
          f"{'std':>6s} {'energy (J)':>12s}")
    for name, method, c in (("AFL", "afl", 0.0),
                            ("CA-AFL C=2", "ca_afl", 2.0),
                            ("CA-AFL C=8", "ca_afl", 8.0),
                            ("greedy", "greedy", 0.0)):
        fl = FLConfig(num_clients=20, clients_per_round=8, rounds=60,
                      batch_size=20, lr0=0.3, lr_decay=0.995,
                      ascent_lr=2e-2, method=method, energy_C=c)
        h = run_simulation(model, fl, data)
        print(f"{name:12s} {float(h.avg_acc[-1]):8.3f} "
              f"{float(h.worst_acc[-1]):10.3f} {float(h.std_acc[-1]):6.3f} "
              f"{float(h.energy[-1]):12.3e}")
    print("\nCA-AFL trades a sliver of worst-client accuracy for a large "
          "energy saving; C interpolates AFL -> greedy (Props. 1-2).")


if __name__ == "__main__":
    main()
