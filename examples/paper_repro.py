"""Full reproduction of the paper's experiments (Figs. 2-3).

Defaults to the reduced-faithful configuration (minutes on CPU); pass
``--full`` for the paper's exact scale: N=100, K=40, M=7850 logistic
regression, T=500 rounds, 5 seeds.

    PYTHONPATH=src python examples/paper_repro.py [--full]
"""
import sys

sys.path.insert(0, ".")  # allow running from repo root

from benchmarks.paper_figs import main  # noqa: E402

if __name__ == "__main__":
    main(full="--full" in sys.argv)
