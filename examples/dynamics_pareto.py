"""CA-AFL vs. baselines under temporal dynamics: battery budgets + Markov
fading.

The paper evaluates the energy/robustness trade-off under i.i.d. block
fading. This example replays the comparison in the regime where it matters
most (Sun et al.'s battery-constrained scheduling): channels persist across
rounds (Gauss-Markov, rho=0.8) and every client has a finite battery that
eqs. (3-6) uploads deplete. Methods that keep hammering the cheapest clients
(greedy, high-C CA-AFL) exhaust them and starve; the sweep reports the
schedulable-pool size and worst remaining battery alongside the usual
energy/worst-accuracy Pareto front — all through ONE jitted executable per
selection method (the whole dynamic grid shares a compilation).

`PYTHONPATH=src python examples/dynamics_pareto.py`
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import FLConfig
from repro.core import sweep
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

N_CLIENTS = 24
C_GRID = (0.0, 2.0, 8.0, 32.0)
BATTERY_J = 2.0e-2  # ~20 uploads per client: binds midway through the run


def main():
    x, y, xt, yt = make_fmnist_like(3000, 800, dim=64, seed=0)
    xs, ys = sorted_label_shards(x, y, N_CLIENTS)
    xts, yts = sorted_label_shards(xt, yt, N_CLIENTS)
    data = (xs, ys, xts, yts)
    model = logistic_regression(64, 10)
    fl = FLConfig(num_clients=N_CLIENTS, clients_per_round=10, rounds=120,
                  batch_size=24, lr0=0.3, lr_decay=0.995, ascent_lr=2e-2)

    variants = {f"ca_afl_C{c:g}": {"method": "ca_afl", "energy_C": c}
                for c in C_GRID}
    variants["afl"] = {"method": "afl"}
    variants["fedavg"] = {"method": "fedavg"}
    variants["greedy"] = {"method": "greedy"}

    scenario = ("battery", {"temporal": True, "rho_fading": 0.8,
                            "battery_init": BATTERY_J})
    specs = sweep.expand_grid(fl, variants=variants, scenarios=(scenario,))
    sweep.reset_trace_log()
    result = sweep.run_sweep(model, data, specs, seeds=(0, 1, 2))
    print(f"{len(specs)} configs x 3 seeds (all temporal) -> "
          f"{sweep.trace_count()} compilations\n")

    summary = result.summary(window=10)
    front = result.pareto_front(window=10)
    print(f"{'config':22s} {'energy (J)':>11s} {'worst acc':>10s} "
          f"{'pool':>6s} {'min batt':>10s}  on front?")
    for lbl in result.labels:
        row = summary[lbl]
        mark = "  *" if lbl in front else ""
        print(f"{lbl:22s} {row['energy']:11.3e} {row['worst_acc']:10.3f} "
              f"{row['avail_count']:6.1f} {row['min_battery']:10.2e}{mark}")
    print(f"\nPareto front under battery constraints: {front}")

    out = Path(__file__).resolve().parent / "dynamics_pareto.json"
    out.write_text(json.dumps(result.to_dict(window=10), indent=2))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
