"""Cross-tier equivalence: one ``ParameterServer.step`` round must match one
simulator ``round_fn`` round numerically on logreg with shared keys — the
same selection mask, λ update, energy ledger and aggregated weights — so the
production and simulator tiers can never drift apart silently. Also the
server-tier GCA path (gradient-norm probe), which used to crash."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.energy import transmit_energy
from repro.core.simulator import init_sim_state, make_param_round_fn
from repro.core.sweep import sweep_point_from_config
from repro.federated.rounds import make_grad_norm_probe
from repro.federated.server import ParameterServer, ServerState
from repro.models.logreg import logistic_regression, logistic_regression_prod
from repro.optim import sgd
from repro.utils.tree import tree_size

N, DIM, CLS = 6, 16, 10
PER_CLIENT = 4  # examples per client in the production batch


def _fl(method="ca_afl", **kw):
    return FLConfig(num_clients=N, clients_per_round=3, rounds=1,
                    batch_size=PER_CLIENT, local_steps=1, method=method,
                    lr0=0.2, lr_decay=0.995, ascent_lr=1e-2, energy_C=4.0,
                    noise_std=0.0, **kw)


@pytest.fixture(scope="module")
def tier_data():
    """One example per client (shard size 1): the simulator's with-replacement
    batch sampler then draws that row deterministically, so both tiers train
    on literally the same data and the comparison is exact."""
    key = jax.random.PRNGKey(7)
    xs = jax.random.normal(key, (N, 1, DIM))
    ys = jax.random.randint(jax.random.fold_in(key, 1), (N, 1), 0, CLS)
    return xs, ys


def _prod_batch(xs, ys):
    """The production-tier view of the same data: PER_CLIENT copies of each
    client's row, client-contiguous (the layout the round + probe require)."""
    x = jnp.repeat(xs[:, 0, :], PER_CLIENT, axis=0)            # [N*m, D]
    labels = jnp.repeat(ys[:, 0], PER_CLIENT, axis=0)          # [N*m]
    cids = jnp.repeat(jnp.arange(N), PER_CLIENT)
    return {"x": x, "labels": labels, "client_ids": cids}


@pytest.mark.parametrize("method", ["ca_afl", "fedavg", "afl", "greedy"])
def test_server_step_matches_simulator_round(tier_data, method):
    xs, ys = tier_data
    fl = _fl(method)
    sim_model = logistic_regression(DIM, CLS)
    data = (xs, ys, xs, ys)

    # --- simulator tier: one parameterized round ------------------------
    point = sweep_point_from_config(fl)
    state = init_sim_state(sim_model, fl, jax.random.PRNGKey(0),
                           process=point.process)
    model_size = tree_size(state.w)
    round_fn = make_param_round_fn(sim_model, fl, data, model_size, method)
    new_state, hist = jax.jit(lambda p, s: round_fn(p, s, 0))(point, state)

    # --- production tier: same key, same params, same λ -----------------
    prod_model = logistic_regression_prod(DIM, CLS)
    ps = ParameterServer(prod_model, sgd(fl.lr0), fl, seed=0)
    ps.key = state.key  # align the per-round 7-way split with the simulator
    srv = ServerState(params=jax.tree.map(jnp.asarray, state.w),
                      opt_state=sgd(fl.lr0).init(state.w),
                      lam=state.lam)
    srv = ps.step(srv, _prod_batch(xs, ys))

    # selection mask (via scheduled count + energy), energy ledger, λ, and
    # the aggregated model must all agree
    assert srv.history[-1]["num_scheduled"] == int(hist.num_scheduled)
    np.testing.assert_allclose(srv.energy_joules, float(hist.energy),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(srv.lam), np.asarray(new_state.lam),
                               atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(srv.params),
                    jax.tree_util.tree_leaves(new_state.w), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_server_step_matches_simulator_round_temporal(tier_data):
    """The temporal ChannelProcess evolves identically host-side: same
    degenerate-process trick, now through the ChanState carry on both tiers."""
    xs, ys = tier_data
    fl = _fl("ca_afl", temporal=True, battery_init=1.0)
    sim_model = logistic_regression(DIM, CLS)
    point = sweep_point_from_config(fl)
    state = init_sim_state(sim_model, fl, jax.random.PRNGKey(0),
                           process=point.process)
    model_size = tree_size(state.w)
    round_fn = make_param_round_fn(sim_model, fl, (xs, ys, xs, ys),
                                   model_size, "ca_afl")
    new_state, hist = jax.jit(lambda p, s: round_fn(p, s, 0))(point, state)

    prod_model = logistic_regression_prod(DIM, CLS)
    ps = ParameterServer(prod_model, sgd(fl.lr0), fl, seed=0)
    ps.key = state.key
    # init_state mirrors init_sim_state's key discipline: same outer key =>
    # same initial ChanState (and same zeros-init logreg params)
    srv = ps.init_state(jax.random.PRNGKey(0))
    for a, b in zip(srv.chan_state, state.chan_state, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    srv = ServerState(params=jax.tree.map(jnp.asarray, state.w),
                      opt_state=sgd(fl.lr0).init(state.w),
                      lam=state.lam, chan_state=srv.chan_state)
    srv = ps.step(srv, _prod_batch(xs, ys))

    np.testing.assert_allclose(srv.energy_joules, float(hist.energy),
                               rtol=1e-5)
    assert srv.history[-1]["avail_count"] == int(hist.avail_count)
    np.testing.assert_allclose(np.asarray(srv.chan_state.battery),
                               np.asarray(new_state.chan_state.battery),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(srv.lam), np.asarray(new_state.lam),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# Uplink transports: the tiers cannot drift on quantized/digital either
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["ca_afl", "fedavg", "gca"])
@pytest.mark.parametrize("transport", ["quantized", "digital", "sparse"])
def test_server_step_matches_simulator_round_transports(tier_data, transport,
                                                        method):
    """One ``ParameterServer.step`` == one simulator round under the
    quantized, digital and sparse transports: same mask, λ, energy ledger
    and aggregated weights. Quantized exercises the per-client stochastic-
    rounding streams on both tiers (the server reconstructs each client's
    −η·g_i delta from the grad probe and rounds it with the simulator's
    fold_in discipline); digital exercises the OFDMA energy accounting with
    the noise-free orthogonal decode; sparse exercises the deterministic
    top-k compression plus the error-feedback memory born at zeros on both
    tiers."""
    xs, ys = tier_data
    fl = _fl(method, transport=transport, quant_bits=6.0, sparse_density=0.25)
    sim_model = logistic_regression(DIM, CLS)
    point = sweep_point_from_config(fl)
    state = init_sim_state(sim_model, fl, jax.random.PRNGKey(0),
                           process=point.process)
    round_fn = make_param_round_fn(sim_model, fl, (xs, ys, xs, ys),
                                   tree_size(state.w), method)
    new_state, hist = jax.jit(lambda p, s: round_fn(p, s, 0))(point, state)

    prod_model = logistic_regression_prod(DIM, CLS)
    ps = ParameterServer(prod_model, sgd(fl.lr0), fl, seed=0)
    ps.key = state.key
    srv = ServerState(params=jax.tree.map(jnp.asarray, state.w),
                      opt_state=sgd(fl.lr0).init(state.w),
                      lam=state.lam)
    srv = ps.step(srv, _prod_batch(xs, ys))

    assert srv.history[-1]["num_scheduled"] == int(hist.num_scheduled)
    np.testing.assert_allclose(srv.energy_joules, float(hist.energy),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(srv.lam), np.asarray(new_state.lam),
                               atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(srv.params),
                    jax.tree_util.tree_leaves(new_state.w), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    if transport == "sparse":
        # the error-feedback memory (the dropped mass) must agree too —
        # a drift here silently compounds into every later round
        np.testing.assert_allclose(np.asarray(srv.ef_resid),
                                   np.asarray(new_state.ef_resid),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("transport",
                         ["analog", "quantized", "digital", "sparse"])
def test_server_downlink_ledger_matches_simulator(tier_data, transport):
    """With a nonzero broadcast receive power, BOTH tiers price the downlink
    identically for every scheme: one ``ParameterServer.step`` and one
    simulator round agree on the total energy column AND its downlink share
    (N receivers × per-model listen energy × the scheme's payload
    fraction)."""
    xs, ys = tier_data
    fl = _fl("ca_afl", transport=transport, quant_bits=6.0,
             sparse_density=0.25, dl_rx_power=2e-4)
    sim_model = logistic_regression(DIM, CLS)
    point = sweep_point_from_config(fl)
    state = init_sim_state(sim_model, fl, jax.random.PRNGKey(0),
                           process=point.process)
    round_fn = make_param_round_fn(sim_model, fl, (xs, ys, xs, ys),
                                   tree_size(state.w), "ca_afl")
    new_state, hist = jax.jit(lambda p, s: round_fn(p, s, 0))(point, state)
    assert float(hist.dl_energy) > 0.0

    prod_model = logistic_regression_prod(DIM, CLS)
    ps = ParameterServer(prod_model, sgd(fl.lr0), fl, seed=0)
    ps.key = state.key
    srv = ServerState(params=jax.tree.map(jnp.asarray, state.w),
                      opt_state=sgd(fl.lr0).init(state.w),
                      lam=state.lam)
    srv = ps.step(srv, _prod_batch(xs, ys))
    np.testing.assert_allclose(srv.dl_energy_joules, float(hist.dl_energy),
                               rtol=1e-5)
    np.testing.assert_allclose(srv.energy_joules, float(hist.energy),
                               rtol=1e-5)
    # downlink rides the TOTAL ledger additively
    assert srv.energy_joules > srv.dl_energy_joules > 0.0


def test_server_battery_depletion_matches_simulator_quantized(tier_data):
    """Battery gating prices uploads under the transport on BOTH tiers: the
    temporal ChanState (incl. the post-round battery ledger) stays equal
    through a quantized round."""
    xs, ys = tier_data
    fl = _fl("ca_afl", temporal=True, battery_init=1.0,
             transport="quantized", quant_bits=6.0)
    sim_model = logistic_regression(DIM, CLS)
    point = sweep_point_from_config(fl)
    state = init_sim_state(sim_model, fl, jax.random.PRNGKey(0),
                           process=point.process)
    round_fn = make_param_round_fn(sim_model, fl, (xs, ys, xs, ys),
                                   tree_size(state.w), "ca_afl")
    new_state, hist = jax.jit(lambda p, s: round_fn(p, s, 0))(point, state)

    prod_model = logistic_regression_prod(DIM, CLS)
    ps = ParameterServer(prod_model, sgd(fl.lr0), fl, seed=0)
    ps.key = state.key
    srv = ps.init_state(jax.random.PRNGKey(0))
    srv = ServerState(params=jax.tree.map(jnp.asarray, state.w),
                      opt_state=sgd(fl.lr0).init(state.w),
                      lam=state.lam, chan_state=srv.chan_state)
    srv = ps.step(srv, _prod_batch(xs, ys))
    np.testing.assert_allclose(srv.energy_joules, float(hist.energy),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(srv.chan_state.battery),
                               np.asarray(new_state.chan_state.battery),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# GCA on the server tier (regression: used to raise ValueError)
# ---------------------------------------------------------------------------


def test_grad_norm_probe_matches_per_client_grads(tier_data):
    xs, ys = tier_data
    prod_model = logistic_regression_prod(DIM, CLS)
    params = prod_model.init(jax.random.PRNGKey(0))
    params = {"w": params["w"] + 0.1, "b": params["b"] - 0.05}  # off-zero
    batch = _prod_batch(xs, ys)
    norms = make_grad_norm_probe(prod_model, N)(params, batch)
    assert norms.shape == (N,)
    sim_model = logistic_regression(DIM, CLS)
    for c in range(N):
        g = jax.grad(sim_model.loss)(params, xs[c], ys[c])
        ref = jnp.sqrt(sum(jnp.sum(jnp.square(l))
                           for l in jax.tree_util.tree_leaves(g)))
        np.testing.assert_allclose(float(norms[c]), float(ref), rtol=1e-5)


def test_grad_norm_probe_handles_permuted_client_blocks(tier_data):
    """Client blocks need not arrive in ascending id order: norms are
    scattered by the observed ids, not by block position."""
    xs, ys = tier_data
    prod_model = logistic_regression_prod(DIM, CLS)
    params = prod_model.init(jax.random.PRNGKey(0))
    params = {"w": params["w"] + 0.1, "b": params["b"] - 0.05}
    batch = _prod_batch(xs, ys)
    probe = make_grad_norm_probe(prod_model, N)
    ref = probe(params, batch)
    perm = np.random.default_rng(0).permutation(N)
    idx = jnp.asarray((perm[:, None] * PER_CLIENT
                       + np.arange(PER_CLIENT)).reshape(-1))
    shuffled = {k: v[idx] for k, v in batch.items()}
    got = probe(params, shuffled)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


def test_server_gca_smoke(tier_data):
    """GCA end-to-end on the production tier: probe feeds selection, rounds
    complete, scheduled counts stay in range."""
    xs, ys = tier_data
    fl = _fl("gca")
    ps = ParameterServer(logistic_regression_prod(DIM, CLS), sgd(0.1), fl,
                         seed=1)
    state = ps.init_state(jax.random.PRNGKey(2))

    def batches():
        while True:
            yield _prod_batch(xs, ys)

    state = ps.run(state, batches(), rounds=3, log_fn=None)
    assert state.round == 3
    assert all(np.isfinite(h["loss"]) for h in state.history)
    assert all(0 <= h["num_scheduled"] <= N for h in state.history)
    assert state.energy_joules >= 0.0


def test_server_gca_rejects_mixed_client_blocks(tier_data):
    """Interleaved client rows would silently mis-attribute probe norms;
    the server validates the layout host-side and refuses."""
    xs, ys = tier_data
    ps = ParameterServer(logistic_regression_prod(DIM, CLS), sgd(0.1),
                         _fl("gca"), seed=0)
    state = ps.init_state(jax.random.PRNGKey(0))
    bad = _prod_batch(xs, ys)
    bad["client_ids"] = jnp.tile(jnp.arange(N), PER_CLIENT)  # interleaved
    with pytest.raises(ValueError):
        ps.step(state, bad)


def test_battery_exhaustion_stops_spending_on_server(tier_data):
    """Production tier honours battery budgets: with a budget smaller than
    one upload, nobody transmits and the ledger stays at zero."""
    xs, ys = tier_data
    # one upload costs psi*M*tau/h^2 >= psi*M*tau (h <= ~few): make the
    # budget orders of magnitude below that
    model_size = DIM * CLS + CLS
    tiny = float(transmit_energy(jnp.array(10.0), model_size, 0.5e-3, 1e-3)) / 1e3
    fl = _fl("fedavg", temporal=True, battery_init=tiny)
    ps = ParameterServer(logistic_regression_prod(DIM, CLS), sgd(0.1), fl,
                         seed=0)
    state = ps.init_state(jax.random.PRNGKey(0))

    def batches():
        while True:
            yield _prod_batch(xs, ys)

    p0 = jax.tree.map(jnp.copy, state.params)
    state = ps.run(state, batches(), rounds=3, log_fn=None)
    assert state.energy_joules == 0.0
    assert all(h["num_scheduled"] == 0 for h in state.history)
    assert all(h["avail_count"] == 0 for h in state.history)
    # the PS received nothing over the air: the global model must not move
    for a, b in zip(jax.tree_util.tree_leaves(p0),
                    jax.tree_util.tree_leaves(state.params), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
