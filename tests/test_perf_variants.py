"""Validation of beyond-paper performance variants.

1. fused_probe: λ-ascent on one-round-stale losses (w^t instead of w^{t+1})
   must not change CA-AFL's training behaviour — validated on the paper-scale
   simulator (stale-λ variant) and on the production round (shapes/finite).
2. TP activation constraints / microbatching must not change round semantics
   (covered in test_federated; here we add the fused-probe round equivalence
   against the faithful round at convergence level).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.configs.base import FLConfig
from repro.core.simulator import run_simulation
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.federated.rounds import make_fl_round
from repro.models.api import build_model
from repro.models.logreg import logistic_regression
from repro.optim import sgd


def test_fused_probe_round_runs_and_matches_descent(key):
    cfg = get_reduced("qwen2-0.5b").with_(dtype="float32", remat=False)
    model = build_model(cfg)
    params = model.init(key)
    opt = sgd(0.1)
    B, N = 8, 4
    batch = {"tokens": jax.random.randint(key, (B, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, 16), 0, cfg.vocab_size),
             "client_ids": jnp.repeat(jnp.arange(N), B // N)}
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    exact = jax.jit(make_fl_round(model, opt, N, 2))
    fused = jax.jit(make_fl_round(model, opt, N, 2, fused_probe=True))
    p1, _, m1 = exact(params, opt.init(params), batch, mask, key)
    p2, _, m2 = fused(params, opt.init(params), batch, mask, key)
    # the DESCENT update is identical (same weighted grads)
    np.testing.assert_allclose(m1.loss, m2.loss, rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2), strict=True):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    # probe losses differ by exactly one optimizer step (w^t vs w^{t+1});
    # both are finite and the stale ones are the PRE-update losses (higher
    # on the selected clients, which just improved)
    assert bool(jnp.all(jnp.isfinite(m2.client_losses)))
    sel = jnp.array([0, 2])
    assert bool(jnp.all(m2.client_losses[sel] >= m1.client_losses[sel]))


def test_fused_probe_microbatched(key):
    cfg = get_reduced("qwen2-0.5b").with_(dtype="float32", remat=False)
    model = build_model(cfg)
    params = model.init(key)
    opt = sgd(0.1)
    B, N = 8, 4
    batch = {"tokens": jax.random.randint(key, (B, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, 16), 0, cfg.vocab_size),
             "client_ids": jnp.repeat(jnp.arange(N), B // N)}
    mask = jnp.array([1.0, 1.0, 0.0, 0.0])
    f1 = jax.jit(make_fl_round(model, opt, N, 2, fused_probe=True))
    f4 = jax.jit(make_fl_round(model, opt, N, 2, fused_probe=True,
                               microbatches=4))
    p1, _, m1 = f1(params, opt.init(params), batch, mask, key)
    p4, _, m4 = f4(params, opt.init(params), batch, mask, key)
    np.testing.assert_allclose(m1.loss, m4.loss, rtol=1e-5)
    np.testing.assert_allclose(m1.client_losses, m4.client_losses, rtol=1e-3,
                               atol=1e-4)


def test_stale_lambda_ascent_converges_like_exact():
    """Simulator-level check: λ updated with one-round-stale losses gives the
    same worst-client trajectory as the exact Alg. 1 (within seed noise)."""
    x, y, xt, yt = make_fmnist_like(2000, 500, dim=64, seed=0)
    data = (*sorted_label_shards(x, y, 20)[:2],
            *sorted_label_shards(xt, yt, 20))
    model = logistic_regression(64, 10)
    fl = FLConfig(num_clients=20, clients_per_round=8, rounds=50,
                  batch_size=20, lr0=0.3, lr_decay=0.995, ascent_lr=2e-2,
                  method="ca_afl", energy_C=8.0)
    # exact: per-round fresh losses. The simulator's ascent already evaluates
    # at w^{t+1}; a stale variant shifts losses by one round, equivalent to
    # evaluating at w^t — emulate by running with the same seed and comparing
    # the final metrics envelope.
    h = run_simulation(model, fl, data, seed=0)
    h2 = run_simulation(model, fl, data, seed=1)
    exact_spread = abs(float(h.worst_acc[-1]) - float(h2.worst_acc[-1]))
    # seed-to-seed spread bounds the acceptable stale-λ deviation
    assert exact_spread < 0.25


def test_slstm_custom_vjp_matches_autodiff(key):
    """The BPTT custom VJP (perf iteration 3) is exactly autodiff."""
    from repro.models.xlstm import SLSTMCache, _slstm_cell, _slstm_core
    S, B, H, d = 6, 2, 2, 4
    gx = 0.5 * jax.random.normal(key, (S, B, 4, H, d))
    r = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (H, d, 4, d))
    bg = 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (4, H, d))
    z = jnp.zeros((B, H, d))
    m0 = jnp.full((B, H, d), -1e30)

    def loss_c(gx, r, bg):
        hs, *_ = _slstm_core(gx, r, bg, z, z, z, m0)
        return jnp.sum(jnp.sin(hs))

    def loss_r(gx, r, bg):
        _, hs = jax.lax.scan(lambda cr, g: _slstm_cell(cr, g, r, bg),
                             SLSTMCache(z, z, z, m0), gx)
        return jnp.sum(jnp.sin(hs))

    g1 = jax.grad(loss_c, argnums=(0, 1, 2))(gx, r, bg)
    g2 = jax.grad(loss_r, argnums=(0, 1, 2))(gx, r, bg)
    for a, b in zip(g1, g2, strict=True):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_slstm_pallas_kernel_matches_ref(key):
    from repro.kernels.slstm.kernel import slstm_pallas
    from repro.kernels.slstm.ref import slstm_ref
    S, B, H, d = 64, 2, 4, 32
    gx = 0.5 * jax.random.normal(key, (S, B, 4, H, d))
    r = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (H, d, 4, d))
    b = 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (4, H, d))
    z = jnp.zeros((B, H, d))
    m0 = jnp.full((B, H, d), -1e30)
    hs_p, st_p = slstm_pallas(gx, r, b, z, z, z, m0, tb=16, interpret=True)
    hs_r, st_r = slstm_ref(gx, r, b, z, z, z, m0)
    np.testing.assert_allclose(hs_p, hs_r, rtol=2e-4, atol=2e-4)
    for a, b_ in zip(st_p, st_r, strict=True):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-4)
