"""ISSUE 9 layer-2 tests: jaxpr program analyzers across methods ×
transports × control planes.

The analyzers trace the REAL compiled programs (``jax.make_jaxpr`` on a
size-1 clients mesh — collectives appear in the jaxpr regardless of mesh
size) and these tests pin the invariants the prose contracts promise:

  - every exact-K sharded round is sort-free with K-bounded all_gather
    operands and a pinned psum census, under all three transports and with
    the temporal (ChannelProcess) program too;
  - GCA keeps its documented dense exception but its census is pinned;
  - the REPLICATED control plane's round (both an exact-K and the GCA
    program) DOES sort — the negative control proving the census sees what
    it claims to see;
  - ``project_simplex_sharded`` spends exactly 1 psum per bisection
    iteration plus pmax + 2 polish psums;
  - the sweep runner's donation aliasing and one-compile-per-structural-
    group accounting hold.
"""
import jax
import pytest

from repro.lint import jaxpr_checks as jc

EXACT_K = jc.EXACT_K_METHODS
TRANSPORTS = jc.TRANSPORTS


# ---------------------------------------------------------------------------
# Sharded control plane: methods × transports
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transport", TRANSPORTS)
@pytest.mark.parametrize("method", EXACT_K)
def test_sharded_round_sort_free_and_k_bounded(method, transport):
    closed = jc.trace_sharded_round(method, transport)
    census = jc.primitive_census(closed)
    assert census["sort"] == 0, (
        f"{method}/{transport}: sort primitive on the sharded path")
    sizes = jc.all_gather_operand_sizes(closed)
    assert sizes, "expected the hierarchical top-k candidate gathers"
    assert max(sizes) <= jc.K, (
        f"{method}/{transport}: all_gather operand sizes {sizes} exceed the "
        f"K={jc.K} candidate bound — an O(n_local) row block is gathered")
    assert census["psum"] == jc.PINNED_PSUMS[(method, transport)]


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_sharded_gca_census_pinned(transport):
    census = jc.primitive_census(jc.trace_sharded_round("gca", transport))
    assert census["psum"] == jc.PINNED_PSUMS[("gca", transport)]


def test_sharded_round_temporal_program_still_clean():
    # the ChannelProcess carry is a different structural program; the
    # collective discipline must survive it
    closed = jc.trace_sharded_round("ca_afl", "analog", temporal=True)
    census = jc.primitive_census(closed)
    assert census["sort"] == 0
    assert max(jc.all_gather_operand_sizes(closed)) <= jc.K


def test_exact_k_psum_census_transport_invariant():
    # exact-K aggregation rides the same psum-tree shape under every
    # direct transport — pinned as a single shared budget; sparse pays
    # exactly ONE extra psum, the ownership assembly of the winners'
    # error-feedback residual rows
    direct = {jc.PINNED_PSUMS[(m, t)] for m in EXACT_K
              for t in TRANSPORTS if t != "sparse"}
    assert len(direct) == 1
    sparse = {jc.PINNED_PSUMS[(m, "sparse")] for m in EXACT_K}
    assert sparse == {next(iter(direct)) + 1}


# ---------------------------------------------------------------------------
# Replicated control plane: the negative control
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_replicated_round_sorts(transport):
    census = jc.primitive_census(
        jc.trace_replicated_round("ca_afl", transport))
    assert census["sort"] >= 1, (
        "replicated round shows no sort — the analyzer is blind")


def test_replicated_gca_round_traces():
    census = jc.primitive_census(jc.trace_replicated_round("gca"))
    assert census["sort"] >= 1  # GCA median + the sort-based projection


# ---------------------------------------------------------------------------
# Projection budget, donation, compile accounting
# ---------------------------------------------------------------------------


def test_projection_psum_budget():
    ok, detail = jc.check_projection_psum_budget()
    assert ok, detail


def test_sweep_donation_aliasing():
    ok, detail = jc.check_sweep_donation()
    assert ok, detail


def test_compile_count_one_per_structural_group():
    ok, detail = jc.check_compile_count()
    assert ok, detail


def test_run_all_green():
    results = jc.run_all()
    assert [name for name, ok, _ in results if not ok] == [], results


def test_harness_mesh_is_single_device():
    # the whole suite must stay runnable in the tier-1 single-device lane
    _, _, _, mesh = jc._setup()
    assert mesh.size == 1
    assert jax.device_count() >= 1
