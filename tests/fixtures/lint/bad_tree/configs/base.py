"""Lint fixture FLConfig: the fields the fixture sweep.py may reference."""


class FLConfig:
    num_clients: int = 4
    eval_every: int = 1
    record_lambda_every: int = 1
