"""Lint fixture: the single-source owner of TRUNCATION_FLOOR."""

TRUNCATION_FLOOR = 0.05
