"""Known-bad lint fixture: sorts on the sharded path; the exempt gather."""
import jax.numpy as jnp

from repro.core.sharding import all_gather_axis


def project_simplex_sharded(v_local):
    # BAD: sort in a sharded-path function
    u = jnp.sort(v_local)
    return u[::-1]


def control_sharded_cell_run(scores_local):
    # BAD: sort in a sharded-path function
    return jnp.argsort(scores_local)


def hierarchical_top_k(v, axis_name):
    # NOT flagged: registry.GATHER_EXEMPT_FUNCTIONS — K-bounded by design
    return all_gather_axis(v, axis_name)
