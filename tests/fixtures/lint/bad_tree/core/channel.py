"""Known-bad lint fixture: a drifted copy of the truncation floor."""

# BAD: duplicates core/energy.py's TRUNCATION_FLOOR literal
FLOOR_COPY = 0.05
