"""Known-bad lint fixture: a reasonless allow-comment."""


def evolve_availability(avail):
    # lint: allow(gather-then-reduce)
    return avail
