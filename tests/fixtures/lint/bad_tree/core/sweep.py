"""Known-bad lint fixture: structural-field violations, both directions."""

# BAD: "not_a_real_field" is not an FLConfig field (converse check)
STATIC_FIELDS = ("num_clients", "not_a_real_field")


def _build_runner(fl):
    # BAD: eval_every read in control flow but missing from STATIC_FIELDS
    if fl.eval_every == 1:
        return 1
    return 2


def _build_sharded_group_runner(fl):
    # BAD via alias: cadence derives from fl.record_lambda_every
    cadence = fl.record_lambda_every
    while cadence > 0:
        cadence -= 1
    return cadence
