"""Known-bad lint fixture: sharded-randomness + gather-then-reduce.

Never imported — parsed by ``repro.lint`` self-tests. The function names
deliberately collide with the real registry entries so the rules scope in.
"""
import jax
import jax.numpy as jnp

from repro.core.sharding import all_gather_axis


def make_control_sharded_round_fn(key, n_local, axis_name):
    def round_fn(v_local):
        # BAD: local-shaped draw, not content-addressed by client id
        noise = jax.random.normal(key, (n_local,))
        # BAD: gather-then-reduce via a tainted name
        accs = all_gather_axis(v_local, axis_name)
        mean = jnp.mean(accs)
        # BAD: gather-then-reduce, nested call form
        nested = jnp.mean(all_gather_axis(v_local, axis_name))
        return noise, mean, nested

    return round_fn


def _batch_indices_ids(key, ids):
    # lint: allow(sharded-randomness): fixture — a reasoned suppression must hold
    return jax.random.uniform(key, ids.shape)
