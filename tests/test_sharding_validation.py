"""Single-device regression suite for ISSUE 7's satellite fixes.

Runs in the tier-1 fast lane (no multi-device mesh needed):

  - ``sharding.resolve_device_count`` raises the same actionable error as
    ``_mesh`` on an over-request instead of silently clamping;
  - ``sharding.pad_to_multiple`` / ``population_device_count`` validate
    their inputs (the empty-seed ZeroDivisionError, the N=0 infinite loop,
    the stray ``"auto"`` treated as truthy garbage);
  - the §IV-A truncation floor 0.05 has exactly ONE definition
    (``energy.TRUNCATION_FLOOR``) — ``transport.py`` used to hard-code the
    literal in its three ``digital_*`` signatures, so changing the paper
    constant in one place silently desynchronized the digital scheme;
  - the ``control_plane`` structural knob validates its value and its
    argument coupling.
"""
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro import lint
from repro.configs.base import FLConfig
from repro.core import energy, sharding, transport
from repro.core.simulator import init_sim_state, make_param_round_fn
from repro.models.logreg import logistic_regression

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"


# ---------------------------------------------------------------------------
# resolve_device_count: over-request must raise, not clamp
# ---------------------------------------------------------------------------


def test_resolve_device_count_over_request_raises():
    n = jax.device_count()
    with pytest.raises(ValueError, match=rf"requested {n + 1} devices"):
        sharding.resolve_device_count(n + 1)


def test_resolve_device_count_matches_mesh_error():
    # the satellite's contract: resolve_device_count and _mesh agree — both
    # raise (neither clamps) and both name the present device count
    n = jax.device_count()
    with pytest.raises(ValueError, match=rf"only {n} present"):
        sharding.resolve_device_count(n + 3)
    with pytest.raises(ValueError):
        sharding._mesh(n + 3, "cells")


@pytest.mark.parametrize("bad", ["8", 2.0, True, [4]])
def test_resolve_device_count_rejects_non_int(bad):
    with pytest.raises(TypeError, match="devices must be"):
        sharding.resolve_device_count(bad)


def test_resolve_device_count_valid_inputs():
    assert sharding.resolve_device_count(None) == 1
    assert sharding.resolve_device_count("auto") == jax.device_count()
    assert sharding.resolve_device_count(1) == 1
    with pytest.raises(ValueError, match=">= 1"):
        sharding.resolve_device_count(0)


# ---------------------------------------------------------------------------
# population_device_count / pad_to_multiple input validation
# ---------------------------------------------------------------------------


def test_population_device_count_rejects_zero_clients():
    # used to never terminate: the divisor search decremented from D toward
    # a modulus that 0 satisfies for no positive divisor ordering
    with pytest.raises(ValueError, match="num_clients must be >= 1"):
        sharding.population_device_count(0)
    with pytest.raises(ValueError, match="num_clients"):
        sharding.population_device_count(-4, 8)


@pytest.mark.parametrize("bad", ["auto", "8", 2.5, True])
def test_population_device_count_rejects_non_int_devices(bad):
    with pytest.raises(TypeError, match="devices must be"):
        sharding.population_device_count(16, bad)


def test_population_device_count_auto_hint_names_resolver():
    with pytest.raises(TypeError, match="resolve_device_count"):
        sharding.population_device_count(16, "auto")


def test_population_device_count_divisor_search():
    assert sharding.population_device_count(16, 8) == 8
    assert sharding.population_device_count(12, 8) == 6
    assert sharding.population_device_count(7, 8) == 7
    assert sharding.population_device_count(13, 8) == 1
    with pytest.raises(ValueError, match="devices must be >= 1"):
        sharding.population_device_count(16, 0)


def test_pad_to_multiple_rejects_empty():
    # used to crash with ZeroDivisionError deep in the modulo
    with pytest.raises(ValueError, match="at least one value"):
        sharding.pad_to_multiple([], 4)


@pytest.mark.parametrize("bad", [0, -2, 1.5, "4", True])
def test_pad_to_multiple_rejects_bad_multiple(bad):
    with pytest.raises(ValueError, match="multiple must be"):
        sharding.pad_to_multiple([1, 2], bad)


def test_pad_to_multiple_pads_cyclically():
    assert sharding.pad_to_multiple([5, 7, 9], 4) == [5, 7, 9, 5]
    assert sharding.pad_to_multiple([1], 3) == [1, 1, 1]
    assert sharding.pad_to_multiple([1, 2], 2) == [1, 2]
    assert sharding.pad_to_multiple([1, 2], 1) == [1, 2]


# ---------------------------------------------------------------------------
# Truncation floor: single source of truth (satellite 3)
# ---------------------------------------------------------------------------


def test_truncation_floor_literal_defined_once():
    """The §IV-A truncation floor has exactly ONE defining literal —
    machine-enforced by ``repro.lint``'s single-source-literal rule (ISSUE 9
    migrated the hand-rolled tokenize walk that used to live here onto the
    declarative ``registry.SINGLE_SOURCE_LITERALS``). transport.py used to
    repeat the 0.05 as three keyword defaults; comments/docstrings citing
    the value are prose, not a second source of truth, and don't count."""
    from repro.lint.rules import SingleSourceLiteralRule

    rule = SingleSourceLiteralRule(SRC)
    violations = [v for src in lint.iter_source_files(SRC)
                  for v in rule.run(src)]
    assert violations == [], [v.format() for v in violations]


def test_truncation_floor_rule_fires_on_seeded_duplicate(tmp_path):
    """The migrated rule still has teeth: a drifted copy of the 0.05 literal
    anywhere in core/ is flagged at its exact site."""
    from repro.lint.rules import SingleSourceLiteralRule

    core = tmp_path / "core"
    core.mkdir()
    (core / "energy.py").write_text("TRUNCATION_FLOOR = 0.05\n")
    (core / "transport.py").write_text(
        "def digital_rate(h, floor=0.05):\n    return h - floor\n")
    rule = SingleSourceLiteralRule(tmp_path)
    violations = [v for src in lint.iter_source_files(tmp_path)
                  for v in rule.run(src)]
    assert [(v.path, v.line, v.rule) for v in violations] == \
        [("core/transport.py", 1, "single-source-literal")]
    assert "TRUNCATION_FLOOR" in violations[0].message


def test_transport_digital_defaults_are_truncation_floor():
    import inspect

    for fn in (transport.digital_rate, transport.digital_latency,
               transport.digital_energy):
        sig = inspect.signature(fn)
        assert sig.parameters["floor"].default is energy.TRUNCATION_FLOOR, \
            f"{fn.__name__} floor default is not energy.TRUNCATION_FLOOR"


def test_config_floor_default_matches_energy_constant():
    # configs/base.py cannot import core (cycle through core/__init__), so
    # its channel_floor default is pinned here instead
    assert FLConfig().channel_floor == energy.TRUNCATION_FLOOR


# ---------------------------------------------------------------------------
# control_plane knob validation
# ---------------------------------------------------------------------------


def _tiny():
    return FLConfig(num_clients=4, clients_per_round=2, rounds=1,
                    batch_size=2)


def test_control_plane_rejects_unknown_value():
    fl = FLConfig(num_clients=4, clients_per_round=2, rounds=1, batch_size=2,
                  control_plane="bogus")
    model = logistic_regression(dim=8, num_classes=2)
    with pytest.raises(ValueError, match="control_plane"):
        make_param_round_fn(model, fl, (None,) * 4, 10, "fedavg")


def test_control_plane_sharded_rejects_dense():
    from dataclasses import replace

    fl = replace(_tiny(), control_plane="sharded")
    model = logistic_regression(dim=8, num_classes=2)
    with pytest.raises(ValueError, match="dense"):
        make_param_round_fn(model, fl, (None,) * 4, 10, "fedavg", dense=True)


def test_init_sim_state_ids_needs_sharded_control_plane():
    model = logistic_regression(dim=8, num_classes=2)
    with pytest.raises(ValueError, match="control_plane"):
        init_sim_state(model, _tiny(), jax.random.PRNGKey(0),
                       ids=jnp.arange(4))


def test_init_sim_state_sharded_local_rows():
    from dataclasses import replace

    fl = replace(_tiny(), control_plane="sharded",
                 temporal=True, rho_fading=0.9)
    model = logistic_regression(dim=8, num_classes=2)
    st = init_sim_state(model, fl, jax.random.PRNGKey(0),
                        ids=jnp.arange(2, dtype=jnp.int32))
    assert st.lam.shape == (2,)
    assert float(jnp.sum(st.lam)) == pytest.approx(0.5)  # rows of the 1/N simplex
    assert st.chan_state.battery.shape == (2,)
    # the same two rows of the full-population init, bit-for-bit (the
    # content-addressing contract)
    full = init_sim_state(model, fl, jax.random.PRNGKey(0))
    assert (st.chan_state.fast == full.chan_state.fast[:, :2]).all()
