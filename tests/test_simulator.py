"""Paper-scale simulator tests: Algorithm 1 end-to-end on small N/T + the
paper's qualitative claims at reduced scale."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.simulator import run_simulation
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression


@pytest.fixture(scope="module")
def sim_data():
    x, y, xt, yt = make_fmnist_like(num_train=2000, num_test=500, dim=64,
                                    seed=0)
    xs, ys = sorted_label_shards(x, y, 20)
    # stacked per-client test shards for worst-client metrics
    xts, yts = sorted_label_shards(xt, yt, 20)
    return xs, ys, xts, yts


def _fl(method="ca_afl", rounds=30, **kw):
    return FLConfig(num_clients=20, clients_per_round=8, rounds=rounds,
                    batch_size=20, method=method, lr0=0.3, lr_decay=0.995,
                    ascent_lr=2e-2, **kw)


MODEL = logistic_regression(dim=64, num_classes=10)


def test_simulator_runs_and_learns(sim_data):
    hist = run_simulation(MODEL, _fl("ca_afl"), sim_data)
    assert hist.avg_acc.shape == (30,)
    assert float(hist.avg_acc[-1]) > 0.5          # learns
    assert float(hist.loss[0]) > float(hist.loss[-1])
    assert bool(jnp.all(jnp.isfinite(hist.energy)))
    assert bool(jnp.all(hist.energy[1:] >= hist.energy[:-1]))  # cumulative


@pytest.mark.parametrize("method", ["fedavg", "afl", "greedy", "gca"])
def test_all_baselines_run(sim_data, method):
    hist = run_simulation(MODEL, _fl(method, rounds=10), sim_data)
    assert bool(jnp.all(jnp.isfinite(hist.avg_acc)))
    if method == "gca":
        counts = np.asarray(hist.num_scheduled)
        assert counts.std() > 0  # variable scheduled count
    else:
        np.testing.assert_allclose(np.asarray(hist.num_scheduled), 8)


def test_energy_ordering_greedy_ca_afl_afl(sim_data):
    """Paper's Fig. 3: greedy <= CA-AFL(C=8) <= AFL in energy."""
    e = {}
    for method, c in (("greedy", 0.0), ("ca_afl", 8.0), ("afl", 0.0)):
        hist = run_simulation(MODEL, _fl(method, energy_C=c), sim_data)
        e[method] = float(hist.energy[-1])
    assert e["greedy"] < e["ca_afl"] < e["afl"]


@pytest.mark.slow
def test_ca_afl_c0_statistically_afl(sim_data):
    """C=0 has the same expected energy as AFL (same sampling law)."""
    runs = {m: [] for m in ("afl", "c0")}
    for s in range(3):
        runs["afl"].append(float(run_simulation(
            MODEL, _fl("afl"), sim_data, seed=s).energy[-1]))
        runs["c0"].append(float(run_simulation(
            MODEL, _fl("ca_afl", energy_C=0.0), sim_data, seed=s).energy[-1]))
    a, c = np.mean(runs["afl"]), np.mean(runs["c0"])
    assert abs(a - c) / a < 0.25


@pytest.mark.slow
def test_dro_improves_worst_client(sim_data):
    """AFL-style methods beat FedAvg on worst-client accuracy (Fig. 2b).

    Three seeds: the two-seed estimate sits exactly on the 0.02 tolerance
    boundary (fedavg 0.108 vs afl 0.088) and fails by float-epsilon; the
    statistical claim needs the extra seed at this tiny scale.
    """
    worst = {}
    seeds = range(3)
    for method in ("fedavg", "afl"):
        hists = [run_simulation(MODEL, _fl(method, rounds=60), sim_data, seed=s)
                 for s in seeds]
        worst[method] = np.mean(
            [float(jnp.mean(h.worst_acc[-5:])) for h in hists])
    assert worst["afl"] > worst["fedavg"] - 0.02


def test_increasing_c_reduces_energy(sim_data):
    energies = []
    for c in (0.0, 2.0, 8.0, 32.0):
        h = run_simulation(MODEL, _fl("ca_afl", energy_C=c), sim_data)
        energies.append(float(h.energy[-1]))
    # monotone non-increasing (allow small stochastic wiggle)
    for lo, hi in zip(energies[1:], energies[:-1], strict=True):
        assert lo < hi * 1.10
    assert energies[-1] < energies[0] * 0.7
