"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs ref.py
oracle, per the assignment (assert_allclose on every combination)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.aircomp.kernel import aircomp_pallas
from repro.kernels.aircomp.ops import aircomp_aggregate_flat
from repro.kernels.aircomp.ref import aircomp_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

TOL = {jnp.float32: dict(rtol=2e-3, atol=2e-3),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


# ---------------------------------------------------------------------------
# aircomp
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(4, 128), (100, 7850), (7, 333), (40, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_aircomp_sweep(n, m, dtype, key):
    x = jax.random.normal(key, (n, m), dtype)
    w = (jax.random.uniform(jax.random.fold_in(key, 1), (n,)) > 0.5
         ).astype(jnp.float32)
    z = jax.random.normal(jax.random.fold_in(key, 2), (m,), jnp.float32)
    out = aircomp_pallas(x, w, z, noise_std=0.3, k=max(float(w.sum()), 1.0),
                         interpret=True)
    ref = aircomp_ref(x, w, z, 0.3, max(float(w.sum()), 1.0))
    np.testing.assert_allclose(out, ref, **TOL[dtype])


def test_aircomp_ops_dispatch(key):
    x = jax.random.normal(key, (10, 500))
    w = jnp.ones((10,))
    z = jnp.zeros((500,))
    a = aircomp_aggregate_flat(x, w, z, noise_std=0.0, k=10.0,
                               use_pallas=True)
    b = aircomp_aggregate_flat(x, w, z, noise_std=0.0, k=10.0,
                               use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r,d", [(8, 128), (300, 512), (1024, 896), (5, 6144)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(r, d, dtype, key):
    x = jax.random.normal(key, (r, d), dtype)
    s = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    out = rmsnorm_pallas(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_rmsnorm_ops_nd(key):
    x = jax.random.normal(key, (2, 7, 384))
    s = jnp.ones((384,))
    out = rmsnorm(x, s, use_pallas=True)
    ref = rmsnorm(x, s, use_pallas=False)
    assert out.shape == x.shape
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,hkv,g,sq,t,d", [
    (1, 1, 1, 128, 128, 64),     # minimal
    (2, 2, 3, 128, 128, 64),     # GQA group routing
    (1, 1, 48, 128, 128, 128),   # granite-like kv=1
    (2, 4, 2, 256, 256, 128),    # qwen-like
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hkv, g, sq, t, d, dtype, key):
    q = jax.random.normal(key, (b * hkv * g, sq, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b * hkv, t, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b * hkv, t, d), dtype)
    o = flash_attention_pallas(q, k, v, group=g, causal=True,
                               tq=64, tk=64, interpret=True)
    ref = attention_ref(q.reshape(b, hkv * g, sq, d),
                        k.reshape(b, hkv, t, d),
                        v.reshape(b, hkv, t, d),
                        causal=True).reshape(b * hkv * g, sq, d)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_attention_window_sweep(window, key):
    b, hkv, g, s, d = 1, 2, 2, 128, 64
    q = jax.random.normal(key, (b * hkv * g, s, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b * hkv, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b * hkv, s, d))
    o = flash_attention_pallas(q, k, v, group=g, causal=True, window=window,
                               tq=32, tk=32, interpret=True)
    ref = attention_ref(q.reshape(b, hkv * g, s, d),
                        k.reshape(b, hkv, s, d),
                        v.reshape(b, hkv, s, d),
                        causal=True, window=window
                        ).reshape(b * hkv * g, s, d)
    np.testing.assert_allclose(o, ref, rtol=2e-3, atol=2e-3)


def test_flash_attention_noncausal(key):
    b, hkv, g, s, d = 1, 2, 1, 64, 64
    q = jax.random.normal(key, (b * hkv * g, s, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b * hkv, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b * hkv, s, d))
    o = flash_attention_pallas(q, k, v, group=g, causal=False,
                               tq=32, tk=32, interpret=True)
    ref = attention_ref(q.reshape(b, hkv * g, s, d),
                        k.reshape(b, hkv, s, d),
                        v.reshape(b, hkv, s, d),
                        causal=False).reshape(b * hkv * g, s, d)
    np.testing.assert_allclose(o, ref, rtol=2e-3, atol=2e-3)


def test_flash_ops_model_layout_matches_chunked_attention(key):
    """ops.flash_attention (model layout) == models.attention oracle."""
    from repro.models.attention import attention as model_attn
    b, s, hkv, g, d = 2, 128, 2, 2, 64
    q = jax.random.normal(key, (b, s, hkv, g, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    o1 = flash_attention(q, k, v, causal=True, tq=64, tk=64, use_pallas=True)
    o2 = model_attn(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(o1, o2, rtol=2e-3, atol=2e-3)
