"""Distribution machinery: HLO cost analyzer, spec selection, small-mesh
end-to-end sharded round, and a subprocess dry-run on a tiny forced mesh."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import MeshAxes
from repro.models.specs import ShardingCtx, pad_vocab
from repro.utils.hlo_cost import analyze_hlo
from repro.utils.roofline import Roofline, model_flops

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# HLO cost analyzer
# ---------------------------------------------------------------------------


def test_analyzer_counts_scan_trips():
    L, N = 8, 128

    def step(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        return jax.lax.scan(body, x, w)[0]

    c = jax.jit(step).lower(
        jax.ShapeDtypeStruct((L, N, N), jnp.float32),
        jax.ShapeDtypeStruct((4, N), jnp.float32)).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.flops == pytest.approx(2 * 4 * N * N * L, rel=0.01)


def test_analyzer_counts_backward_three_matmuls():
    L, N = 4, 64

    def step(w, x):
        def loss(w_):
            def body(c, wl):
                return jnp.tanh(c @ wl), None
            return jnp.sum(jax.lax.scan(body, x, w_)[0] ** 2)
        return jax.grad(loss)(w)

    c = jax.jit(step).lower(
        jax.ShapeDtypeStruct((L, N, N), jnp.float32),
        jax.ShapeDtypeStruct((2, N), jnp.float32)).compile()
    cost = analyze_hlo(c.as_text())
    # fwd + dgrad + wgrad = 3 matmuls per layer
    assert cost.flops == pytest.approx(3 * 2 * 2 * N * N * L, rel=0.05)


def test_analyzer_bytes_reasonable():
    def f(a, b):
        return a @ b

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    cost = analyze_hlo(c.as_text())
    lo = 3 * 256 * 256 * 4          # two reads + one write
    assert lo <= cost.bytes <= 4 * lo


# ---------------------------------------------------------------------------
# Roofline math
# ---------------------------------------------------------------------------


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, bytes_hbm=819e9 / 2, bytes_wire=0.0,
                 chips=256, model_flops=197e12 * 256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.bottleneck == "compute"
    assert r.useful_ratio == pytest.approx(1.0)


def test_model_flops_train_vs_decode():
    from repro.configs import get_config, get_shape
    cfg = get_config("qwen2-0.5b")
    n = 500_000_000
    tr = model_flops(cfg, get_shape("train_4k"), n)
    de = model_flops(cfg, get_shape("decode_32k"), n)
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert de == pytest.approx(2 * n * 128)


def test_moe_active_params():
    from repro.configs import get_config
    from repro.utils.roofline import active_params
    cfg = get_config("qwen3-moe-30b-a3b")
    total = 30_000_000_000
    act = active_params(cfg, total)
    assert act < 0.2 * total  # top-8 of 128 experts


# ---------------------------------------------------------------------------
# Spec selection
# ---------------------------------------------------------------------------


class _FakeCtx(ShardingCtx):
    def __init__(self, model_size=16, data_size=16, fsdp=True):
        self.mesh = object()
        self.axes = MeshAxes()
        self.model_size = model_size
        self.data_size = data_size
        self.fsdp = fsdp


def test_attn_spec_picker_prefers_divisible_axes():
    ctx = _FakeCtx()
    # granite: kv=1, G=48, hd=128 -> shard G
    assert ctx.attn_q_spec(1, 48, 128) == P("data", None, "model", None)
    # qwen2-7b: kv=4, G=7, hd=128 -> shard hd
    assert ctx.attn_q_spec(4, 7, 128) == P("data", None, None, "model")
    # zamba2: kv=32 -> shard kv heads
    assert ctx.attn_q_spec(32, 1, 64) == P("data", "model", None, None)


def test_vocab_padding():
    assert pad_vocab(49152) == 49152         # already a multiple of 512
    assert pad_vocab(151936) == 152064
    assert pad_vocab(256206) == 256512       # seamless's awkward vocab


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-30b-a3b",
                                  "xlstm-1.3b", "zamba2-1.2b",
                                  "llama-3.2-vision-11b",
                                  "seamless-m4t-medium"])
def test_param_specs_match_params(arch):
    """Every param leaf has a spec with matching rank and divisible dims."""
    from repro.configs import get_config
    from repro.models.api import build_model
    cfg = get_config(arch)
    model = build_model(cfg)
    ctx = _FakeCtx()
    params_abs = model.abstract_params()
    specs = model.param_specs(ctx)
    flat_p = jax.tree_util.tree_leaves(params_abs)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    sizes = {"data": 16, "model": 16}
    for leaf, spec in zip(flat_p, flat_s, strict=True):
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim,
                           strict=False):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            factor = int(np.prod([sizes[a] for a in axes]))
            assert dim % factor == 0, (leaf.shape, spec)


# ---------------------------------------------------------------------------
# Small-mesh end-to-end (8 forced host devices in a subprocess)
# ---------------------------------------------------------------------------

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.models.api import build_model
from repro.models.specs import ShardingCtx
from repro.federated.rounds import make_fl_round
from repro.optim import sgd

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_reduced("qwen2-0.5b").with_(dtype="float32", remat=False,
                                      d_model=256, num_heads=4, num_kv_heads=2)
ctx = ShardingCtx(mesh)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = sgd(0.1)
key = jax.random.PRNGKey(1)
B, S, N = 8, 16, 4
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "client_ids": jnp.repeat(jnp.arange(N), B // N)}
mask = jnp.array([1., 0., 1., 0.])

# sharded round
pspecs = model.param_specs(ctx)
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                               is_leaf=lambda x: isinstance(x, P))
ps = jax.device_put(params, named(pspecs))
st = opt.init(ps)
rnd = make_fl_round(model, opt, N, 2, noise_std=0.0, ctx=ctx)
with mesh:
    p_sh, _, m_sh = jax.jit(rnd)(ps, st, batch, mask, key)

# unsharded reference
rnd0 = make_fl_round(model, opt, N, 2, noise_std=0.0, ctx=None)
p_ref, _, m_ref = jax.jit(rnd0)(params, opt.init(params), batch, mask, key)

np.testing.assert_allclose(float(m_sh.loss), float(m_ref.loss), rtol=1e-4)
np.testing.assert_allclose(np.asarray(m_sh.client_losses),
                           np.asarray(m_ref.client_losses), rtol=1e-3)
for a, b in zip(jax.tree_util.tree_leaves(p_sh),
                jax.tree_util.tree_leaves(p_ref)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_round_matches_unsharded():
    """The 4x2-mesh FL round reproduces the single-device round exactly —
    proves the sharding (specs + constraints) does not change semantics."""
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"},
        cwd=str(REPO))
    assert "SHARDED_OK" in res.stdout, res.stderr[-3000:]
