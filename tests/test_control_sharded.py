"""ISSUE 7 differential suite: the sharded control plane.

``control_plane="sharded"`` replaces the replicate-full-[N]-then-slice
discipline with per-client draws content-addressed by GLOBAL client id plus
a hierarchical (per-shard → group → global) exact-K top-k, so each device
materializes only N/D rows of channels, availability, scores, λ and
``ChanState``.

Pinned here:
  - the mesh-sharded program agrees with the unsharded reference (the SAME
    discipline at ``ids = arange(N)``) for every method ×
    {default, markov_fading, battery_constrained} and across the uplink
    transports. Per-client values are sharding-independent by construction
    (same fold_in streams, ownership-psum adds exact zeros, the tree top-k
    preserves dense tie-breaks); the two *compiled* programs differ only by
    XLA's shape-dependent FMA contraction — so discrete decisions
    (scheduled counts, availability) are asserted EXACTLY and continuous
    histories to a few ulps (``FMA_TOL``);
  - ``hierarchical_top_k`` equals dense ``lax.top_k`` — ties straddling
    shard boundaries, k > n_local, all-(-inf) shards, -inf-padded
    indivisible N, every tree fan-in;
  - the cross-tier contract (``ParameterServer`` vs simulator) holds under
    the sharded discipline (single-device, tier-1 lane);
  - an N=100k smoke on 8 forced host devices (slow lane).
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import sharding
from repro.core.channel import SCENARIOS
from repro.core.simulator import (_batch_indices_ids, init_sim_state,
                                  make_param_round_fn, run_simulation)
from repro.core.sweep import sweep_point_from_config
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression
from repro.utils.tree import tree_size

multidev = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="multi-device suite: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

N, DIM = 16, 32
MODEL = logistic_regression(dim=DIM, num_classes=10)
# Per-client values are identical by construction; the compiled unsharded
# and sharded programs differ only by XLA's shape-dependent instruction
# selection (FMA contraction of mul+add chains) — a few ulps on
# channel/energy values, never a decision flip at these seeds.
FMA_TOL = dict(rtol=2e-5, atol=2e-6)
EXACT_FIELDS = ("num_scheduled", "avail_count")


@pytest.fixture(scope="module")
def cs_data():
    x, y, xt, yt = make_fmnist_like(num_train=640, num_test=320, dim=DIM,
                                    seed=0)
    xs, ys = sorted_label_shards(x, y, N)
    xts, yts = sorted_label_shards(xt, yt, N)
    return xs, ys, xts, yts


def _fl(method="ca_afl", rounds=4, **kw):
    return FLConfig(num_clients=N, clients_per_round=5, rounds=rounds,
                    batch_size=16, method=method, lr0=0.3, lr_decay=0.995,
                    ascent_lr=2e-2, control_plane="sharded", **kw)


def _assert_agrees(ref, sh):
    for f in ref._fields:
        a, b = np.asarray(getattr(ref, f)), np.asarray(getattr(sh, f))
        if f in EXACT_FIELDS:
            np.testing.assert_array_equal(a, b, err_msg=f"field {f}")
        else:
            np.testing.assert_allclose(b, a, err_msg=f"field {f}", **FMA_TOL)


# ---------------------------------------------------------------------------
# Unsharded sharded-discipline program (tier-1 lane, single device)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["fedavg", "afl", "ca_afl", "greedy",
                                    "gca"])
def test_sharded_discipline_runs(cs_data, method):
    h = run_simulation(MODEL, _fl(method), cs_data, seed=0)
    assert np.isfinite(np.asarray(h.avg_acc)).all()
    assert np.isfinite(np.asarray(h.lam)).all()
    assert h.lam.shape == (4, N)
    np.testing.assert_allclose(np.asarray(h.lam).sum(axis=1), 1.0, rtol=1e-5)
    if method != "gca":
        # static scenario: exact-K methods schedule exactly K every round
        np.testing.assert_array_equal(np.asarray(h.num_scheduled), 5.0)


@pytest.mark.parametrize("eval_every", [1, 2])
def test_eval_stats_psum_form_matches_unsharded(cs_data, eval_every):
    """ISSUE 9 regression: the sharded round's test-eval statistics are
    psum-of-local-rows (mean/min via psum/pmin, std via the two-pass
    centered variance) instead of the old all_gather + jnp.{mean,min,std} —
    the one remaining O(N) gather on the exact-K path. A size-1 clients
    mesh runs the psum-form program in the tier-1 lane; it must agree with
    the unsharded stack-form reference to summation-order ulps, on both the
    per-round and the cond-gated (eval_every > 1) eval programs."""
    fl = replace(_fl("ca_afl"), eval_every=eval_every)
    mesh = sharding.client_mesh(1)
    ref = run_simulation(MODEL, fl, cs_data, seed=0)
    sh = sharding.run_simulation_control_sharded(MODEL, fl, cs_data, mesh,
                                                 seed=0)
    _assert_agrees(ref, sh)


def test_sharded_discipline_deterministic(cs_data):
    h1 = run_simulation(MODEL, _fl(), cs_data, seed=3)
    h2 = run_simulation(MODEL, _fl(), cs_data, seed=3)
    for f in h1._fields:
        np.testing.assert_array_equal(np.asarray(getattr(h1, f)),
                                      np.asarray(getattr(h2, f)))
    h3 = run_simulation(MODEL, _fl(), cs_data, seed=4)
    assert not np.array_equal(np.asarray(h1.energy), np.asarray(h3.energy))


def test_batch_indices_content_addressed():
    key = jax.random.PRNGKey(11)
    ids = jnp.arange(12, dtype=jnp.int32)
    full = _batch_indices_ids(key, ids, 7, 5)
    # any slice of the population draws ITS rows bit-identically, and so
    # does a gather of an arbitrary winner subset — the property the
    # selected-K slot path relies on
    np.testing.assert_array_equal(
        np.asarray(_batch_indices_ids(key, ids[4:9], 7, 5)),
        np.asarray(full[4:9]))
    win = jnp.asarray([10, 0, 7], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(_batch_indices_ids(key, win, 7, 5)),
        np.asarray(full[win]))


def test_sharded_discipline_cross_tier():
    """One ``ParameterServer.step`` == one simulator round under the sharded
    discipline (same 7-way key split, now per-id streams on both tiers)."""
    from repro.federated.server import ParameterServer, ServerState
    from repro.models.logreg import logistic_regression_prod
    from repro.optim import sgd

    n, dim, cls, per = 6, 16, 10, 4
    key = jax.random.PRNGKey(7)
    xs = jax.random.normal(key, (n, 1, dim))
    ys = jax.random.randint(jax.random.fold_in(key, 1), (n, 1), 0, cls)
    for method in ("ca_afl", "greedy"):
        fl = FLConfig(num_clients=n, clients_per_round=3, rounds=1,
                      batch_size=per, local_steps=1, method=method, lr0=0.2,
                      ascent_lr=1e-2, energy_C=4.0, control_plane="sharded")
        sim_model = logistic_regression(dim, cls)
        point = sweep_point_from_config(fl)
        state = init_sim_state(sim_model, fl, jax.random.PRNGKey(0),
                               process=point.process)
        round_fn = make_param_round_fn(sim_model, fl, (xs, ys, xs, ys),
                                       tree_size(state.w), method)
        new_state, hist = jax.jit(lambda p, s: round_fn(p, s, 0))(point,
                                                                  state)

        prod_model = logistic_regression_prod(dim, cls)
        ps = ParameterServer(prod_model, sgd(fl.lr0), fl, seed=0)
        ps.key = state.key
        srv = ServerState(params=jax.tree.map(jnp.asarray, state.w),
                          opt_state=sgd(fl.lr0).init(state.w),
                          lam=state.lam)
        batch = {"x": jnp.repeat(xs[:, 0, :], per, axis=0),
                 "labels": jnp.repeat(ys[:, 0], per, axis=0),
                 "client_ids": jnp.repeat(jnp.arange(n), per)}
        srv = ps.step(srv, batch)

        assert srv.history[-1]["num_scheduled"] == int(hist.num_scheduled)
        np.testing.assert_allclose(srv.energy_joules, float(hist.energy),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(srv.lam),
                                   np.asarray(new_state.lam), atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(srv.params),
                        jax.tree_util.tree_leaves(new_state.w),
                        strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Mesh differential: sharded program == unsharded reference
# ---------------------------------------------------------------------------


POP_SCENARIOS = ("default", "markov_fading", "battery_constrained")


@multidev
@pytest.mark.parametrize("scenario", POP_SCENARIOS)
@pytest.mark.parametrize("method", ["fedavg", "afl", "ca_afl", "greedy",
                                    "gca"])
def test_control_sharded_matches_unsharded(cs_data, method, scenario):
    fl = replace(_fl(method), **SCENARIOS[scenario])
    if scenario == "battery_constrained":
        fl = replace(fl, battery_init=0.05)  # some rounds transmit at N=16
    mesh = sharding.client_mesh(sharding.population_device_count(N))
    assert mesh.size > 1
    ref = run_simulation(MODEL, fl, cs_data, seed=0)
    sh = run_simulation(MODEL, fl, cs_data, seed=0, mesh=mesh)
    _assert_agrees(ref, sh)


@multidev
@pytest.mark.parametrize("transport", ["quantized", "digital"])
@pytest.mark.parametrize("method", ["fedavg", "ca_afl", "gca"])
def test_control_sharded_matches_unsharded_transport(cs_data, method,
                                                     transport):
    # the transport axis crosses the two aggregation code paths: the
    # exact-K [K]-stack path (identical for all EXACT_K_METHODS) and GCA's
    # local-psum path — fedavg/ca_afl cover λ-free and λ-driven scoring
    fl = replace(_fl(method), transport=transport)
    mesh = sharding.client_mesh(sharding.population_device_count(N))
    ref = run_simulation(MODEL, fl, cs_data, seed=0)
    sh = run_simulation(MODEL, fl, cs_data, seed=0, mesh=mesh)
    _assert_agrees(ref, sh)


@multidev
@pytest.mark.parametrize("group_size", [1, 2, 4, 8])
def test_control_sharded_group_size(cs_data, group_size):
    # every tree fan-in (1 and 8 degenerate to the flat pass at D=8, 2 and 4
    # exercise both gather stages) selects identically
    fl = _fl()
    mesh = sharding.client_mesh(8)
    ref = run_simulation(MODEL, fl, cs_data, seed=0)
    sh = sharding.run_simulation_control_sharded(MODEL, fl, cs_data, mesh,
                                                 seed=0,
                                                 group_size=group_size)
    _assert_agrees(ref, sh)


@multidev
def test_control_sharded_lambda_stitching(cs_data):
    # λ history rows come back in global client order, not shard order
    fl = _fl("afl", rounds=3)
    mesh = sharding.client_mesh(8)
    ref = run_simulation(MODEL, fl, cs_data, seed=1)
    sh = run_simulation(MODEL, fl, cs_data, seed=1, mesh=mesh)
    assert sh.lam.shape == (3, N)
    np.testing.assert_allclose(np.asarray(sh.lam), np.asarray(ref.lam),
                               **FMA_TOL)


@multidev
def test_control_sharded_rejects_indivisible():
    fl = replace(_fl(), num_clients=N + 1)
    mesh = sharding.client_mesh(jax.device_count())
    with pytest.raises(ValueError, match="N % devices"):
        sharding.run_simulation_control_sharded(MODEL, fl, (None,) * 4, mesh)


@multidev
def test_control_sharded_rejects_replicated_config():
    fl = replace(_fl(), control_plane="replicated")
    mesh = sharding.client_mesh(jax.device_count())
    with pytest.raises(ValueError, match="control_plane"):
        sharding.run_simulation_control_sharded(MODEL, fl, (None,) * 4, mesh)


# ---------------------------------------------------------------------------
# hierarchical_top_k == dense lax.top_k (satellite 4)
# ---------------------------------------------------------------------------


def _run_hier_top_k(scores, k, group_size=None):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = sharding.client_mesh(
        sharding.population_device_count(scores.shape[0]))
    ax = mesh.axis_names[0]
    n_shards = mesh.size

    def body(s):
        return sharding.hierarchical_top_k(s, k, ax, n_shards,
                                           group_size=group_size)

    fn = shard_map(body, mesh=mesh, in_specs=P(ax), out_specs=P(),
                   check_rep=False)
    return np.asarray(jax.jit(fn)(scores))


def _dense_idx(scores, k):
    return np.asarray(jax.lax.top_k(scores, k)[1])


@multidev
@pytest.mark.property
@pytest.mark.parametrize("group_size", [None, 1, 2, 4, 8])
def test_hier_top_k_property_vs_dense(group_size):
    # random draws + heavy quantization (ties straddling shard boundaries)
    for seed in range(8):
        raw = jax.random.normal(jax.random.PRNGKey(seed), (N,))
        for scores in (raw, jnp.round(raw * 2) / 2):
            for k in (1, 3, 5, 13, 16):
                np.testing.assert_array_equal(
                    _run_hier_top_k(scores, k, group_size),
                    _dense_idx(scores, k),
                    err_msg=f"seed={seed} k={k} g={group_size}")


@multidev
def test_hier_top_k_k_exceeds_n_local():
    # k=13 > n_local=2 at D=8: stage-1 candidates cap at n_local and the
    # tree must still recover the exact global winner set
    assert N // jax.device_count() < 13
    scores = jax.random.normal(jax.random.PRNGKey(0), (N,))
    np.testing.assert_array_equal(_run_hier_top_k(scores, 13, 2),
                                  _dense_idx(scores, 13))


@multidev
@pytest.mark.parametrize("group_size", [None, 2])
def test_hier_top_k_all_neg_inf_shards(group_size):
    # entire shards at -inf (fully-unavailable populations) and the fully
    # -inf vector: ties resolve to the lowest global index, like dense
    n_local = N // sharding.population_device_count(N)
    shard_ids = jnp.arange(N) // n_local
    scores = jnp.where(shard_ids % 2 == 0, -jnp.inf, 1.0)
    for k in (3, 8, 12):
        np.testing.assert_array_equal(_run_hier_top_k(scores, k, group_size),
                                      _dense_idx(scores, k))
    all_inf = jnp.full((N,), -jnp.inf)
    np.testing.assert_array_equal(_run_hier_top_k(all_inf, 5, group_size),
                                  _dense_idx(all_inf, 5))


@multidev
def test_hier_top_k_indivisible_population_via_padding():
    # N=20 does not divide 8 shards: the documented recipe pads with -inf
    # rows to the next multiple; winners equal dense top-k on the padded
    # vector (and, for k <= the finite count, on the original)
    n_real, n_dev = 20, jax.device_count()
    n_pad = -(-n_real // n_dev) * n_dev
    raw = jax.random.normal(jax.random.PRNGKey(5), (n_real,))
    padded = jnp.concatenate([raw, jnp.full((n_pad - n_real,), -jnp.inf)])
    for k in (1, 7, 19):
        idx = _run_hier_top_k(padded, k)
        np.testing.assert_array_equal(idx, _dense_idx(padded, k))
        np.testing.assert_array_equal(idx, _dense_idx(raw, k))


# ---------------------------------------------------------------------------
# Large-N smoke (CI large-N lane: -m slow)
# ---------------------------------------------------------------------------


@multidev
@pytest.mark.slow
def test_control_sharded_large_population_smoke():
    """N=100k clients on the forced-8-device host: the O(N/D) control plane
    runs a few rounds end to end and λ stays a valid simplex."""
    n, dim = 100_000, 16
    fl = FLConfig(num_clients=n, clients_per_round=32, rounds=2,
                  batch_size=2, local_steps=1, num_subcarriers=1,
                  method="ca_afl", lr0=0.1, ascent_lr=1e-2,
                  control_plane="sharded", eval_every=2)
    model = logistic_regression(dim=dim, num_classes=4)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, 2, dim), jnp.float32)
    y = jax.random.randint(jax.random.fold_in(key, 1), (n, 2), 0, 4)
    mesh = sharding.client_mesh(jax.device_count())
    hist = run_simulation(model, fl, (x, y, x, y), seed=0, mesh=mesh)
    assert np.isfinite(np.asarray(hist.avg_acc)).all()
    assert np.asarray(hist.num_scheduled).max() <= 32
    np.testing.assert_allclose(np.asarray(hist.lam).sum(axis=1), 1.0,
                               rtol=1e-4)
    assert hist.lam.shape == (2, n)


# ---------------------------------------------------------------------------
# ISSUE 8: psum-bisection projection over randomized shard layouts
# ---------------------------------------------------------------------------


def _run_sharded_projection(v, n_dev):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = sharding.client_mesh(n_dev)
    ax = mesh.axis_names[0]
    fn = shard_map(
        lambda s: sharding.project_simplex_sharded(s, axis_name=ax),
        mesh=mesh, in_specs=P(ax), out_specs=P(ax), check_rep=False)
    return np.asarray(jax.jit(fn)(v))


@multidev
@pytest.mark.property
def test_projection_sharded_property_layouts():
    """project_simplex_sharded over randomized shard layouts: for every
    divisor-of-N device count the mesh result equals the unsharded result
    of the same program (psum order is the ONLY difference) and the sort
    reference, including duplicate scores and -inf rows."""
    from repro.core.dro import project_simplex

    max_dev = jax.device_count()
    for seed in range(6):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 9)) * max_dev
        v = rng.normal(size=n).astype(np.float32) * 10
        if seed % 2:
            v = np.round(v)                      # duplicates at water level
        if seed >= 4:
            v[rng.integers(0, n, size=n // 4)] = -np.inf
        vj = jnp.asarray(v)
        ref = np.asarray(sharding.project_simplex_sharded(vj))
        for d in (2, 4, max_dev):
            if n % d:
                continue
            got = _run_sharded_projection(vj, d)
            np.testing.assert_allclose(got, ref, atol=2e-6,
                                       err_msg=f"seed={seed} d={d}")
        if np.isfinite(v).all():
            np.testing.assert_allclose(
                ref, np.asarray(project_simplex(vj)), atol=2e-6,
                err_msg=f"seed={seed} vs sort")


@multidev
@pytest.mark.property
def test_hier_top_k_property_random_layouts():
    """hierarchical_top_k == dense lax.top_k over randomized (population,
    group_size) layouts with duplicate and -inf scores — the handpicked
    edge cases generalized (ISSUE 8 satellite)."""
    max_dev = jax.device_count()
    for seed in range(6):
        rng = np.random.default_rng(1000 + seed)
        n = int(rng.integers(2, 9)) * max_dev
        raw = rng.normal(size=n).astype(np.float32)
        if seed % 2:
            raw = np.round(raw * 2) / 2
        if seed >= 4:
            raw[rng.integers(0, n, size=n // 3)] = -np.inf
        k = int(rng.integers(1, n + 1))
        g = int(rng.choice([1, 2, 4, max_dev]))
        scores = jnp.asarray(raw)
        np.testing.assert_array_equal(
            _run_hier_top_k(scores, k, g), _dense_idx(scores, k),
            err_msg=f"seed={seed} n={n} k={k} g={g}")


# ---------------------------------------------------------------------------
# ISSUE 8: run_sweep on the 2-D cells × clients mesh
# ---------------------------------------------------------------------------


@multidev
@pytest.mark.parametrize("transport", ["analog", "quantized"])
def test_sweep_2d_mesh_matches_single_device(cs_data, transport):
    """The differential contract extended across the 2-D grid: run_sweep on
    the cells × clients mesh == the 1-D cells mesh == single device, for
    3 methods × 2 scenarios (× 2 transports via the parametrize) — discrete
    fields exact, continuous to ulps."""
    from repro.core.sweep import expand_grid, run_sweep

    base = replace(_fl(rounds=3), transport=transport)
    specs = expand_grid(
        base,
        variants={"ca": {"method": "ca_afl"}, "af": {"method": "afl"},
                  "gr": {"method": "greedy"}},
        scenarios=("default", "heterogeneous_pathloss"))
    n_dev = jax.device_count()
    ref = run_sweep(MODEL, cs_data, specs, seeds=(0,))
    two_d = run_sweep(MODEL, cs_data, specs, seeds=(0,), devices=n_dev,
                      client_devices=max(d for d in (2, 4, n_dev)
                                         if n_dev % d == 0 and N % d == 0))
    one_d = run_sweep(MODEL, cs_data, specs, seeds=(0,), devices=n_dev,
                      client_devices=1)
    for lbl in ref.labels:
        for sweep_hist in (two_d, one_d):
            _assert_agrees(ref.history(lbl), sweep_hist.history(lbl))


@multidev
def test_sweep_2d_mesh_strided_lambda(cs_data):
    # the strided recorder composes with the 2-D mesh: snapshots stitch
    # back to global client order and match the dense rows on the cadence
    fl = replace(_fl(rounds=4), record_lambda_every=2)
    specs = [("s", fl)]
    from repro.core.sweep import run_sweep

    ref = run_sweep(MODEL, cs_data, specs, seeds=(0, 1))
    two_d = run_sweep(MODEL, cs_data, specs, seeds=(0, 1),
                      devices=jax.device_count(), client_devices=4)
    assert np.asarray(two_d.history("s").lam).shape == (2, 2, N)
    np.testing.assert_allclose(np.asarray(two_d.history("s").lam),
                               np.asarray(ref.history("s").lam), **FMA_TOL)


@multidev
def test_factor_client_devices():
    assert sharding.factor_client_devices(16, 8) == 8
    assert sharding.factor_client_devices(12, 8) == 4
    assert sharding.factor_client_devices(7, 8) == 1  # no divisor fits
    assert sharding.factor_client_devices(16, 8, 2) == 2  # explicit wins
    with pytest.raises(ValueError):
        sharding.factor_client_devices(16, 8, 3)  # 3 divides neither
    with pytest.raises(ValueError):
        sharding.factor_client_devices(15, 8, 5)  # 5 divides N, not devices


@multidev
@pytest.mark.slow
def test_sweep_2d_mesh_large_population_smoke():
    """N=50k × 2 sweep cells on the forced-8-device host factored as a
    (2 cells × 4 clients) mesh: the composed O(N/D) path runs end to end,
    the psum-bisection keeps λ a valid simplex, and the strided recorder
    bounds the history to ceil(T/E) rows."""
    from repro.core.sweep import run_sweep

    n, dim = 50_000, 16
    fl = FLConfig(num_clients=n, clients_per_round=32, rounds=2,
                  batch_size=2, local_steps=1, num_subcarriers=1,
                  method="ca_afl", lr0=0.1, ascent_lr=1e-2,
                  control_plane="sharded", eval_every=2,
                  record_lambda_every=2)
    model = logistic_regression(dim=dim, num_classes=4)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, 2, dim), jnp.float32)
    y = jax.random.randint(jax.random.fold_in(key, 1), (n, 2), 0, 4)
    res = run_sweep(model, (x, y, x, y), [("a", fl)], seeds=(0, 1),
                    devices=jax.device_count(), client_devices=4)
    hist = res.history("a")
    assert np.asarray(hist.lam).shape == (2, 1, n)  # ceil(2/2) = 1 snapshot
    np.testing.assert_allclose(np.asarray(hist.lam).sum(-1), 1.0, rtol=1e-4)
    assert np.isfinite(np.asarray(hist.avg_acc)).all()
    assert np.asarray(hist.num_scheduled).max() <= 32
    # the lone snapshot is round 0 (t % E == 0), so pin it against the
    # round-0 summary leaf, not the final round's
    np.testing.assert_allclose(np.asarray(hist.lam_ess)[:, 0],
                               1.0 / (np.asarray(hist.lam)[:, 0] ** 2)
                               .sum(-1), rtol=1e-4)
