"""Unit + property tests for the paper's core machinery (eqs. 3-10, Props 1-2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.aircomp import aircomp_aggregate, aircomp_aggregate_tree
from repro.core.channel import draw_channels, effective_channel
from repro.core.dro import lambda_ascent, project_simplex
from repro.core.energy import round_energy, transmit_energy
from repro.core.poe import ca_afl_pmf, energy_expert_pmf, product_of_experts
from repro.core.selection import gumbel_topk_mask, select_clients

FLOATS = st.floats(min_value=0.05, max_value=10.0, allow_nan=False)


# ---------------------------------------------------------------------------
# Channel + energy (eqs. 3-6)
# ---------------------------------------------------------------------------


def test_channel_truncation_and_shape(key):
    h = draw_channels(key, 100, 64, floor=0.05, flat=True)
    assert h.shape == (100, 64)
    assert float(jnp.min(h)) >= 0.05
    # flat fading: identical across sub-carriers
    np.testing.assert_allclose(h[:, 0], h[:, 63])


def test_channel_frequency_selective(key):
    h = draw_channels(key, 10, 64, flat=False)
    assert float(jnp.std(h[0])) > 0  # varies across sub-carriers


def test_effective_channel_harmonic_mean():
    h = jnp.array([[1.0, 1.0], [1.0, 0.5]])
    eff = effective_channel(h)
    np.testing.assert_allclose(eff[0], 1.0, rtol=1e-6)
    # 1/h_eff^2 = mean(1, 4) = 2.5
    np.testing.assert_allclose(eff[1], 1 / np.sqrt(2.5), rtol=1e-6)


def test_energy_formula():
    # E~ = psi * M * tau / |h|^2  (paper's numbers: M=7850, psi=0.5mW, tau=1ms)
    e = transmit_energy(jnp.array([1.0]), 7850, 0.5e-3, 1e-3)
    np.testing.assert_allclose(e, 7850 * 0.5e-6, rtol=1e-6)
    # round energy only counts the selected set
    h = jnp.array([1.0, 0.5])
    mask = jnp.array([1.0, 0.0])
    np.testing.assert_allclose(
        round_energy(h, mask, 100, 1.0, 1.0), 100.0, rtol=1e-6)


@given(hnp.arrays(np.float32, st.integers(2, 50).map(lambda n: (n,)),
                  elements=FLOATS))
@settings(max_examples=50, deadline=None)
def test_energy_monotone_in_channel(h):
    """Better channel => lower upload energy (eq. 5 inverse-square)."""
    e = np.asarray(transmit_energy(jnp.asarray(h), 100, 1e-3, 1e-3))
    order_h = np.argsort(h)
    order_e = np.argsort(-e)
    assert np.array_equal(order_h, order_e) or np.allclose(
        np.sort(h), h[order_e][::-1])


# ---------------------------------------------------------------------------
# PoE PMF (Prop. 1, eqs. 7-9)
# ---------------------------------------------------------------------------


@given(hnp.arrays(np.float32, st.integers(2, 64).map(lambda n: (n,)),
                  elements=FLOATS),
       st.floats(min_value=0.0, max_value=64.0))
@settings(max_examples=80, deadline=None)
def test_energy_expert_is_pmf(h, c):
    y = np.asarray(energy_expert_pmf(jnp.asarray(h), c))
    assert np.all(y >= 0)
    np.testing.assert_allclose(y.sum(), 1.0, rtol=1e-4)


def test_energy_expert_unbiased_at_c0():
    """C=0 -> uniform PMF (Prop. 1 'unbiased' extreme)."""
    h = jnp.array([0.1, 1.0, 5.0])
    np.testing.assert_allclose(energy_expert_pmf(h, 0.0),
                               jnp.full(3, 1 / 3), rtol=1e-6)


def test_energy_expert_fully_biased_at_large_c():
    """C->inf -> argmax collapse (Prop. 1 'fully biased' extreme)."""
    h = jnp.array([0.5, 2.0, 1.0])
    y = energy_expert_pmf(h, 1000.0)
    np.testing.assert_allclose(y, jnp.array([0.0, 1.0, 0.0]), atol=1e-6)


@given(hnp.arrays(np.float32, (8,), elements=FLOATS),
       st.floats(min_value=0.1, max_value=16.0))
@settings(max_examples=50, deadline=None)
def test_energy_expert_order_preservation(h, c):
    """Prop. 1 proof property: h_i > h_j => y_i > y_j."""
    y = np.asarray(energy_expert_pmf(jnp.asarray(h), c))
    for i in range(len(h)):
        for j in range(len(h)):
            if h[i] > h[j] + 1e-4:
                assert y[i] >= y[j] - 1e-6


def test_poe_equals_eq9():
    """product_of_experts(lambda, y) == rho of eq. (9)."""
    key = jax.random.PRNGKey(3)
    lam = jax.nn.softmax(jax.random.normal(key, (16,)))
    h = jnp.exp(jax.random.normal(jax.random.fold_in(key, 1), (16,)))
    c = 4.0
    rho1 = product_of_experts(lam, energy_expert_pmf(h, c))
    rho2 = ca_afl_pmf(lam, h, c)
    np.testing.assert_allclose(rho1, rho2, rtol=1e-5)


def test_ca_afl_c0_recovers_afl():
    """C=0: rho == lambda (the algorithm defaults to AFL)."""
    lam = jnp.array([0.1, 0.2, 0.3, 0.4])
    h = jnp.array([5.0, 0.1, 2.0, 1.0])
    np.testing.assert_allclose(ca_afl_pmf(lam, h, 0.0), lam, rtol=1e-5)


def test_ca_afl_large_c_recovers_greedy():
    """Prop. 2: C->inf puts all mass on the best channel."""
    lam = jnp.array([0.7, 0.1, 0.1, 0.1])
    h = jnp.array([0.2, 0.4, 3.0, 1.0])
    rho = ca_afl_pmf(lam, h, 500.0)
    np.testing.assert_allclose(rho, jnp.array([0, 0, 1.0, 0]), atol=1e-6)


# ---------------------------------------------------------------------------
# Simplex projection + lambda ascent (Alg. 1 lines 13-15)
# ---------------------------------------------------------------------------


@given(hnp.arrays(np.float32, st.integers(2, 100).map(lambda n: (n,)),
                  elements=st.floats(-5, 5, allow_nan=False)))
@settings(max_examples=80, deadline=None)
def test_project_simplex_valid(v):
    p = np.asarray(project_simplex(jnp.asarray(v)))
    assert np.all(p >= -1e-6)
    np.testing.assert_allclose(p.sum(), 1.0, atol=1e-4)


def test_project_simplex_idempotent_on_simplex():
    v = jnp.array([0.2, 0.3, 0.5])
    np.testing.assert_allclose(project_simplex(v), v, atol=1e-6)


def test_project_simplex_matches_bruteforce():
    """Compare against a scipy-free QP-style reference on small inputs."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        v = rng.normal(size=5).astype(np.float32)
        p = np.asarray(project_simplex(jnp.asarray(v)))
        # KKT check: p = max(v - theta, 0) with sum p = 1
        active = p > 1e-7
        theta = (v[active].sum() - 1) / active.sum()
        np.testing.assert_allclose(p[active], v[active] - theta, atol=1e-5)


def test_lambda_ascent_direction():
    """Higher-loss clients gain lambda mass (the DRO adversary)."""
    lam = jnp.full((4,), 0.25)
    losses = jnp.array([0.1, 0.1, 0.1, 5.0])
    lam2 = lambda_ascent(lam, losses, jnp.ones(4), gamma=0.1)
    assert float(lam2[3]) > float(lam2[0])
    np.testing.assert_allclose(jnp.sum(lam2), 1.0, atol=1e-5)


def test_lambda_ascent_respects_mask():
    lam = jnp.full((4,), 0.25)
    losses = jnp.array([0.0, 0.0, 0.0, 100.0])
    lam2 = lambda_ascent(lam, losses, jnp.array([1, 1, 1, 0.0]), gamma=0.1)
    np.testing.assert_allclose(lam2, lam, atol=1e-6)  # masked-out: no drift


# ---------------------------------------------------------------------------
# Selection strategies
# ---------------------------------------------------------------------------


@given(st.integers(1, 9))
@settings(max_examples=20, deadline=None)
def test_gumbel_topk_exactly_k(k):
    key = jax.random.PRNGKey(k)
    logits = jax.random.normal(key, (10,))
    mask = gumbel_topk_mask(key, logits, k)
    assert int(jnp.sum(mask)) == k


def test_gumbel_topk_matches_pmf_marginals():
    """Empirical inclusion frequency follows the PMF ordering."""
    key = jax.random.PRNGKey(0)
    logits = jnp.log(jnp.array([0.5, 0.3, 0.15, 0.05]))
    masks = jax.vmap(lambda k: gumbel_topk_mask(k, logits, 1))(
        jax.random.split(key, 3000))
    freq = np.asarray(masks.mean(0))
    assert freq[0] > freq[1] > freq[2] > freq[3]
    np.testing.assert_allclose(freq, [0.5, 0.3, 0.15, 0.05], atol=0.04)


def test_greedy_is_prop2_limit():
    """Greedy == CA-AFL at C=inf (Prop. 2), for any lambda > 0."""
    key = jax.random.PRNGKey(7)
    lam = jax.nn.softmax(jax.random.normal(key, (20,)))
    h = jnp.exp(jax.random.normal(jax.random.fold_in(key, 1), (20,)))
    greedy = select_clients("greedy", key, lam, h, 5)
    # CA-AFL at enormous C: gumbel noise is dwarfed by C*log h spread
    ca = select_clients("ca_afl", key, lam, h, 5, C=1e6)
    np.testing.assert_allclose(greedy, ca)


def test_gca_requires_grad_norms():
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError):
        select_clients("gca", key, jnp.full(4, .25), jnp.ones(4), 2)


def test_gca_variable_count(key):
    """GCA schedules a VARIABLE number of clients (the paper's critique)."""
    counts = []
    for s in range(20):
        kk = jax.random.fold_in(key, s)
        h = effective_channel(draw_channels(kk, 100, 64))
        g = jnp.abs(jax.random.normal(kk, (100,))) + 0.1
        mask = select_clients("gca", kk, jnp.full(100, 0.01), h, 40,
                              grad_norms=g)
        counts.append(int(jnp.sum(mask)))
    assert len(set(counts)) > 1
    assert 10 < np.mean(counts) < 70  # ~42 in the paper's setting


# ---------------------------------------------------------------------------
# AirComp aggregation (eq. 10)
# ---------------------------------------------------------------------------


@given(st.integers(2, 12), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_aircomp_weighted_mean(n, d):
    key = jax.random.PRNGKey(n * 31 + d)
    x = jax.random.normal(key, (n, d))
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (n,)) > 0.5
            ).astype(jnp.float32)
    k = jnp.maximum(jnp.sum(mask), 1.0)
    out = aircomp_aggregate(x, mask, key, noise_std=0.0, k=k)
    ref = (x * mask[:, None]).sum(0) / k
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


def test_aircomp_noise_statistics(key):
    """Injected AWGN has the right std (eq. 10's z/K)."""
    x = jnp.zeros((4, 20000))
    mask = jnp.ones((4,))
    out = aircomp_aggregate(x, mask, key, noise_std=2.0, k=4.0)
    np.testing.assert_allclose(jnp.std(out), 2.0 / 4.0, rtol=0.05)


def test_aircomp_tree_matches_flat(key):
    tree = {"a": jax.random.normal(key, (5, 3)),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (5, 2, 2))}}
    mask = jnp.array([1, 1, 0, 1, 0.0])
    out = aircomp_aggregate_tree(tree, mask, key, noise_std=0.0)
    ref_a = (tree["a"] * mask[:, None]).sum(0) / 3
    np.testing.assert_allclose(out["a"], ref_a, rtol=1e-5)
    assert out["b"]["c"].shape == (2, 2)
