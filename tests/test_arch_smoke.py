"""Per-architecture smoke tests (assignment requirement).

Each assigned arch instantiates its REDUCED variant (<=2-4 layers,
d_model<=512, <=4 experts) and runs one forward/train step + one decode step
on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_reduced
from repro.models.api import build_model, make_decode_step, make_train_step
from repro.models.specs import pad_vocab
from repro.optim import sgd


def _batch(cfg, key, b=2, s=16):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(
            key, (b, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["audio"] = jax.random.normal(
            key, (b, cfg.num_audio_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_config_limits(arch):
    cfg = get_reduced(arch)
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 4
    if cfg.num_experts:
        assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch, key):
    cfg = get_reduced(arch).with_(dtype="float32", remat=False)
    model = build_model(cfg)
    params = model.init(key)
    opt = sgd(0.05)
    step = jax.jit(make_train_step(model, opt))
    b, s = 2, 16
    p2, _, metrics = step(params, opt.init(params), _batch(cfg, key, b, s))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # loss near ln(V) at random init
    assert 0.5 * jnp.log(cfg.vocab_size) < metrics["loss"] < 3 * jnp.log(
        cfg.vocab_size)
    # params actually moved
    moved = any(
        bool(jnp.any(a != b_))
        for a, b_ in zip(jax.tree_util.tree_leaves(p2),
                         jax.tree_util.tree_leaves(params), strict=True))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_smoke(arch, key):
    cfg = get_reduced(arch).with_(dtype="float32", remat=False)
    model = build_model(cfg)
    params = model.init(key)
    b, s = 2, 16
    cache = model.init_cache(b, s)
    step = jax.jit(make_decode_step(model))
    tok = jnp.zeros((b,), jnp.int32)
    nxt, logits, cache2 = step(params, cache, tok,
                               jnp.asarray(0, jnp.int32))
    assert logits.shape == (b, pad_vocab(cfg.vocab_size))
    assert not bool(jnp.isnan(logits).any())
    assert nxt.dtype == jnp.int32
    # cache structurally unchanged
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_then_decode_consistency(arch, key):
    """Greedy continuation from prefill == teacher-forced forward argmax."""
    cfg = get_reduced(arch).with_(dtype="float32", remat=False)
    model = build_model(cfg)
    params = model.init(key)
    b, s = 2, 12
    batch = _batch(cfg, key, b, s)
    logits_pf, _cache = model.prefill(params, batch, chunk=None)
    assert logits_pf.shape == (b, pad_vocab(cfg.vocab_size))
    assert not bool(jnp.isnan(logits_pf).any())
