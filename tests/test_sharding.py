"""Multi-device differential suite (ISSUE 4 sharding contract).

Runs only when the process sees a multi-device mesh — the CI multi-device
lane sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before
pytest starts (per conftest, the default lanes must keep seeing 1 device).

Pinned here:
  - sweep-cell sharding (``run_sweep(devices=...)``) is BIT-identical to the
    single-device sweep on every history leaf, including the padded-seed
    path — cells are independent, so no tolerance is tolerated;
  - population sharding (``run_simulation(mesh=...)``) keeps the O(N)
    control plane (masks, energy, availability) bit-identical across
    methods × {static, markov_fading, battery_constrained} and the model
    trajectories equal to the summation order of the eq. (10) psum;
  - the distributed local-then-global top-k equals dense ``lax.top_k``
    exactly, ties included;
  - a mesh of size 1 is a structural no-op.
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import sharding, sweep
from repro.core.channel import SCENARIOS
from repro.core.simulator import run_simulation
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

pytestmark = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="multi-device suite: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

N, DIM = 16, 32
MODEL = logistic_regression(dim=DIM, num_classes=10)
# trajectories may differ from the dense reference only by the cross-shard
# summation order of the eq. (10) psum — ulps, amplified over a few rounds
SUM_ORDER_TOL = dict(rtol=2e-5, atol=2e-6)


@pytest.fixture(scope="module")
def shard_data():
    x, y, xt, yt = make_fmnist_like(num_train=640, num_test=320, dim=DIM,
                                    seed=0)
    xs, ys = sorted_label_shards(x, y, N)
    xts, yts = sorted_label_shards(xt, yt, N)
    return xs, ys, xts, yts


def _fl(method="ca_afl", rounds=6, **kw):
    return FLConfig(num_clients=N, clients_per_round=5, rounds=rounds,
                    batch_size=16, method=method, lr0=0.3, lr_decay=0.995,
                    ascent_lr=2e-2, **kw)


def _assert_bit_identical(h1, h2, fields=None):
    for f in fields or h1._fields:
        a, b = np.asarray(getattr(h1, f)), np.asarray(getattr(h2, f))
        np.testing.assert_array_equal(a, b, err_msg=f"field {f}")


# ---------------------------------------------------------------------------
# Sweep-cell sharding
# ---------------------------------------------------------------------------


def test_sharded_sweep_bit_identical(shard_data):
    specs = sweep.expand_grid(
        _fl(), variants={"ca": {}, "afl": {"method": "afl"}},
        scenarios=("default", "noisy_uplink"))
    seeds = tuple(range(jax.device_count() // 2))  # exercises seed padding
    r1 = sweep.run_sweep(MODEL, shard_data, specs, seeds=seeds)
    rd = sweep.run_sweep(MODEL, shard_data, specs, seeds=seeds,
                         devices=jax.device_count())
    assert rd.seeds == r1.seeds
    for lbl in r1.labels:
        _assert_bit_identical(r1.history(lbl), rd.history(lbl))


def test_sharded_sweep_bit_identical_divisible_seeds(shard_data):
    specs = [("run", _fl(temporal=True, rho_fading=0.9))]
    seeds = tuple(range(jax.device_count()))  # no padding
    r1 = sweep.run_sweep(MODEL, shard_data, specs, seeds=seeds)
    rd = sweep.run_sweep(MODEL, shard_data, specs, seeds=seeds,
                         devices="auto")
    _assert_bit_identical(r1.history("run"), rd.history("run"))


def test_sharded_sweep_devices_one_is_single_device_path(shard_data):
    # devices=1 must not even build a mesh: it is the exact default program
    specs = [("run", _fl(rounds=3))]
    r1 = sweep.run_sweep(MODEL, shard_data, specs, seeds=(0, 1))
    rd = sweep.run_sweep(MODEL, shard_data, specs, seeds=(0, 1), devices=1)
    _assert_bit_identical(r1.history("run"), rd.history("run"))


# ---------------------------------------------------------------------------
# Population sharding
# ---------------------------------------------------------------------------


POP_SCENARIOS = ("default", "markov_fading", "battery_constrained")


@pytest.mark.parametrize("scenario", POP_SCENARIOS)
@pytest.mark.parametrize("method", ["fedavg", "afl", "ca_afl", "greedy",
                                    "gca"])
def test_population_sharded_matches_dense(shard_data, method, scenario):
    fl = replace(_fl(method), **SCENARIOS[scenario])
    if scenario == "battery_constrained":
        # enough budget that *some* rounds transmit on N=16
        fl = replace(fl, battery_init=0.05)
    mesh = sharding.client_mesh(sharding.population_device_count(N))
    assert mesh.size > 1
    dense = run_simulation(MODEL, fl, shard_data, dense=True)
    shard = run_simulation(MODEL, fl, shard_data, mesh=mesh)
    # control plane: bit-identical (every [N] draw is replicated, selection
    # and the energy ledger read only replicated inputs)
    _assert_bit_identical(dense, shard,
                          fields=["num_scheduled", "energy", "avail_count",
                                  "min_battery"])
    # model-dependent metrics: equal to the psum's summation order
    for f in ["avg_acc", "worst_acc", "std_acc", "loss", "lam"]:
        np.testing.assert_allclose(
            np.asarray(getattr(dense, f)), np.asarray(getattr(shard, f)),
            err_msg=f"field {f}", **SUM_ORDER_TOL)


def test_population_sharded_eval_cadence(shard_data):
    fl = _fl(eval_every=3, rounds=7)
    mesh = sharding.client_mesh(sharding.population_device_count(N))
    dense = run_simulation(MODEL, fl, shard_data, dense=True)
    shard = run_simulation(MODEL, fl, shard_data, mesh=mesh)
    _assert_bit_identical(dense, shard, fields=["num_scheduled", "energy"])
    np.testing.assert_allclose(np.asarray(dense.avg_acc),
                               np.asarray(shard.avg_acc), **SUM_ORDER_TOL)
    # forward-fill structure survives sharding: non-eval rounds copy the
    # previous eval exactly
    acc = np.asarray(shard.avg_acc)
    for t in range(fl.rounds):
        if t % 3:
            assert acc[t] == acc[t - 1]


def test_population_mesh_of_one_is_noop(shard_data):
    fl = _fl()
    plain = run_simulation(MODEL, fl, shard_data, dense=True)
    m1 = run_simulation(MODEL, fl, shard_data, dense=True,
                        mesh=sharding.client_mesh(1))
    _assert_bit_identical(plain, m1)


def test_population_sharding_rejects_indivisible():
    fl = replace(_fl(), num_clients=N + 1)
    mesh = sharding.client_mesh(jax.device_count())
    with pytest.raises(ValueError, match="N % devices"):
        sharding.run_simulation_sharded(MODEL, fl, (None,) * 4, mesh)


def test_population_device_count_divides():
    assert sharding.population_device_count(16, 8) == 8
    assert sharding.population_device_count(12, 8) == 6
    assert sharding.population_device_count(7, 8) == 7
    assert sharding.population_device_count(13, 8) == 1


# ---------------------------------------------------------------------------
# Distributed top-k == dense lax.top_k (ties included)
# ---------------------------------------------------------------------------


def _run_distributed_top_k(scores, k):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = sharding.client_mesh(
        sharding.population_device_count(scores.shape[0]))
    fn = shard_map(
        lambda s: sharding.distributed_top_k(
            s, k, mesh.axis_names[0], n_global=scores.shape[0]),
        mesh=mesh, in_specs=P(mesh.axis_names[0]), out_specs=P(),
        check_rep=False)
    return jax.jit(fn)(scores)


@pytest.mark.parametrize("k", [1, 5, 16])
def test_distributed_top_k_matches_dense(k):
    for seed in range(5):
        scores = jax.random.normal(jax.random.PRNGKey(seed), (N,))
        mask, idx = _run_distributed_top_k(scores, k)
        _, didx = jax.lax.top_k(scores, k)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(didx))
        dmask = np.zeros(N, np.float32)
        dmask[np.asarray(didx)] = 1.0
        np.testing.assert_array_equal(np.asarray(mask), dmask)


@pytest.mark.parametrize("k", [3, 8])
def test_distributed_top_k_ties_pinned(k):
    for seed in range(5):
        # heavy quantization => many exact ties, incl. across shards
        raw = jax.random.normal(jax.random.PRNGKey(100 + seed), (N,))
        scores = jnp.round(raw * 2) / 2
        mask, idx = _run_distributed_top_k(scores, k)
        _, didx = jax.lax.top_k(scores, k)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(didx))


def test_distributed_top_k_with_neg_inf():
    scores = jnp.where(jnp.arange(N) % 3 == 0, -jnp.inf,
                       jnp.ones(N))  # tied finite scores + -inf holes
    mask, idx = _run_distributed_top_k(scores, 8)
    _, didx = jax.lax.top_k(scores, 8)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(didx))


# ---------------------------------------------------------------------------
# Production tier: sharded batch placement is semantics-free
# ---------------------------------------------------------------------------


def test_server_sharded_batch_matches_unsharded(shard_data):
    from repro.federated.server import ParameterServer
    from repro.models.logreg import logistic_regression_prod
    from repro.optim import sgd

    fl = _fl(rounds=3)
    model = logistic_regression_prod(DIM, 10)
    xs, ys = shard_data[0], shard_data[1]
    per = 8

    def batches():
        while True:
            xb = jnp.reshape(xs[:, :per], (N * per, DIM))
            yb = jnp.reshape(ys[:, :per], (N * per,))
            yield {"x": xb, "labels": yb,
                   "client_ids": jnp.repeat(jnp.arange(N), per)}

    mesh = sharding.client_mesh(sharding.population_device_count(N))
    out = {}
    for name, m in [("plain", None), ("sharded", mesh)]:
        ps = ParameterServer(model, sgd(0.3), fl, seed=0, mesh=m)
        state = ps.init_state(jax.random.PRNGKey(0))
        state = ps.run(state, batches(), rounds=3, log_fn=None)
        out[name] = state
    for a, b in zip(out["plain"].history, out["sharded"].history,
                    strict=True):
        assert a["num_scheduled"] == b["num_scheduled"]
        np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5)
        np.testing.assert_allclose(a["energy_j"], b["energy_j"], rtol=1e-6)
    pa = jax.tree_util.tree_leaves(out["plain"].params)
    pb = jax.tree_util.tree_leaves(out["sharded"].params)
    for la, lb in zip(pa, pb, strict=True):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=2e-5, atol=2e-6)
