"""Serving-stack tests: grow_cache across all families + multi-step greedy
decode through the public API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.api import build_model, make_decode_step


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-30b-a3b",
                                  "zamba2-1.2b", "xlstm-1.3b",
                                  "llama-3.2-vision-11b",
                                  "seamless-m4t-medium"])
def test_prefill_grow_decode_roundtrip(arch, key):
    """prefill(P tokens) -> grow cache -> decode G more == forward(P+G)."""
    cfg = get_reduced(arch).with_(dtype="float32", remat=False,
                                  moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(key)
    b, p_len, gen = 2, 8, 4
    total = p_len + gen
    toks = jax.random.randint(key, (b, total), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :p_len]}
    fwd_batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        img = jax.random.normal(key, (b, cfg.num_image_tokens, cfg.d_model))
        batch["images"] = img
        fwd_batch["images"] = img
    if cfg.family == "audio":
        aud = jax.random.normal(key, (b, cfg.num_audio_frames, cfg.d_model))
        batch["audio"] = aud
        fwd_batch["audio"] = aud

    logits, cache = model.prefill(params, batch, chunk=None)
    cache = model.grow_cache(cache, p_len, total)
    step = make_decode_step(model)
    for i in range(gen):
        _, logits, cache = step(params, cache, toks[:, p_len + i],
                                jnp.asarray(p_len + i, jnp.int32))

    # teacher-forced reference for the final position
    if cfg.family == "vlm":
        ref = model.mod.forward(cfg, params, toks, fwd_batch["images"])
    elif cfg.family == "audio":
        ref = model.mod.forward(cfg, params, toks, fwd_batch["audio"])
    elif cfg.family == "moe":
        ref, _ = model.mod.forward(cfg, params, toks)
    else:
        ref = model.mod.forward(cfg, params, toks)
    np.testing.assert_allclose(logits, ref[:, -1], rtol=1e-3, atol=1e-3)


def test_grow_cache_noop_for_state_models(key):
    cfg = get_reduced("xlstm-1.3b").with_(dtype="float32")
    model = build_model(cfg)
    cache = model.init_cache(2, 8)
    grown = model.grow_cache(cache, 8, 100)
    for a, b in zip(jax.tree_util.tree_leaves(cache),
                    jax.tree_util.tree_leaves(grown), strict=True):
        assert a.shape == b.shape
