"""Error-feedback sparse transport invariants (``transport="sparse"``).

The compression contract (``core/transport.py``):

  - **telescoping** — per round, kept + dropped == payload BITWISE
    (``c = v·mask`` and ``v − c`` recompute the same f32 mask), so over a
    run Σ compressed + final residual == Σ raw updates and no gradient mass
    is ever silently lost;
  - **layout determinism** — the top-k mask is a within-row magnitude
    threshold with NO per-client randomness stream, so the dense [N],
    gathered [K] and population-sharded row layouts select identical
    supports;
  - **state carry** — the residual is genuine simulation state: it rides
    the scan carry, survives a checkpoint save/restore split exactly, and
    gated (weight-0) clients keep theirs untouched;
  - **density→1 recovery** — at ``sparse_density=1.0`` every coordinate is
    kept, the residual stays zero and the sparse program reproduces the
    analog trajectories with the identical AWGN realization.
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.core.simulator import init_sim_state, make_round_fn, run_simulation
from repro.core.transport import (sparse_aggregate_flat_rows,
                                  sparse_compress_rows, sparse_k_coords,
                                  sparse_thresholds)
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression
from repro.utils.tree import tree_size

N, DIM = 12, 32
MODEL = logistic_regression(dim=DIM, num_classes=10)


@pytest.fixture(scope="module")
def tdata():
    x, y, xt, yt = make_fmnist_like(num_train=600, num_test=240, dim=DIM,
                                    seed=0)
    xs, ys = sorted_label_shards(x, y, N)
    xts, yts = sorted_label_shards(xt, yt, N)
    return xs, ys, xts, yts


def _fl(method="ca_afl", rounds=6, **kw):
    return FLConfig(num_clients=N, clients_per_round=5, rounds=rounds,
                    batch_size=16, method=method, lr0=0.3, lr_decay=0.995,
                    ascent_lr=2e-2, transport="sparse", sparse_density=0.2,
                    **kw)


# ---------------------------------------------------------------------------
# Telescoping: kept + dropped == payload, bitwise per round
# ---------------------------------------------------------------------------


@pytest.mark.property
def test_compression_telescopes_bitwise_per_round():
    """c + (v − c) == v with NO floating-point slack: the residual update
    recomputes the kernel's exact mask, so kept coordinates cancel exactly
    (v − v = 0) and dropped ones pass through exactly (v − 0 = v)."""
    v = jax.random.normal(jax.random.PRNGKey(0), (6, 257))
    c, thr = sparse_compress_rows(v, 13)
    np.testing.assert_array_equal(np.asarray(c + (v - c)), np.asarray(v))
    # per row: at least k kept (ties keep extra), dropped strictly below thr
    kept = np.asarray(jnp.abs(v) >= thr[:, None])
    assert (kept.sum(1) >= 13).all()
    assert (np.abs(np.asarray(v))[~kept] < np.asarray(thr)[
        np.nonzero(~kept)[0]]).all()


@pytest.mark.property
def test_error_feedback_telescopes_over_rounds():
    """Over T rounds of the fused aggregate (noise-free, all clients, k=1):
    (base_T − base_0) + Σ_c resid_T == Σ_t Σ_c delta_t — the error-feedback
    memory accounts for every unit of dropped gradient mass."""
    key = jax.random.PRNGKey(1)
    c, p, rounds, k_coords = 5, 120, 7, 11
    base = jnp.zeros((p,))
    resid = jnp.zeros((c, p))
    w = jnp.ones((c,))
    total = jnp.zeros((p,))
    for t in range(rounds):
        deltas = jax.random.normal(jax.random.fold_in(key, t), (c, p)) * 0.1
        total = total + deltas.sum(0)
        base, resid = sparse_aggregate_flat_rows(
            base, deltas, resid, w, None, 0.0, k_coords, 1.0)
    np.testing.assert_allclose(np.asarray(base + resid.sum(0)),
                               np.asarray(total), rtol=1e-5, atol=1e-6)
    # the residual is genuinely nonzero at density << 1 (mass IS deferred)
    assert float(jnp.abs(resid).sum()) > 0.0


@pytest.mark.property
def test_gated_clients_keep_their_residual():
    """A weight-0 slot transmits nothing: its payload never left the device,
    so its error-feedback row must stay bit-identical (a zeroed or updated
    row would leak a phantom upload into later rounds)."""
    key = jax.random.PRNGKey(2)
    deltas = jax.random.normal(key, (4, 64))
    resid = jax.random.normal(jax.random.fold_in(key, 1), (4, 64)) * 0.01
    w = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    base, new_resid = sparse_aggregate_flat_rows(
        jnp.zeros((64,)), deltas, resid, w, None, 0.0, 7, 2.0)
    np.testing.assert_array_equal(np.asarray(new_resid[1]),
                                  np.asarray(resid[1]))
    np.testing.assert_array_equal(np.asarray(new_resid[3]),
                                  np.asarray(resid[3]))
    assert not np.array_equal(np.asarray(new_resid[0]), np.asarray(resid[0]))


# ---------------------------------------------------------------------------
# Layout determinism: dense [N] / gathered [K] / sharded rows pick one mask
# ---------------------------------------------------------------------------


@pytest.mark.property
def test_compression_mask_is_layout_independent():
    """The threshold is a within-row property: any row subset (a gather, a
    shard slice, a permutation) compresses each row bit-identically to the
    dense [N] layout — the property that lets the three control-plane
    layouts share one contract with no per-client randomness stream."""
    v = jax.random.normal(jax.random.PRNGKey(3), (10, 300))
    k_coords = 17
    thr_dense = sparse_thresholds(v, k_coords)
    c_dense, _ = sparse_compress_rows(v, k_coords)
    idx = jnp.asarray([7, 2, 9])                      # a gathered-K layout
    c_gath, thr_gath = sparse_compress_rows(v[idx], k_coords)
    np.testing.assert_array_equal(np.asarray(thr_gath),
                                  np.asarray(thr_dense[idx]))
    np.testing.assert_array_equal(np.asarray(c_gath),
                                  np.asarray(c_dense[idx]))
    for lo, hi in ((0, 5), (5, 10)):                  # shard-local rows
        c_loc, _ = sparse_compress_rows(v[lo:hi], k_coords)
        np.testing.assert_array_equal(np.asarray(c_loc),
                                      np.asarray(c_dense[lo:hi]))


def test_sparse_k_coords_is_clamped_static():
    assert sparse_k_coords(0.05, 1000) == 50
    assert sparse_k_coords(0.0, 1000) == 1      # never an empty upload
    assert sparse_k_coords(1e-9, 3) == 1
    assert sparse_k_coords(2.0, 1000) == 1000   # never beyond the model
    assert sparse_k_coords(1.0, 7) == 7


# ---------------------------------------------------------------------------
# State carry: scan, checkpoint split, density→1 analog recovery
# ---------------------------------------------------------------------------


def test_residual_survives_checkpoint_split(tdata, tmp_path):
    """6 straight rounds == 3 rounds → checkpoint save/restore → 3 more,
    bit-for-bit on the model AND the error-feedback leaf: the residual is
    real state — dropping it at a restore boundary would silently lose the
    deferred gradient mass."""
    fl = _fl(rounds=6)
    model_size = tree_size(MODEL.init(jax.random.PRNGKey(0)))
    round_fn = make_round_fn(MODEL, fl, tdata, model_size)
    state = init_sim_state(MODEL, fl, jax.random.PRNGKey(42))
    assert state.ef_resid.shape == (N, model_size)

    ref = state
    for t in range(6):
        ref, _ = round_fn(ref, jnp.int32(t))

    half = state
    for t in range(3):
        half, _ = round_fn(half, jnp.int32(t))
    ckpt = {"w": half.w, "lam": half.lam, "energy": half.energy,
            "key": jax.random.key_data(half.key), "ef_resid": half.ef_resid,
            "dl_energy": half.dl_energy}
    save_checkpoint(str(tmp_path), 3, ckpt)
    got = restore_checkpoint(str(tmp_path), jax.tree.map(np.asarray, ckpt))
    resumed = half._replace(
        w=jax.tree.map(jnp.asarray, got["w"]),
        lam=jnp.asarray(got["lam"]),
        energy=jnp.asarray(got["energy"]),
        key=jax.random.wrap_key_data(jnp.asarray(got["key"])),
        ef_resid=jnp.asarray(got["ef_resid"]),
        dl_energy=jnp.asarray(got["dl_energy"]))
    for t in range(3, 6):
        resumed, _ = round_fn(resumed, jnp.int32(t))

    for a, b in zip(jax.tree_util.tree_leaves(ref.w),
                    jax.tree_util.tree_leaves(resumed.w), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ref.ef_resid),
                                  np.asarray(resumed.ef_resid))
    np.testing.assert_array_equal(np.asarray(ref.energy),
                                  np.asarray(resumed.energy))
    # the memory is live by round 6 at density 0.2
    assert float(jnp.abs(ref.ef_resid).sum()) > 0.0


def test_density_one_recovers_analog(tdata):
    """At density=1.0 the threshold is each row's min |coordinate|, every
    coordinate is kept, the residual stays identically zero and the sparse
    program equals analog — with the IDENTICAL AWGN realization (same
    per-leaf streams) and the identical energy bill (the payload fraction
    caps at 1)."""
    fl = _fl("ca_afl", noise_std=1e-3)
    ha = run_simulation(MODEL, replace(fl, transport="analog"), tdata, seed=3)
    hs = run_simulation(MODEL, replace(fl, sparse_density=1.0), tdata, seed=3)
    eps = float(np.finfo(np.float32).eps)
    for name in ha._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(ha, name)), np.asarray(getattr(hs, name)),
            err_msg=f"d1:{name}", rtol=64 * eps, atol=64 * eps)


def test_sparse_run_defers_then_delivers(tdata):
    """End-to-end sanity at density 0.2: the run is finite, cheaper on the
    uplink ledger than analog, and still learns (error feedback keeps the
    dropped mass in play instead of discarding it)."""
    fl = _fl("fedavg", rounds=25)
    hs = run_simulation(MODEL, fl, tdata, seed=3)
    ha = run_simulation(MODEL, replace(fl, transport="analog"), tdata, seed=3)
    assert np.isfinite(np.asarray(hs.avg_acc)).all()
    # FedAvg schedules identically (uniform draw), so ledgers are comparable
    np.testing.assert_array_equal(np.asarray(hs.num_scheduled),
                                  np.asarray(ha.num_scheduled))
    assert float(hs.energy[-1]) < 0.5 * float(ha.energy[-1])
    assert float(hs.avg_acc[-1]) > 0.4 > float(hs.avg_acc[0])
