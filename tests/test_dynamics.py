"""Temporal scenario dynamics (core/dynamics.py): degenerate-process
bit-for-bit identity with the static path, Gauss-Markov correlation,
availability/battery invariants across tiers, and the compilation-group
contract for dynamic sweeps."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import sweep
from repro.core.channel import SCENARIOS, scenario_from_config
from repro.core.dynamics import (evolve_availability, evolve_fading,
                                 init_chan_state, process_from_config)
from repro.core.simulator import run_simulation
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

N, DIM = 12, 32
MODEL = logistic_regression(dim=DIM, num_classes=10)

# a battery that binds within a few rounds at this scale (M = 330 params,
# per-upload energy ~ psi*M*tau/h^2 ~ 1.7e-4/h^2 J)
TIGHT_BATTERY = 1.2e-3


@pytest.fixture(scope="module")
def dyn_data():
    x, y, xt, yt = make_fmnist_like(num_train=600, num_test=240, dim=DIM,
                                    seed=0)
    xs, ys = sorted_label_shards(x, y, N)
    xts, yts = sorted_label_shards(xt, yt, N)
    return xs, ys, xts, yts


def _fl(method="ca_afl", rounds=8, **kw):
    return FLConfig(num_clients=N, clients_per_round=5, rounds=rounds,
                    batch_size=16, method=method, lr0=0.3, lr_decay=0.995,
                    ascent_lr=2e-2, **kw)


# ---------------------------------------------------------------------------
# The carry contract: static scenarios are untouched, and a degenerate
# temporal process reproduces them bit-for-bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["ca_afl", "fedavg", "greedy", "gca"])
def test_degenerate_process_matches_static_bitwise(dyn_data, method):
    """temporal=True with all identity knobs (rho=0, no walk, no dropout,
    infinite battery) consumes the same key streams and computes the same
    arithmetic as the stateless path — trajectories must be IDENTICAL, which
    pins that the dynamics thread-through did not perturb the default
    (i.i.d.) program."""
    static = run_simulation(MODEL, _fl(method), dyn_data, seed=3)
    degen = run_simulation(MODEL, _fl(method, temporal=True), dyn_data, seed=3)
    for name in static._fields:
        if name == "min_battery":
            continue  # inf (static sentinel) vs inf battery: both inf anyway
        if name == "energy":
            # the dynamic program carries extra reductions (avail counts,
            # battery gating) that XLA may fuse WITH the eq. (3-6) ledger
            # sum, reassociating it by one f32 ulp — the mask, the channels
            # and every model-trajectory field below are exactly equal
            np.testing.assert_allclose(
                np.asarray(static.energy), np.asarray(degen.energy),
                rtol=5e-7, err_msg=f"{method}:energy")
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(static, name)), np.asarray(getattr(degen, name)),
            err_msg=f"{method}:{name}")
    assert np.all(np.isinf(np.asarray(degen.min_battery)))
    np.testing.assert_array_equal(np.asarray(static.avail_count), float(N))


def test_static_history_records_sentinels(dyn_data):
    hist = run_simulation(MODEL, _fl("afl", rounds=4), dyn_data, seed=0)
    np.testing.assert_array_equal(np.asarray(hist.avail_count), float(N))
    assert np.all(np.isinf(np.asarray(hist.min_battery)))


# ---------------------------------------------------------------------------
# Gauss-Markov fading + shadowing walk
# ---------------------------------------------------------------------------


def _scan_fading(rho, rounds=200, rho_shadow=0.0, walk_std=0.0):
    fl = _fl(temporal=True, rho_fading=rho, rho_shadow=rho_shadow,
             shadow_walk_std=walk_std)
    scen = scenario_from_config(fl)
    proc = process_from_config(fl)
    cs = init_chan_state(proc, jax.random.PRNGKey(0), N, fl.num_subcarriers,
                         fl.flat_fading)

    def step(carry, key):
        h_mag, fast, log_shadow = evolve_fading(key, scen, proc, carry, N,
                                                fl.num_subcarriers)
        return carry._replace(fast=fast, log_shadow=log_shadow), h_mag[:, 0]

    _, hs = jax.lax.scan(step, cs, jax.random.split(jax.random.PRNGKey(1),
                                                    rounds))
    return np.asarray(hs)  # [T, N]


def _lag1_autocorr(series):
    a, b = series[:-1], series[1:]
    a = a - a.mean()
    b = b - b.mean()
    return float((a * b).mean() / np.sqrt((a**2).mean() * (b**2).mean()))


def test_markov_fading_is_temporally_correlated():
    """rho=0.95 channels persist across rounds; rho=0 channels do not."""
    corr_hi = _lag1_autocorr(_scan_fading(0.95)[:, 0])
    corr_lo = _lag1_autocorr(_scan_fading(0.0)[:, 0])
    assert corr_hi > 0.6
    assert abs(corr_lo) < 0.25


def test_markov_fading_preserves_stationary_scale():
    """The Gauss-Markov update keeps the Rayleigh unit-mean-square law:
    mean |h|^2 ~= 1 regardless of rho (no energy drift over time)."""
    for rho in (0.0, 0.9):
        hs = _scan_fading(rho, rounds=400)
        assert abs(float((hs**2).mean()) - 1.0) < 0.15, rho


def test_shadow_walk_wanders():
    """A near-unit-root shadowing walk spreads the channel distribution over
    time (slow mobility), unlike the rho_shadow=0 fast-only process."""
    hs = _scan_fading(0.0, rounds=300, rho_shadow=0.995, walk_std=0.15)
    early = np.log(hs[:30]).std()
    late = np.log(hs[-30:]).std()
    assert late > early * 1.3


# ---------------------------------------------------------------------------
# Availability + battery invariants (simulator tier)
# ---------------------------------------------------------------------------


def test_availability_chain_stationary_rate():
    proc = process_from_config(_fl(temporal=True, p_dropout=0.1, p_return=0.3))
    avail = jnp.ones((500,))

    def step(a, key):
        a = evolve_availability(key, proc, a)
        return a, a.mean()

    _, rates = jax.lax.scan(step, avail,
                            jax.random.split(jax.random.PRNGKey(0), 300))
    # stationary availability = p_return / (p_dropout + p_return) = 0.75
    assert abs(float(jnp.asarray(rates)[-100:].mean()) - 0.75) < 0.05


def test_unavailable_clients_never_scheduled_in_simulation(dyn_data):
    """End-to-end: with heavy churn, every round schedules no more clients
    than are schedulable (and the run stays finite/learnable)."""
    fl = _fl("ca_afl", rounds=12, temporal=True, p_dropout=0.4, p_return=0.3)
    hist = run_simulation(MODEL, fl, dyn_data, seed=0)
    sched = np.asarray(hist.num_scheduled)
    avail = np.asarray(hist.avail_count)
    assert np.all(sched <= avail + 1e-6)
    assert np.all(sched <= fl.clients_per_round)
    assert bool(jnp.all(jnp.isfinite(hist.avg_acc)))


def test_battery_depletes_monotonically_and_gates_scheduling(dyn_data):
    fl = _fl("fedavg", rounds=20, temporal=True, battery_init=TIGHT_BATTERY)
    hist = run_simulation(MODEL, fl, dyn_data, seed=0)
    mb = np.asarray(hist.min_battery)
    assert np.all(mb >= -1e-9)                # never overdrawn
    assert np.all(np.diff(mb) <= 1e-9)        # monotone depletion
    assert mb[-1] < mb[0]                     # actually spent something
    # once budgets bind the schedulable pool shrinks below N
    assert np.asarray(hist.avail_count)[-1] < N
    # and the energy ledger slows down accordingly (strictly bounded by the
    # total budget: no client can spend more than its battery)
    assert float(np.asarray(hist.energy)[-1]) <= N * TIGHT_BATTERY + 1e-6


def test_empty_schedule_keeps_model_and_spends_nothing(dyn_data):
    """With budgets below one upload, nobody ever transmits: the global
    model must survive untouched (eq. 10's zero sum must NOT be applied),
    the ledger stays at zero, and the run stays finite."""
    fl = _fl("ca_afl", rounds=6, temporal=True, battery_init=1e-12)
    hist = run_simulation(MODEL, fl, dyn_data, seed=0)
    assert np.all(np.asarray(hist.num_scheduled) == 0)
    assert np.all(np.asarray(hist.avail_count) == 0)
    assert np.all(np.asarray(hist.energy) == 0.0)
    # the model never changes => test accuracy is flat across rounds
    acc = np.asarray(hist.avg_acc)
    np.testing.assert_array_equal(acc, acc[0])
    assert np.all(np.isfinite(np.asarray(hist.loss)))


def test_battery_constrained_caps_total_energy_vs_unconstrained(dyn_data):
    fl_free = _fl("afl", rounds=25, temporal=True)
    fl_batt = _fl("afl", rounds=25, temporal=True, battery_init=TIGHT_BATTERY)
    e_free = float(np.asarray(
        run_simulation(MODEL, fl_free, dyn_data, seed=1).energy)[-1])
    e_batt = float(np.asarray(
        run_simulation(MODEL, fl_batt, dyn_data, seed=1).energy)[-1])
    assert e_batt <= N * TIGHT_BATTERY + 1e-6
    assert e_batt < e_free


# ---------------------------------------------------------------------------
# Sweep-engine integration: registry entries + compilation groups
# ---------------------------------------------------------------------------


def test_dynamic_registry_entries_are_valid_configs():
    for name in ("markov_fading", "commuter_mobility", "battery_constrained"):
        fl = replace(_fl(), **SCENARIOS[name])
        assert fl.temporal, name
        assert process_from_config(fl).temporal, name


def test_dynamic_scenarios_share_one_compile_per_method(dyn_data):
    """The compilation-group contract: every temporal scenario (whatever its
    knobs — correlated fading, mobility churn, battery budgets, or a
    degenerate i.i.d.-equivalent process) rides ONE executable per selection
    method; their knobs are vmap'd sweep-point leaves."""
    scenarios = ("markov_fading", "commuter_mobility",
                 ("battery_tight", {"temporal": True,
                                    "battery_init": TIGHT_BATTERY}),
                 ("degenerate_iid", {"temporal": True}))
    specs = sweep.expand_grid(
        _fl(rounds=6), variants={"ca_afl": {"method": "ca_afl"},
                                 "fedavg": {"method": "fedavg"}},
        scenarios=scenarios)
    sweep.reset_trace_log()
    res = sweep.run_sweep(MODEL, dyn_data, specs, seeds=(0, 1))
    assert sweep.trace_count() == 2  # one per method for the whole dyn grid
    for lbl in res.labels:
        assert bool(jnp.all(jnp.isfinite(res.history(lbl).avg_acc))), lbl


def test_mixed_static_dynamic_grid_groups_by_structure(dyn_data):
    """A grid mixing i.i.d. and temporal scenarios: the static cells keep
    compiling to PR 1's program (their own group), the dynamic cells share
    theirs — structure, not knob values, decides the grouping."""
    specs = sweep.expand_grid(
        _fl(rounds=6), variants={"ca_afl": {}},
        scenarios=("default", "noisy_uplink",           # static group
                   "markov_fading", "battery_constrained"))  # temporal group
    sweep.reset_trace_log()
    res = sweep.run_sweep(MODEL, dyn_data, specs, seeds=(0,))
    assert sweep.trace_count() == 2  # {static, temporal} x {ca_afl}
    # the static cells must equal their standalone runs (no perturbation)
    ref = run_simulation(MODEL, _fl(rounds=6), dyn_data, seed=0)
    np.testing.assert_allclose(
        np.asarray(res.history("ca_afl").avg_acc)[0],
        np.asarray(ref.avg_acc), atol=1e-6)


def test_sweep_summary_reports_dynamics_columns(dyn_data):
    specs = [("batt", _fl("fedavg", rounds=10, temporal=True,
                          battery_init=TIGHT_BATTERY)),
             ("plain", _fl("fedavg", rounds=10))]
    res = sweep.run_sweep(MODEL, dyn_data, specs, seeds=(0,))
    s = res.summary(window=4)
    assert s["batt"]["min_battery"] is not None
    assert s["batt"]["min_battery"] >= 0.0
    assert s["plain"]["min_battery"] is None  # static sentinel -> JSON null
    assert s["plain"]["avail_count"] == pytest.approx(float(N))
    assert s["batt"]["avail_count"] <= N
