"""ISSUE 9 self-tests: the contract linter's layer-1 AST rules.

Two directions, per the fixture discipline:

  - the REAL tree passes clean (``run_lint()`` returns nothing) — the
    contracts hold and the allow-comments in core/ are honored;
  - the known-bad fixture tree under ``tests/fixtures/lint/bad_tree``
    trips EVERY rule (each seeded violation is found at its seeded site),
    the registry exemption (``hierarchical_top_k``) and a reasoned
    allow-comment both suppress, and a reasonless allow-comment is itself
    flagged.

The CLI contract (exit 0 on the tree, nonzero on the fixture, JSON report)
is pinned via subprocess — it is what the CI lint lane gates on.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core.sweep import STATIC_FIELDS
from repro.lint import default_root, run_lint
from repro.lint.base import ALLOW_RE
from repro.lint.rules import load_flconfig_fields, load_static_fields

REPO = Path(__file__).resolve().parents[1]
FIXTURE = REPO / "tests" / "fixtures" / "lint" / "bad_tree"


@pytest.fixture(scope="module")
def fixture_violations():
    return run_lint(FIXTURE)


# ---------------------------------------------------------------------------
# The real tree is clean
# ---------------------------------------------------------------------------


def test_real_tree_clean():
    violations = run_lint()
    assert not violations, "\n".join(v.format() for v in violations)


def test_default_root_is_src_repro():
    assert default_root().name == "repro"
    assert (default_root() / "core" / "simulator.py").exists()


# ---------------------------------------------------------------------------
# Every rule fires on the known-bad fixture, at its seeded site
# ---------------------------------------------------------------------------


def test_every_rule_fires_on_fixture(fixture_violations):
    pairs = {(v.rule, v.path) for v in fixture_violations}
    assert ("sharded-randomness", "core/simulator.py") in pairs
    assert ("gather-then-reduce", "core/simulator.py") in pairs
    assert ("gather-then-reduce", "core/sharding.py") in pairs
    assert ("structural-field", "core/sweep.py") in pairs
    assert ("single-source-literal", "core/channel.py") in pairs
    assert ("allow-reason", "core/dynamics.py") in pairs


def test_sharded_randomness_site(fixture_violations):
    vs = [v for v in fixture_violations if v.rule == "sharded-randomness"]
    assert len(vs) == 1  # the allow-commented draw is suppressed
    assert vs[0].path == "core/simulator.py"
    assert "n_local" in vs[0].message
    assert "make_control_sharded_round_fn" in vs[0].message  # nested def
    # inherits the outer builder's scope


def test_gather_then_reduce_arms(fixture_violations):
    vs = [v for v in fixture_violations if v.rule == "gather-then-reduce"]
    msgs = "\n".join(v.message for v in vs)
    # bare sorts in sharding fixture: sort + argsort
    sorts = [v for v in vs if v.path == "core/sharding.py"]
    assert len(sorts) == 2
    # simulator fixture: tainted-name reduce, nested-call reduce, bare gather
    sim = [v for v in vs if v.path == "core/simulator.py"]
    assert any("reduces a value gathered" in v.message for v in sim)
    assert any("reduces a all_gather_axis result" in v.message for v in sim)
    assert any("materializes" in v.message for v in sim)
    # the registry-exempt K-bounded gather is NOT flagged
    assert "hierarchical_top_k" not in msgs


def test_structural_field_both_directions(fixture_violations):
    vs = [v for v in fixture_violations if v.rule == "structural-field"]
    msgs = "\n".join(v.message for v in vs)
    assert "not_a_real_field" in msgs          # converse: stale entry
    assert "FLConfig.eval_every" in msgs       # direct attribute read
    assert "FLConfig.record_lambda_every" in msgs  # via the alias
    assert all(v.path == "core/sweep.py" for v in vs)


def test_single_source_literal_site(fixture_violations):
    vs = [v for v in fixture_violations if v.rule == "single-source-literal"]
    assert len(vs) == 1
    assert (vs[0].path, "TRUNCATION_FLOOR" in vs[0].message) == \
        ("core/channel.py", True)


def test_reasonless_allow_flagged(fixture_violations):
    vs = [v for v in fixture_violations if v.rule == "allow-reason"]
    assert [(v.path) for v in vs] == ["core/dynamics.py"]


def test_reasoned_allow_suppresses(fixture_violations):
    # the fixture's _batch_indices_ids draw carries a reasoned allow-comment
    assert not any("_batch_indices_ids" in v.message
                   for v in fixture_violations)


# ---------------------------------------------------------------------------
# Allow-comment grammar + registry cross-check loaders
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("line,rules,has_reason", [
    ("x = 1  # lint: allow(gather-then-reduce): GCA median needs [N]",
     {"gather-then-reduce"}, True),
    ("# lint: allow(sharded-randomness)", {"sharded-randomness"}, False),
    ("#lint:allow(a-rule, b-rule): two at once", {"a-rule", "b-rule"}, True),
    ("# lint: allow(structural-field):", {"structural-field"}, False),
])
def test_allow_regex(line, rules, has_reason):
    m = ALLOW_RE.search(line)
    assert m is not None
    got = {r.strip() for r in m.group("rules").split(",")}
    assert got == rules
    assert bool(m.group("sep") and m.group("reason").strip()) == has_reason


def test_allow_regex_ignores_plain_comments():
    assert ALLOW_RE.search("# a normal comment about allow lists") is None


def test_static_fields_loader_matches_runtime():
    fields, line = load_static_fields(default_root())
    assert fields == STATIC_FIELDS
    assert line > 0


def test_flconfig_loader_sees_real_fields():
    fields = load_flconfig_fields(default_root())
    assert {"num_clients", "transport", "control_plane",
            "record_lambda_every"} <= fields
    # every runtime STATIC_FIELDS entry is a real field (the converse check
    # the rule enforces, asserted here directly against the live tree)
    assert set(STATIC_FIELDS) <= fields


# ---------------------------------------------------------------------------
# CLI contract (what the CI lint lane runs)
# ---------------------------------------------------------------------------


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.lint", *args],
        capture_output=True, text=True, cwd=REPO, env=env)


def test_cli_tree_passes(tmp_path):
    report = tmp_path / "report.json"
    proc = _run_cli("--json", str(report))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(report.read_text())
    assert payload["ast"]["violations"] == []
    assert {r["name"] for r in payload["ast"]["rules"]} == {
        "sharded-randomness", "gather-then-reduce", "structural-field",
        "single-source-literal", "allow-reason"}


def test_cli_fixture_fails(tmp_path):
    report = tmp_path / "report.json"
    proc = _run_cli("--root", str(FIXTURE), "--json", str(report))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    payload = json.loads(report.read_text())
    rules_hit = {v["rule"] for v in payload["ast"]["violations"]}
    assert {"sharded-randomness", "gather-then-reduce", "structural-field",
            "single-source-literal", "allow-reason"} <= rules_hit
    # human-readable lines on stdout, one per violation
    assert "core/sweep.py" in proc.stdout
