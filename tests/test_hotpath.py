"""Selected-K hot-path differential suite.

The sparse gather-compute-scatter round (the default for exact-K methods)
is pinned against the dense [N, model] reference path across every
selection method × scenario family: identical masks/energy (the O(N)
control-channel arithmetic is shared), model trajectories equal to
summation order, and bit-for-bit where the reduction order is unchanged
(λ, scheduled counts). Also: the fused flat-buffer AirComp (Pallas
interpret == fused jnp == per-leaf reference), the ``eval_every`` cadence
semantics, and the GCA probe-reuse fix.
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.aircomp import (aircomp_aggregate_stack_tree,
                                aircomp_aggregate_tree)
from repro.core.channel import SCENARIOS
from repro.core.selection import (EXACT_K_METHODS, select_clients,
                                  select_clients_sparse)
from repro.core.simulator import run_simulation
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

N, DIM = 12, 32
MODEL = logistic_regression(dim=DIM, num_classes=10)


@pytest.fixture(scope="module")
def hot_data():
    x, y, xt, yt = make_fmnist_like(num_train=600, num_test=240, dim=DIM,
                                    seed=0)
    xs, ys = sorted_label_shards(x, y, N)
    xts, yts = sorted_label_shards(xt, yt, N)
    return xs, ys, xts, yts


def _fl(method="ca_afl", rounds=8, **kw):
    return FLConfig(num_clients=N, clients_per_round=5, rounds=rounds,
                    batch_size=16, method=method, lr0=0.3, lr_decay=0.995,
                    ascent_lr=2e-2, **kw)


# ---------------------------------------------------------------------------
# Sparse == dense reference, all methods × scenario families
# ---------------------------------------------------------------------------


SCENARIO_CASES = ("default", "markov_fading", "battery_constrained",
                  "noisy_uplink")


@pytest.mark.parametrize("scenario", SCENARIO_CASES)
@pytest.mark.parametrize("method", ["fedavg", "afl", "ca_afl", "greedy",
                                    "gca"])
def test_sparse_matches_dense_reference(hot_data, method, scenario):
    """The acceptance pin: the default (sparse for exact-K) program equals
    the dense [N, model] reference on every history field. The O(N)
    control-channel arithmetic (masks, energy ledger, λ) is shared between
    the paths, so num_scheduled is exact and energy/λ tight; the model
    trajectory differs only by eq. (10)'s summation order (K-slot sum vs
    N-masked sum) — including under receiver noise, where both paths draw
    the identical per-leaf AWGN streams."""
    fl = replace(_fl(method), **SCENARIOS[scenario])
    got = run_simulation(MODEL, fl, hot_data, seed=3)
    ref = run_simulation(MODEL, fl, hot_data, seed=3, dense=True)
    np.testing.assert_array_equal(np.asarray(got.num_scheduled),
                                  np.asarray(ref.num_scheduled))
    np.testing.assert_allclose(np.asarray(got.energy),
                               np.asarray(ref.energy), rtol=1e-6)
    for name in ref._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(got, name)), np.asarray(getattr(ref, name)),
            rtol=1e-4, atol=1e-5, err_msg=f"{method}@{scenario}:{name}")


def test_gca_default_path_is_the_dense_reference(hot_data):
    """GCA's thresholded count is unbounded by K (it can exceed
    clients_per_round), so it must NOT ride the K-slot gather path — its
    default program IS the dense one, bit-for-bit."""
    fl = _fl("gca")
    got = run_simulation(MODEL, fl, hot_data, seed=1)
    ref = run_simulation(MODEL, fl, hot_data, seed=1, dense=True)
    for name in ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(ref, name)),
            err_msg=name)


def test_sparse_selection_matches_dense_mask():
    """(mask, idx) of select_clients_sparse: the mask equals
    select_clients' and the idx slots cover exactly its support, with
    zero-weight slots where availability gates."""
    key = jax.random.PRNGKey(0)
    lam = jax.nn.softmax(jax.random.normal(key, (N,)))
    h = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (N,))) + 0.05
    avail = (jax.random.uniform(jax.random.fold_in(key, 2), (N,)) > 0.4
             ).astype(jnp.float32)
    for method in EXACT_K_METHODS:
        for av in (None, avail):
            mask, idx = select_clients_sparse(method, key, lam, h, 5, C=4.0,
                                              avail=av)
            dense = select_clients(method, key, lam, h, 5, C=4.0, avail=av)
            np.testing.assert_array_equal(np.asarray(mask), np.asarray(dense),
                                          err_msg=method)
            assert idx.shape == (5,)
            assert len(np.unique(np.asarray(idx))) == 5  # distinct slots
            # the mask's support is exactly the non-gated slots
            slot_w = np.asarray(mask)[np.asarray(idx)]
            assert float(mask.sum()) == float(slot_w.sum())


# ---------------------------------------------------------------------------
# Fused flat-buffer AirComp: Pallas (interpret) == fused jnp == per-leaf ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("noise_std", [0.0, 0.3])
def test_aircomp_stack_tree_matches_per_leaf_reference(key, noise_std):
    k1, k2, k3 = jax.random.split(key, 3)
    trees = {"w": jax.random.normal(k1, (7, 33, 10)),
             "b": jax.random.normal(k2, (7, 10))}
    weights = (jax.random.uniform(k3, (7,)) > 0.3).astype(jnp.float32)
    knoise = jax.random.fold_in(key, 9)
    k_denom = jnp.maximum(weights.sum(), 1.0)
    ref = aircomp_aggregate_tree(trees, weights, knoise, noise_std, k_denom)
    fused = aircomp_aggregate_stack_tree(trees, weights, knoise, noise_std,
                                         k_denom, use_pallas=False)
    pallas = aircomp_aggregate_stack_tree(trees, weights, knoise, noise_std,
                                          k_denom, use_pallas=True)
    for name in ("w", "b"):
        # same per-leaf noise streams: only the summation order differs
        np.testing.assert_allclose(np.asarray(fused[name]),
                                   np.asarray(ref[name]),
                                   rtol=1e-5, atol=1e-6, err_msg=name)
        np.testing.assert_allclose(np.asarray(pallas[name]),
                                   np.asarray(fused[name]),
                                   rtol=1e-5, atol=1e-6, err_msg=name)


def test_aircomp_stack_tree_traced_knobs_no_recompile(key):
    """noise_std and k are traced (SMEM scalars in the kernel): one jit
    serves every value."""
    traces = []

    @jax.jit
    def agg(trees, w, ns, k):
        traces.append(1)
        return aircomp_aggregate_stack_tree(trees, w, jax.random.PRNGKey(0),
                                            ns, k)

    trees = {"a": jax.random.normal(key, (5, 40))}
    w = jnp.ones((5,))
    for ns, k in ((0.1, 5.0), (0.7, 3.0), (0.0, 1.0)):
        agg(trees, w, jnp.float32(ns), jnp.float32(k))
    assert len(traces) == 1


# ---------------------------------------------------------------------------
# eval_every cadence
# ---------------------------------------------------------------------------


def test_eval_every_forward_fills_and_keeps_training_exact(hot_data):
    """eval_every=E: accuracy metrics are computed at rounds 0, E, 2E, ...
    and forward-filled in between; everything that doesn't depend on the
    eval (energy, λ, losses, scheduling) is unchanged."""
    e = 3
    base = run_simulation(MODEL, _fl("ca_afl", rounds=10), hot_data, seed=0)
    cad = run_simulation(MODEL, _fl("ca_afl", rounds=10, eval_every=e),
                         hot_data, seed=0)
    for name in ("energy", "loss", "num_scheduled", "lam"):
        np.testing.assert_allclose(
            np.asarray(getattr(cad, name)), np.asarray(getattr(base, name)),
            rtol=1e-6, atol=1e-7, err_msg=name)
    for name in ("avg_acc", "worst_acc", "std_acc"):
        got = np.asarray(getattr(cad, name))
        ref = np.asarray(getattr(base, name))
        for t in range(10):
            np.testing.assert_allclose(
                got[t], ref[(t // e) * e], rtol=1e-6,
                err_msg=f"{name}[{t}] should hold round {(t // e) * e}'s eval")


def test_eval_every_one_is_the_default_program(hot_data):
    """eval_every=1 is exactly the per-round-eval program (the default)."""
    a = run_simulation(MODEL, _fl("afl", rounds=5), hot_data, seed=2)
    b = run_simulation(MODEL, _fl("afl", rounds=5, eval_every=1), hot_data,
                       seed=2)
    for name in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)), err_msg=name)


# ---------------------------------------------------------------------------
# GCA probe-reuse (the former double-work bug)
# ---------------------------------------------------------------------------


def test_gca_round_reuses_probe_gradients(hot_data):
    """With local_steps=1 the scheduled clients' updates must be exactly one
    SGD step along the PROBE gradients (w - η·g0): the probe batch is the
    descent batch by design and g0 is SGD step 1, not a throwaway."""
    from repro.core.simulator import (init_sim_state, make_param_round_fn)
    from repro.core.sweep import sweep_point_from_config
    from repro.utils.tree import tree_size

    fl = _fl("gca", rounds=1)
    point = sweep_point_from_config(fl)
    state = init_sim_state(MODEL, fl, jax.random.PRNGKey(0),
                           process=point.process)
    round_fn = make_param_round_fn(MODEL, fl, hot_data, tree_size(state.w),
                                   "gca")
    new_state, hist = jax.jit(lambda p, s: round_fn(p, s, 0))(point, state)

    # replay the round's key split and batch draw by hand
    from repro.core.simulator import _sample_batches
    _, _, _, k_batch, _, _, _ = jax.random.split(state.key, 7)
    xb, yb = _sample_batches(k_batch, hot_data[0], hot_data[1], fl.batch_size)
    g0 = jax.vmap(jax.grad(MODEL.loss), in_axes=(None, 0, 0))(state.w, xb, yb)
    eta = fl.lr0  # t = 0
    stepped = jax.vmap(
        lambda g: jax.tree.map(lambda p, gg: p - eta * gg, state.w, g))(g0)
    # aggregate by hand with the recorded mask cardinality
    k_sched = float(hist.num_scheduled)
    assert k_sched > 0
    # reconstruct the mask from the aggregated model: Σ mask_i w_i / k == w̄
    # holds only if the round reused g0 as step 1
    _, _, k_sel, _, _, _, _ = jax.random.split(state.key, 7)
    gn = jax.vmap(lambda g: jnp.sqrt(sum(
        jnp.sum(jnp.square(l)) for l in jax.tree_util.tree_leaves(g))))(g0)
    from repro.core.channel import draw_channels_scenario, effective_channel
    _, k_chan, _, _, _, _, _ = jax.random.split(state.key, 7)
    h = effective_channel(draw_channels_scenario(
        k_chan, point.scenario, N, fl.num_subcarriers))
    mask = select_clients("gca", k_sel, state.lam, h, fl.clients_per_round,
                          grad_norms=gn, gca=point.gca)
    expect = jax.tree.map(
        lambda leaf: jnp.einsum("n...,n->...", leaf, mask) / k_sched, stepped)
    for a, b in zip(jax.tree_util.tree_leaves(expect),
                    jax.tree_util.tree_leaves(new_state.w), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_gca_multi_local_steps_still_descends(hot_data):
    """local_steps > 1 runs the remaining steps after the reused first one."""
    h1 = run_simulation(MODEL, _fl("gca", rounds=6), hot_data, seed=0)
    h3 = run_simulation(MODEL, _fl("gca", rounds=6, local_steps=3), hot_data,
                        seed=0)
    assert bool(jnp.all(jnp.isfinite(h3.avg_acc)))
    # more local steps move the model further in early rounds
    assert not np.allclose(np.asarray(h1.lam), np.asarray(h3.lam))


def test_server_gca_probe_reuse_matches_dense_round(hot_data):
    """Production tier: the probe-reuse GCA step equals the old
    probe-then-full-round step (same params, λ, energy) to summation
    order."""
    from repro.federated.server import ParameterServer
    from repro.models.logreg import logistic_regression_prod
    from repro.optim import sgd

    n_cli, per = 6, 4
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (n_cli * per, DIM))
    yv = jax.random.randint(jax.random.fold_in(key, 1), (n_cli * per,), 0, 10)
    batch = {"x": x, "labels": yv,
             "client_ids": jnp.repeat(jnp.arange(n_cli), per)}
    fl = FLConfig(num_clients=n_cli, clients_per_round=3, rounds=1,
                  batch_size=per, method="gca", lr0=0.2, noise_std=0.0)
    model = logistic_regression_prod(DIM, 10)

    outs = {}
    for reuse in (True, False):
        ps = ParameterServer(model, sgd(fl.lr0), fl, seed=0,
                             reuse_probe_grads=reuse)
        st = ps.init_state(jax.random.PRNGKey(1))
        st.params["w"] = st.params["w"] + 0.1  # off-zero params
        outs[reuse] = ps.step(st, batch)
    a, b = outs[True], outs[False]
    assert a.history[-1]["num_scheduled"] == b.history[-1]["num_scheduled"]
    np.testing.assert_allclose(a.energy_joules, b.energy_joules, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a.lam), np.asarray(b.lam),
                               atol=1e-6)
    np.testing.assert_allclose(a.history[-1]["loss"], b.history[-1]["loss"],
                               rtol=1e-5)
    for pa, pb in zip(jax.tree_util.tree_leaves(a.params),
                      jax.tree_util.tree_leaves(b.params), strict=True):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# ISSUE 4 satellites: wide-index gather + widest-dtype aggregation
# ---------------------------------------------------------------------------


def test_gather_batches_two_stage_matches_composed(key):
    """The two paths of ``_gather_batches`` (composed flat gather vs the
    two-stage per-client fallback for N·S > int32) are interchangeable."""
    from repro.core.simulator import _batch_indices, _gather_batches

    n, s, b, d = 10, 7, 4, 3
    x = jax.random.normal(key, (n, s, d))
    y = jax.random.randint(jax.random.fold_in(key, 1), (n, s), 0, 10)
    cidx = jnp.asarray([8, 2, 5, 2])
    bidx = _batch_indices(jax.random.fold_in(key, 2), n, s, b)[cidx]
    x1, y1 = _gather_batches(x, y, cidx, bidx, two_stage=False)
    x2, y2 = _gather_batches(x, y, cidx, bidx, two_stage=True)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_gather_batches_wide_index_dispatch():
    """N·S beyond int32 must route to the two-stage gather: the composed
    ``cidx * S + bidx`` flat index silently wraps negative in int32 (the
    regression this pins), and int64 indices would need the x64 mode the
    engine does not run under."""
    from repro.core.simulator import _needs_two_stage_gather

    # the bug, reproduced at synthetic shapes: client 9 of a population with
    # S = 2^28-sized shards composes to 9·2^28 + 5 > 2^31 → wraps negative
    with np.errstate(over="ignore"):
        wrapped = np.int32(9) * np.int32(2 ** 28) + np.int32(5)
    assert wrapped < 0  # the silent int32 overflow the old code shipped

    # the static dispatch predicate at synthetic populations just over the
    # boundary (no N·S-sized allocation needed — it reads only the shapes)
    assert not _needs_two_stage_gather(100, 20)           # the paper's scale
    assert not _needs_two_stage_gather(2 ** 26, 2 ** 5 - 1)
    assert not _needs_two_stage_gather(2 ** 16, 2 ** 15)  # N·S-1 == int32max
    assert _needs_two_stage_gather(2 ** 16, 2 ** 15 + 1)  # one past it
    assert _needs_two_stage_gather(2 ** 26, 2 ** 6)       # huge-N regime


def test_aircomp_stack_tree_preserves_float64(key):
    """The fused flat path used to ravel every leaf through f32, silently
    halving a float64 model's mantissa; it must aggregate at the widest
    leaf dtype like the per-leaf reference."""
    from repro.core.aircomp import stack_accum_dtype

    jax.config.update("jax_enable_x64", True)
    try:
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
        trees = {
            "w": jax.random.normal(k1, (6, 17), dtype=jnp.float64),
            "b": jax.random.normal(k2, (6, 5), dtype=jnp.float64),
        }
        assert stack_accum_dtype(jax.tree_util.tree_leaves(trees)) == jnp.float64
        weights = (jax.random.uniform(k3, (6,)) > 0.3).astype(jnp.float64)
        knoise = jax.random.fold_in(k1, 9)
        k_denom = jnp.maximum(weights.sum(), 1.0)
        for noise_std in (0.0, 0.25):
            ref = aircomp_aggregate_tree(trees, weights, knoise, noise_std,
                                         k_denom)
            fused = aircomp_aggregate_stack_tree(trees, weights, knoise,
                                                 noise_std, k_denom)
            for name in ("w", "b"):
                assert fused[name].dtype == jnp.float64, name
                # f64-tight: an f32-raveled path errs at ~1e-8 and fails this
                np.testing.assert_allclose(np.asarray(fused[name]),
                                           np.asarray(ref[name]),
                                           rtol=1e-12, atol=1e-13,
                                           err_msg=name)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_aircomp_stack_tree_mixed_dtype_casts_back(key):
    """bf16 leaves keep f32 accumulation and return as bf16."""
    trees = {"w": jax.random.normal(key, (5, 12)).astype(jnp.bfloat16),
             "b": jax.random.normal(jax.random.fold_in(key, 1), (5, 3))}
    weights = jnp.ones((5,))
    out = aircomp_aggregate_stack_tree(trees, weights, jax.random.PRNGKey(0),
                                       0.0, 5.0)
    assert out["w"].dtype == jnp.bfloat16
    assert out["b"].dtype == jnp.float32
