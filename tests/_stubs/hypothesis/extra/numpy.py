"""`hypothesis.extra.numpy.arrays` for the shim (see package docstring)."""
from __future__ import annotations

import numpy as np

from ..strategies import Strategy


def arrays(dtype, shape, *, elements: Strategy) -> Strategy:
    """Array strategy: `shape` is a tuple or a Strategy producing one."""

    def draw(rng):
        shp = shape.draw(rng) if isinstance(shape, Strategy) else tuple(shape)
        n = int(np.prod(shp)) if shp else 1
        flat = np.array([elements.draw(rng) for _ in range(n)], dtype=dtype)
        return flat.reshape(shp)

    return Strategy(draw)
