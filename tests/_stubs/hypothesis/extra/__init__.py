from . import numpy  # noqa: F401
