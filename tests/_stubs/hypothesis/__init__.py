"""Minimal, deterministic stand-in for the `hypothesis` API this repo uses.

The real `hypothesis` is pinned in ``pyproject.toml`` and is what CI installs;
this shim only exists so the suite still *runs* (rather than failing
collection) in hermetic environments where `hypothesis` cannot be installed.
``tests/conftest.py`` puts ``tests/_stubs`` on ``sys.path`` only when the real
package is missing, so the genuine article always wins when present.

Implemented surface (exactly what the tests use):
  - ``@given(*strategies)`` / ``@settings(max_examples=, deadline=)``
  - ``strategies.floats / integers / lists`` and ``Strategy.map``
  - ``hypothesis.extra.numpy.arrays(dtype, shape, elements=...)``

Examples are drawn from a ``numpy`` Generator seeded from the test name, so
runs are reproducible and shrinking (which the shim does not do) is not needed
for triage — re-running reproduces the same failing example.
"""
from __future__ import annotations

import zlib

import numpy as np

from . import strategies
from .strategies import Strategy

__all__ = ["given", "settings", "strategies", "Strategy"]

_SETTINGS_ATTR = "_stub_hypothesis_settings"


def settings(max_examples: int = 25, deadline=None, **_ignored):
    """Record example-count settings on the test function (decorator)."""

    def deco(fn):
        setattr(fn, _SETTINGS_ATTR, {"max_examples": max_examples})
        return fn

    return deco


def given(*arg_strategies: Strategy):
    """Run the test once per generated example (no shrinking, fixed seed)."""

    def deco(fn):
        def runner():
            # resolved at call time so both decorator orders work: @settings
            # below @given stamps `fn`; @settings above @given stamps `runner`
            cfg = getattr(runner, _SETTINGS_ATTR,
                          getattr(fn, _SETTINGS_ATTR, {"max_examples": 25}))
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for example in range(cfg["max_examples"]):
                args = [s.draw(rng) for s in arg_strategies]
                try:
                    fn(*args)
                except Exception as err:  # noqa: BLE001 - annotate and re-raise
                    raise AssertionError(
                        f"{fn.__name__} failed on example {example} "
                        f"with args {args!r}"
                    ) from err

        # NOTE: no functools.wraps — pytest follows __wrapped__ when
        # inspecting signatures and would demand fixtures named after the
        # strategy parameters.
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner

    return deco
