"""Strategy objects for the `hypothesis` shim (see package docstring)."""
from __future__ import annotations

from typing import Callable

import numpy as np


class Strategy:
    """A draw rule: ``draw(rng) -> value``. Supports ``.map`` like hypothesis."""

    def __init__(self, draw: Callable[[np.random.Generator], object]):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, fn: Callable) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           allow_nan: bool = False, **_ignored) -> Strategy:
    lo, hi = float(min_value), float(max_value)

    def draw(rng):
        # Mix in the endpoints occasionally — hypothesis probes boundaries.
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return float(rng.uniform(lo, hi))

    return Strategy(draw)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(size)]

    return Strategy(draw)
