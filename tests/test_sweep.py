"""Sweep-engine tests: numerical equivalence with the single-run simulator,
compile-count guarantees (one trace per selection method), scenario
parameterization, and the SweepResult aggregation layer."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, GCAParams
from repro.core import sweep
from repro.core.channel import (SCENARIOS, ChannelScenario, draw_channels,
                                draw_channels_scenario, scenario_from_config)
from repro.core.simulator import run_multi_seed, run_simulation
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

N, DIM = 12, 32
MODEL = logistic_regression(dim=DIM, num_classes=10)


@pytest.fixture(scope="module")
def sweep_data():
    x, y, xt, yt = make_fmnist_like(num_train=600, num_test=240, dim=DIM,
                                    seed=0)
    xs, ys = sorted_label_shards(x, y, N)
    xts, yts = sorted_label_shards(xt, yt, N)
    return xs, ys, xts, yts


def _fl(method="ca_afl", rounds=8, **kw):
    return FLConfig(num_clients=N, clients_per_round=5, rounds=rounds,
                    batch_size=16, method=method, lr0=0.3, lr_decay=0.995,
                    ascent_lr=2e-2, **kw)


# ---------------------------------------------------------------------------
# Channel scenarios
# ---------------------------------------------------------------------------


def test_default_scenario_draw_matches_legacy(key):
    """With the paper's defaults the scenario path is the legacy draw,
    bit-for-bit (same key consumption, identity gain)."""
    for flat in (True, False):
        legacy = draw_channels(key, N, 16, floor=0.05, flat=flat)
        scen = scenario_from_config(_fl(flat_fading=flat))
        np.testing.assert_array_equal(
            draw_channels_scenario(key, scen, N, 16), legacy)


def test_scenario_pathloss_and_shadowing_take_effect(key):
    scen = scenario_from_config(_fl(pathloss_db_spread=12.0))
    h = draw_channels_scenario(key, scen, N, 16)
    base = draw_channels_scenario(key, scenario_from_config(_fl()), N, 16)
    # 12 dB spread: first client attenuated, last amplified vs. homogeneous
    assert float(h[0].mean()) < float(base[0].mean())
    assert float(h[-1].mean()) > float(base[-1].mean())

    shadowed = draw_channels_scenario(
        key, scenario_from_config(_fl(shadowing_std=0.8)), N, 16)
    assert not np.allclose(shadowed, base)


def test_scenario_is_vmappable_pytree():
    """Data fields stack along a vmap axis; `flat` stays static metadata."""
    scens = [scenario_from_config(_fl(channel_floor=f)) for f in (0.05, 0.2)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *scens)
    assert stacked.floor.shape == (2,)
    assert stacked.pathloss.shape == (2, N)
    assert stacked.flat is True  # metadata, not stacked

    batched = jax.vmap(
        lambda s: draw_channels_scenario(jax.random.PRNGKey(0), s, N, 16))
    h = batched(stacked)
    assert h.shape == (2, N, 16)
    assert float(h[1].min()) >= 0.2 - 1e-6


def test_scenario_registry_entries_are_valid_configs():
    for name, overrides in SCENARIOS.items():
        fl = replace(_fl(), **overrides)
        scen = scenario_from_config(fl)
        assert isinstance(scen, ChannelScenario), name


# ---------------------------------------------------------------------------
# Numerical equivalence with run_simulation / run_multi_seed
# ---------------------------------------------------------------------------


def test_one_point_sweep_matches_run_simulation(sweep_data):
    fl = _fl("ca_afl")
    ref = run_simulation(MODEL, fl, sweep_data, seed=3)
    res = sweep.run_sweep(MODEL, sweep_data, [("pt", fl)], seeds=(3,))
    got = jax.tree.map(lambda x: x[0], res.history("pt"))
    for name in ref._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(got, name)), np.asarray(getattr(ref, name)),
            rtol=1e-5, atol=1e-6, err_msg=name)


def test_five_seed_two_method_sweep_matches_and_compiles_once_per_method(
        sweep_data):
    """The acceptance criterion: a 5-seed × 2-method sweep reproduces
    per-config `run_simulation` numerically with exactly one compilation per
    selection method (the two CA-AFL C-values share one)."""
    seeds = (0, 1, 2, 3, 4)
    specs = [("fedavg", _fl("fedavg")),
             ("ca_afl_c2", _fl("ca_afl", energy_C=2.0)),
             ("ca_afl_c8", _fl("ca_afl", energy_C=8.0))]
    sweep.reset_trace_log()
    res = sweep.run_sweep(MODEL, sweep_data, specs, seeds=seeds)
    assert sweep.trace_count() == 2  # methods: {fedavg, ca_afl}

    for label, fl in specs:
        hist = res.history(label)
        assert hist.avg_acc.shape == (len(seeds), fl.rounds)
        for si, s in enumerate(seeds):
            ref = run_simulation(MODEL, fl, sweep_data, seed=s)
            np.testing.assert_allclose(
                np.asarray(hist.energy)[si], np.asarray(ref.energy),
                rtol=1e-5, err_msg=f"{label} seed {s}")
            np.testing.assert_allclose(
                np.asarray(hist.avg_acc)[si], np.asarray(ref.avg_acc),
                atol=1e-6, err_msg=f"{label} seed {s}")


def test_run_multi_seed_matches_explicit_average(sweep_data):
    """run_multi_seed (now one jit via the sweep engine) equals the old
    per-seed loop average."""
    fl = _fl("afl", rounds=6)
    seeds = (0, 1, 2)
    got = run_multi_seed(MODEL, fl, sweep_data, seeds)
    runs = [run_simulation(MODEL, fl, sweep_data, seed=s) for s in seeds]
    ref = jax.tree.map(lambda *xs: jnp.stack(xs).mean(0), *runs)
    assert got.avg_acc.shape == (fl.rounds,)
    np.testing.assert_allclose(np.asarray(got.avg_acc),
                               np.asarray(ref.avg_acc), atol=1e-6)
    np.testing.assert_allclose(np.asarray(got.energy),
                               np.asarray(ref.energy), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got.lam),
                               np.asarray(ref.lam), atol=1e-6)


def test_gca_params_ride_the_sweep_axis(sweep_data):
    """A GCA hyperparameter grid shares one compilation and actually changes
    behaviour (scheduled counts differ across thresholds)."""
    specs = [("loose", _fl("gca", gca=GCAParams(rho1=0.2, rho2=0.2))),
             ("tight", _fl("gca", gca=GCAParams(rho1=0.8, rho2=0.8)))]
    sweep.reset_trace_log()
    res = sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0,))
    assert sweep.trace_count() == 1
    loose = float(np.asarray(res.history("loose").num_scheduled).mean())
    tight = float(np.asarray(res.history("tight").num_scheduled).mean())
    assert loose > tight  # lower threshold schedules more clients


def test_dynamic_scenario_end_to_end_in_sweep(sweep_data):
    """A temporal (battery-constrained Gauss-Markov) scenario runs through
    ``expand_grid`` + ``run_sweep`` like any static one: one extra compile
    for the dynamic structure, per-seed histories with live battery/
    availability columns, and a sweep-vs-single-run match."""
    battery = 1.2e-3  # binds within a few rounds at this model scale
    specs = sweep.expand_grid(
        _fl("ca_afl", rounds=10), variants={"ca_afl": {}},
        scenarios=("default",
                   ("battery_markov", {"temporal": True, "rho_fading": 0.8,
                                       "battery_init": battery})))
    sweep.reset_trace_log()
    res = sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0, 1))
    assert sweep.trace_count() == 2  # {static, temporal} structures
    dyn = res.history("ca_afl@battery_markov")
    assert bool(jnp.all(jnp.isfinite(dyn.avg_acc)))
    mb = np.asarray(dyn.min_battery)
    assert np.all(np.diff(mb, axis=1) <= 1e-9) and np.all(mb >= -1e-9)
    assert np.all(np.asarray(dyn.energy)[:, -1] <= N * battery + 1e-6)
    # the sweep cell equals the standalone simulator run of the same config
    fl_dyn = dict(specs)["ca_afl@battery_markov"]
    ref = run_simulation(MODEL, fl_dyn, sweep_data, seed=1)
    np.testing.assert_allclose(np.asarray(dyn.avg_acc)[1],
                               np.asarray(ref.avg_acc), atol=1e-6)
    np.testing.assert_allclose(np.asarray(dyn.min_battery)[1],
                               np.asarray(ref.min_battery), rtol=1e-5)


def test_eval_every_groups_and_matches(sweep_data):
    """eval_every is structural: cells with different cadences land in
    different compilation groups, cells with the same cadence share one —
    and the cadenced cell still matches its standalone run."""
    specs = [("e1", _fl("ca_afl")),
             ("e4a", _fl("ca_afl", eval_every=4)),
             ("e4b", _fl("ca_afl", eval_every=4, energy_C=2.0))]
    sweep.reset_trace_log()
    res = sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0,))
    assert sweep.trace_count() == 2  # {eval_every=1, eval_every=4}
    ref = run_simulation(MODEL, _fl("ca_afl", eval_every=4), sweep_data,
                         seed=0)
    np.testing.assert_allclose(
        np.asarray(res.history("e4a").avg_acc)[0], np.asarray(ref.avg_acc),
        atol=1e-6)
    # forward-filled between evals
    acc = np.asarray(res.history("e4b").avg_acc)[0]
    for t in range(len(acc)):
        np.testing.assert_allclose(acc[t], acc[(t // 4) * 4])


def test_sweep_runner_donates_states_without_warnings(sweep_data):
    """The runner donates the SimState stack (the scan carry reuses the
    caller's buffers); XLA must find the input→output aliasing — a
    'donated buffers were not usable' warning means it did not."""
    import warnings

    specs = [("a", _fl("ca_afl", rounds=4)),
             ("b", _fl("fedavg", rounds=4))]
    with warnings.catch_warnings(record=True) as log:
        warnings.simplefilter("always")
        sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0, 1))
    donation_warnings = [w for w in log if "donat" in str(w.message).lower()]
    assert not donation_warnings, [str(w.message) for w in donation_warnings]


def test_scenarios_change_outcomes_in_sweep(sweep_data):
    """Scenario knobs are live inside the jitted sweep: a 12 dB pathloss
    spread changes the energy ledger under uniform (fedavg) selection."""
    specs = sweep.expand_grid(
        _fl("fedavg"), variants={"fedavg": {}},
        scenarios=("default", "heterogeneous_pathloss"))
    res = sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0,))
    e_def = res.summary(3)["fedavg"]["energy"]
    e_het = res.summary(3)["fedavg@heterogeneous_pathloss"]["energy"]
    assert e_def > 0 and e_het > 0 and not np.isclose(e_def, e_het)


# ---------------------------------------------------------------------------
# Grid expansion + aggregation layer
# ---------------------------------------------------------------------------


def test_expand_grid_labels_and_overrides():
    specs = sweep.expand_grid(
        _fl(), variants={"afl": {"method": "afl"}},
        scenarios=("default", "noisy_uplink"))
    labels = [lbl for lbl, _ in specs]
    assert labels == ["afl", "afl@noisy_uplink"]
    by = dict(specs)
    assert by["afl"].method == "afl" and by["afl"].noise_std == 0.0
    assert by["afl@noisy_uplink"].noise_std == pytest.approx(1e-2)


def test_expand_grid_dict_scenarios_get_distinct_labels():
    """Raw override dicts are labelled by contents; (name, dict) pairs by
    name — so two ad-hoc scenarios never collide."""
    specs = sweep.expand_grid(
        _fl(), scenarios=({"noise_std": 1e-3}, {"noise_std": 1e-2},
                          ("quiet", {"noise_std": 0.0})))
    labels = [lbl for lbl, _ in specs]
    assert labels == ["base@noise_std=0.001", "base@noise_std=0.01",
                      "base@quiet"]
    assert len(set(labels)) == 3


def test_mixed_noise_group_matches_single_runs(sweep_data):
    """A compilation group mixing noise-free and noisy points keeps the
    traced noise path; the statically-elided path (all-zero group) and the
    traced path agree with run_simulation either way."""
    specs = [("clean", _fl("afl", rounds=5)),
             ("noisy", _fl("afl", rounds=5, noise_std=3e-2))]
    sweep.reset_trace_log()
    res = sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0,))
    assert sweep.trace_count() == 1
    for label, fl in specs:
        ref = run_simulation(MODEL, fl, sweep_data, seed=0)
        np.testing.assert_allclose(
            np.asarray(res.history(label).avg_acc)[0],
            np.asarray(ref.avg_acc), atol=1e-6, err_msg=label)


def test_run_sweep_rejects_duplicate_labels(sweep_data):
    with pytest.raises(ValueError):
        sweep.run_sweep(MODEL, sweep_data,
                        [("a", _fl()), ("a", _fl())], seeds=(0,))


def test_pareto_indices():
    costs = np.array([1.0, 2.0, 3.0, 0.5])
    utils = np.array([0.5, 0.9, 0.8, 0.1])
    # idx 2 dominated by idx 1 (more cost, less utility); rest on the front
    assert sweep.pareto_indices(costs, utils) == [3, 0, 1]


def test_summary_and_pareto_shapes(sweep_data):
    specs = [("afl", _fl("afl", rounds=6)),
             ("greedy", _fl("greedy", rounds=6))]
    res = sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0, 1))
    s = res.summary(window=3)
    assert set(s) == {"afl", "greedy"}
    for row in s.values():
        assert row["energy"] > 0
        assert 0.0 <= row["worst_case_acc"] <= row["avg_acc"] <= 1.0
        assert row["num_scheduled"] == pytest.approx(5.0)
    front = res.pareto_front(window=3)
    assert front and set(front) <= {"afl", "greedy"}
    # greedy picks the best channels: it must be the cheaper of the two
    assert s["greedy"]["energy"] < s["afl"]["energy"]


def test_save_json_roundtrip(sweep_data, tmp_path):
    res = sweep.run_sweep(MODEL, sweep_data, [("afl", _fl("afl", rounds=4))],
                          seeds=(0,))
    payload = res.save_json(tmp_path / "out.json", window=2,
                            extra={"bench": "t"})
    import json
    on_disk = json.loads((tmp_path / "out.json").read_text())
    assert on_disk == payload
    assert on_disk["bench"] == "t"
    assert on_disk["labels"] == ["afl"]


# ---------------------------------------------------------------------------
# ISSUE 4 satellites: eval-round summary windows + checkpoint resume
# ---------------------------------------------------------------------------


def test_summary_windows_over_eval_rounds(sweep_data):
    """Regression: under eval_every=E the last `window` rounds are mostly
    forward-filled copies, double-counting stale evals. The summary must
    window over actual eval rounds — E=5's summary equals the E=1 run's
    summary computed on the same subsampled cadence."""
    rounds, window = 20, 3
    r5 = sweep.run_sweep(
        MODEL, sweep_data,
        [("run", _fl("ca_afl", rounds=rounds, eval_every=5))], seeds=(0, 1))
    r1 = sweep.run_sweep(
        MODEL, sweep_data, [("run", _fl("ca_afl", rounds=rounds))],
        seeds=(0, 1))
    s5 = r5.summary(window)["run"]

    # the E=1 oracle, subsampled by hand to the E=5 eval cadence
    h1 = r1.history("run")
    eval_idx = np.arange(0, rounds, 5)[-window:]
    for field, key_ in (("avg_acc", "avg_acc"), ("worst_acc", "worst_acc"),
                        ("std_acc", "client_std")):
        oracle = np.asarray(getattr(h1, field))[:, eval_idx].mean(1).mean()
        np.testing.assert_allclose(s5[key_], oracle, atol=1e-6,
                                   err_msg=field)
    # E=1 summaries keep the plain tail window (old behavior, unchanged)
    s1 = r1.summary(window)["run"]
    np.testing.assert_allclose(
        s1["avg_acc"],
        np.asarray(h1.avg_acc)[:, -window:].mean(1).mean(), atol=1e-6)


def test_sweep_checkpoint_resume(sweep_data, tmp_path):
    """Opt-in resume hook: a rerun with the same grid restores completed
    compilation groups from the checkpoint instead of recomputing them."""
    specs = [("ca", _fl("ca_afl", rounds=4)),
             ("fed", _fl("fedavg", rounds=4))]
    ckdir = str(tmp_path / "sweep_ck")
    full = sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0, 1),
                           checkpoint_dir=ckdir)
    sweep.reset_trace_log()
    resumed = sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0, 1),
                              checkpoint_dir=ckdir)
    assert sweep.trace_count() == 0  # nothing recompiled, nothing rerun
    for lbl in ("ca", "fed"):
        for f in full.history(lbl)._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(full.history(lbl), f)),
                np.asarray(getattr(resumed.history(lbl), f)), err_msg=f)
    # a changed grid shape must fail loudly, not resume garbage
    with pytest.raises(ValueError, match="shape mismatch"):
        sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0, 1, 2),
                        checkpoint_dir=ckdir)
    # ... and so must a shape-compatible but DIFFERENT grid: the done flags
    # are positional, so reordered specs (or changed traced knobs under the
    # same labels) would silently misattribute histories without the
    # fingerprint check
    with pytest.raises(ValueError, match="different sweep grid"):
        sweep.run_sweep(MODEL, sweep_data, list(reversed(specs)),
                        seeds=(0, 1), checkpoint_dir=ckdir)
    with pytest.raises(ValueError, match="different sweep grid"):
        from dataclasses import replace as _rep
        tweaked = [(lbl, _rep(fl, lr0=0.123)) for lbl, fl in specs]
        sweep.run_sweep(MODEL, sweep_data, tweaked, seeds=(0, 1),
                        checkpoint_dir=ckdir)


def test_sweep_devices_one_is_default_path(sweep_data):
    """devices=None and devices=1 build no mesh and share the executable:
    a second call with devices=1 hits the jit cache of neither (fresh
    _build_runner) but produces bit-identical histories."""
    specs = [("run", _fl("ca_afl", rounds=4))]
    a = sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0, 1))
    b = sweep.run_sweep(MODEL, sweep_data, specs, seeds=(0, 1), devices=1)
    for f in a.history("run")._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a.history("run"), f)),
                                      np.asarray(getattr(b.history("run"), f)))
