"""ISSUE 8 λ-control suite: psum-bisection projection + strided λ history.

Pinned here (single-device tier-1 lane; the mesh differentials live in
``tests/test_control_sharded.py``):
  - ``sharding.project_simplex_sharded`` (bisection on the water level θ)
    equals the sort-based ``dro.project_simplex`` reference to <= 1e-6 rel
    under ARBITRARY inputs — duplicates, huge magnitudes, -inf rows — and
    always lands on the simplex (property suite, hypothesis/shim);
  - the satellite bugfix: ``project_simplex`` accumulates its cumsum/θ at
    f64 internally, so with x64 enabled a large-N near-tie vector matches
    the straight-f64 oracle exactly (the f32 cumsum drift used to pick the
    wrong support size ρ);
  - ``FLConfig.record_lambda_every`` semantics: E=1 is today's dense [T, N]
    history bit-for-bit, E>1 records exactly the t % E == 0 rows, E=0 drops
    the leaf — and the always-on λ summary leaves (max / entropy /
    effective support size) are identical across all cadences and match a
    post-hoc recompute from the dense rows;
  - ``SweepResult.summary`` windows λ stats over actual RECORDED rows: the
    E=5 summary equals the E=1 summary computed on the subsampled cadence
    (the forward-fill double-counting bug class PR 4 fixed for accuracy).
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.configs.base import FLConfig
from repro.core import dro
from repro.core.sharding import project_simplex_sharded
from repro.core.simulator import run_simulation
from repro.core.sweep import run_sweep
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.models.logreg import logistic_regression

FINITE = st.floats(min_value=-50.0, max_value=50.0, allow_nan=False)


# ---------------------------------------------------------------------------
# bisection == sort reference (property suite)
# ---------------------------------------------------------------------------


def _check_matches_sort(v):
    v = jnp.asarray(v)
    ref = np.asarray(dro.project_simplex(v))
    bis = np.asarray(project_simplex_sharded(v))
    assert np.all(bis >= 0.0)
    np.testing.assert_allclose(bis.sum(), 1.0, atol=1e-5)
    # <= 1e-6 relative on the simplex scale (entries are <= 1)
    np.testing.assert_allclose(bis, ref, atol=2e-6)


@pytest.mark.property
@given(hnp.arrays(np.float32, (16,), elements=FINITE))
@settings(max_examples=30, deadline=None)
def test_bisection_matches_sort_property(v):
    _check_matches_sort(v)


@pytest.mark.property
@given(st.integers(0, 10_000))
def test_bisection_matches_sort_duplicates(seed):
    # heavy quantization => many exact duplicates sitting at the water level
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 64))
    v = np.round(rng.normal(size=n) * 2).astype(np.float32) / 2
    _check_matches_sort(v)


@pytest.mark.property
@given(st.integers(0, 10_000))
def test_bisection_neg_inf_rows(seed):
    # -inf rows (unavailable clients) get exactly zero mass and the finite
    # rows still form a simplex; the sort reference NaNs on -inf (inf - inf
    # in its cumsum), so the bisection is pinned against the projection of
    # the finite sub-vector instead
    rng = np.random.default_rng(seed)
    n_fin = int(rng.integers(1, 12))
    n_inf = int(rng.integers(1, 12))
    fin = rng.normal(size=n_fin).astype(np.float32)
    v = np.concatenate([fin, np.full((n_inf,), -np.inf, np.float32)])
    v = v[rng.permutation(n_fin + n_inf)]
    out = np.asarray(project_simplex_sharded(jnp.asarray(v)))
    assert np.all(out[np.isneginf(v)] == 0.0)
    ref = np.asarray(dro.project_simplex(jnp.asarray(fin)))
    np.testing.assert_allclose(np.sort(out[np.isfinite(v)]),
                               np.sort(ref), atol=2e-6)


def test_bisection_large_n_matches_f64_oracle():
    # N=10^5 off-simplex ramp (the water level cuts mid-population): the
    # regime the sort path's f32 cumsum used to drift in; the bisection's
    # support-set polish must land on the f64 oracle's triangular profile
    n = 100_000
    v64 = np.full((n,), 1.0 / n) + 1e-9 * np.arange(n, dtype=np.float64)
    out = np.asarray(project_simplex_sharded(jnp.asarray(v64, jnp.float32)))
    np.testing.assert_allclose(out.sum(), 1.0, atol=1e-4)
    assert np.all(out >= 0.0)
    # f64 oracle: support = top-m of the ramp, theta from the closed form
    u = np.sort(v64)[::-1]
    css = np.cumsum(u)
    k = np.arange(1, n + 1)
    rho = int(np.max(np.where(u + (1.0 - css) / k > 0, k, 0)))
    theta = (css[rho - 1] - 1.0) / rho
    np.testing.assert_allclose(out, np.maximum(v64 - theta, 0.0),
                               atol=2e-8)


# ---------------------------------------------------------------------------
# satellite bugfix: f64 internal accumulation of the sort-based projection
# ---------------------------------------------------------------------------


def test_project_simplex_f64_accumulation_matches_oracle():
    """With x64 on, the f32-input projection must agree with a straight-f64
    NumPy oracle on a large near-tie vector. Before the fix the f32 cumsum
    drifted by ~N·ulp over N=10^5 entries near-uniform entries — enough to
    flip the support predicate at the water level and pick a wrong ρ."""
    n = 100_000
    rng = np.random.default_rng(0)
    # near-uniform with ties: worst case for the support-size predicate
    v32 = (np.full((n,), 1.0 / n) +
           rng.choice([0.0, 1e-8], size=n)).astype(np.float32)

    def oracle(v):
        u = np.sort(v.astype(np.float64))[::-1]
        css = np.cumsum(u)
        k = np.arange(1, n + 1, dtype=np.float64)
        rho = np.max(np.where(u + (1.0 - css) / k > 0, k, 0.0))
        theta = (np.sum(np.where(u + (1.0 - css) / k > 0, u, 0.0)) - 1) / rho
        return np.maximum(v - theta, 0.0)

    jax.config.update("jax_enable_x64", True)
    try:
        got = np.asarray(dro.project_simplex(jnp.asarray(v32)))
    finally:
        jax.config.update("jax_enable_x64", False)
    np.testing.assert_allclose(got, oracle(v32).astype(np.float32),
                               atol=np.float32(1.0 / n) * 1e-3)
    np.testing.assert_allclose(got.sum(), 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# record_lambda_every semantics + summary windowing
# ---------------------------------------------------------------------------

_N, _DIM = 8, 32
_MODEL = logistic_regression(dim=_DIM, num_classes=10)


@pytest.fixture(scope="module")
def lam_data():
    x, y, xt, yt = make_fmnist_like(num_train=320, num_test=160, dim=_DIM,
                                    seed=0)
    return (*sorted_label_shards(x, y, _N), *sorted_label_shards(xt, yt, _N))


def _fl(**kw):
    return FLConfig(num_clients=_N, clients_per_round=3, rounds=10,
                    batch_size=8, method="ca_afl", lr0=0.3, ascent_lr=2e-2,
                    **kw)


@pytest.mark.parametrize("control_plane", ["replicated", "sharded"])
def test_record_lambda_every_semantics(lam_data, control_plane):
    fl = _fl(control_plane=control_plane)
    dense = run_simulation(_MODEL, fl, lam_data, seed=0)
    assert np.asarray(dense.lam).shape == (10, _N)
    strided = run_simulation(_MODEL, replace(fl, record_lambda_every=3),
                             lam_data, seed=0)
    # ceil(10/3) = 4 snapshots of rounds {0, 3, 6, 9}, equal to the dense
    # rows on the same cadence (the recorder must not perturb the run)
    assert np.asarray(strided.lam).shape == (4, _N)
    np.testing.assert_array_equal(np.asarray(strided.lam),
                                  np.asarray(dense.lam)[::3])
    off = run_simulation(_MODEL, replace(fl, record_lambda_every=0),
                         lam_data, seed=0)
    assert off.lam == ()
    # the O(T) summary leaves are always-on and cadence-independent
    for f in ("lam_max", "lam_entropy", "lam_ess"):
        np.testing.assert_array_equal(np.asarray(getattr(strided, f)),
                                      np.asarray(getattr(dense, f)))
        np.testing.assert_array_equal(np.asarray(getattr(off, f)),
                                      np.asarray(getattr(dense, f)))


def test_lambda_summary_leaves_match_posthoc(lam_data):
    # the per-round summary leaves equal a recompute from the dense rows
    hist = run_simulation(_MODEL, _fl(), lam_data, seed=0)
    lam = np.asarray(hist.lam)                                   # [T, N]
    np.testing.assert_allclose(np.asarray(hist.lam_max), lam.max(1),
                               rtol=1e-6)
    plogp = lam * np.log(np.where(lam > 0, lam, 1.0))
    np.testing.assert_allclose(np.asarray(hist.lam_entropy),
                               -plogp.sum(1), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(hist.lam_ess),
                               1.0 / (lam ** 2).sum(1), rtol=1e-5)


def test_record_lambda_every_rejects_negative():
    from repro.core.simulator import init_sim_state
    with pytest.raises(ValueError, match="record_lambda_every"):
        init_sim_state(_MODEL, _fl(record_lambda_every=-1),
                       jax.random.PRNGKey(0))


def test_summary_windows_recorded_lambda_rows(lam_data):
    """Satellite bugfix pin: the E=5 summary's λ columns equal the E=1
    summary computed on the subsampled recording cadence — never a tail
    window over round indices that don't exist in the strided history."""
    specs = [("e1", _fl()), ("e5", _fl(record_lambda_every=5))]
    res = run_sweep(_MODEL, lam_data, specs, seeds=(0, 1))
    s = res.summary(window=2)
    # recorded rows at E=5 over T=10: rounds {0, 5}; window=2 covers both
    lam1 = np.asarray(res.history("e1").lam)[:, ::5, :]
    lam5 = np.asarray(res.history("e5").lam)
    np.testing.assert_array_equal(lam5, lam1)
    la = lam5[:, -2:, :]
    np.testing.assert_allclose(s["e5"]["lam_max"],
                               la.max(-1).mean(1).mean(), rtol=1e-6)
    plogp = la * np.log(np.where(la > 0, la, 1.0))
    np.testing.assert_allclose(s["e5"]["lam_entropy"],
                               (-plogp.sum(-1)).mean(1).mean(), rtol=1e-5)
    # E=0 falls back to the per-round summary leaves, which are identical to
    # the dense cell's leaves — so its columns equal e1's computed per-round
    res0 = run_sweep(_MODEL, lam_data, [("e0", _fl(record_lambda_every=0))],
                     seeds=(0, 1))
    s0 = res0.summary(window=2)
    h1 = res.history("e1")
    np.testing.assert_allclose(
        s0["e0"]["lam_max"],
        np.asarray(h1.lam_max)[:, -2:].mean(1).mean(), rtol=1e-6)


def test_sweep_groups_by_record_cadence(lam_data):
    # record_lambda_every is STRUCTURAL: different cadences cannot share a
    # compiled executable (different history pytrees), same cadences must
    from repro.core import sweep as sweep_mod
    specs = [("a", _fl()), ("b", _fl(record_lambda_every=2)),
             ("c", replace(_fl(), lr0=0.2))]
    sweep_mod.reset_trace_log()
    run_sweep(_MODEL, lam_data, specs, seeds=(0,))
    assert sweep_mod.trace_count() == 2  # {a, c} share; b compiles alone
