"""Deep model-correctness tests: decode==forward, chunk invariance, rolling
windows, MoE routing semantics, SSM recurrence equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import dense, encdec, hybrid, moe, ssm, vlm, xlstm


def toks(key, cfg, b=2, s=12):
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------


def test_dense_chunk_invariance(key):
    cfg = get_reduced("qwen2-0.5b").with_(dtype="float32")
    p = dense.init(cfg, key)
    t = toks(key, cfg, 2, 16)
    full = dense.forward(cfg, p, t, chunk=None)
    for chunk in (4, 8, 16):
        np.testing.assert_allclose(
            dense.forward(cfg, p, t, chunk=chunk), full, rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_dense_decode_matches_forward(key):
    cfg = get_reduced("qwen2-0.5b").with_(dtype="float32")
    p = dense.init(cfg, key)
    b, s = 2, 12
    t = toks(key, cfg, b, s)
    full = dense.forward(cfg, p, t, chunk=None)
    # sequential decode from scratch
    cache = dense.init_cache(cfg, b, s)
    outs = []
    for i in range(s):
        lg, cache = dense.decode_step(cfg, p, cache, t[:, i],
                                      jnp.asarray(i, jnp.int32))
        outs.append(lg)
    seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(seq, full, rtol=5e-4, atol=5e-4)


@pytest.mark.slow
def test_dense_rolling_cache_matches_windowed_forward(key):
    cfg = get_reduced("qwen2-0.5b").with_(
        dtype="float32", window=8, long_context_threshold=8)
    p = dense.init(cfg, key)
    b, s = 2, 20
    t = toks(key, cfg, b, s)
    ref = dense.forward(cfg, p, t, chunk=None, window=8)
    cache = dense.init_cache(cfg, b, 1000)  # rolling, len 8
    assert cache["k"].shape[2] == 8
    for i in range(s):
        lg, cache = dense.decode_step(cfg, p, cache, t[:, i],
                                      jnp.asarray(i, jnp.int32))
    np.testing.assert_allclose(lg, ref[:, -1], rtol=5e-4, atol=5e-4)


def test_dense_qkv_bias_used(key):
    cfg = get_reduced("qwen2-0.5b").with_(dtype="float32")
    assert cfg.qkv_bias  # qwen2 has QKV bias per the assignment
    p = dense.init(cfg, key)
    t = toks(key, cfg)
    base = dense.forward(cfg, p, t)
    p["layers"]["bq"] = p["layers"]["bq"] + 1.0
    assert bool(jnp.any(jnp.abs(dense.forward(cfg, p, t) - base) > 1e-4))


def test_vocab_padding_masked(key):
    cfg = get_reduced("qwen2-0.5b").with_(dtype="float32", vocab_size=500)
    p = dense.init(cfg, key)
    logits = dense.forward(cfg, p, toks(key, cfg))
    assert logits.shape[-1] == 512  # padded to VOCAB_PAD multiple
    assert float(jnp.max(logits[..., 500:])) < -1e29  # padded ids masked


# ---------------------------------------------------------------------------
# moe
# ---------------------------------------------------------------------------


def test_moe_capacity_drops_are_bounded(key):
    """With cf=E/k (no drops possible), forward == decode path exactly."""
    cfg = get_reduced("qwen3-moe-30b-a3b").with_(
        dtype="float32", moe_capacity_factor=8.0)
    p = moe.init(cfg, key)
    b, s = 2, 16
    t = toks(key, cfg, b, s)
    logits, aux = moe.forward(cfg, p, t)
    assert jnp.isfinite(aux)
    lgp, c2 = moe.prefill(cfg, p, t[:, :s - 1], chunk=None)
    cache2 = {k_: jnp.pad(v, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
              for k_, v in c2.items()}
    lg3, _ = moe.decode_step(cfg, p, cache2, t[:, s - 1],
                             jnp.asarray(s - 1, jnp.int32))
    np.testing.assert_allclose(lg3, logits[:, -1], rtol=5e-4, atol=5e-4)


def test_moe_router_gradient_flows(key):
    cfg = get_reduced("qwen3-moe-30b-a3b").with_(dtype="float32")
    p = moe.init(cfg, key)
    t = toks(key, cfg)
    g = jax.grad(lambda pp: moe.loss_fn(cfg, pp, {"tokens": t, "labels": t}))(p)
    rnorm = float(jnp.linalg.norm(g["layers"]["router"]))
    assert rnorm > 0 and np.isfinite(rnorm)


def test_moe_aux_loss_balances(key):
    """Aux loss attains its minimum value 1 for perfectly uniform routing:
    aux = E * sum_e(me_e * ce_e) with me = ce = 1/E -> E * E * 1/E^2 = 1."""
    cfg = get_reduced("qwen3-moe-30b-a3b").with_(dtype="float32")
    probs = jnp.full((2, 8, cfg.num_experts), 1.0 / cfg.num_experts)
    me = probs.mean((0, 1))
    assert np.isclose(float(cfg.num_experts * (me * me).sum()), 1.0)


def test_moe_dispatch_indices_exact(key):
    cfg = get_reduced("qwen3-moe-30b-a3b")
    idx = jnp.array([[0, 1], [1, 2], [0, 3], [1, 1]])  # [S=4, k=2]
    slots, valid = moe._dispatch_indices(cfg, idx, cap=3)
    # expert 0 gets assignments {0 (tok0 slot0), 4 (tok2 slot0)}
    got_e0 = sorted(np.asarray(slots[0])[np.asarray(valid[0])].tolist())
    assert got_e0 == [0, 4]
    got_e1 = sorted(np.asarray(slots[1])[np.asarray(valid[1])].tolist())
    assert got_e1 == [1, 2, 6]  # three assignments, cap 3, none dropped


# ---------------------------------------------------------------------------
# ssm / xlstm / hybrid
# ---------------------------------------------------------------------------


def test_ssd_chunk_invariance_and_decode(key):
    cfg = get_reduced("zamba2-1.2b").with_(dtype="float32")
    bp = ssm.block_init(cfg, key)
    x = jax.random.normal(key, (2, 24, cfg.d_model))
    y, cache = ssm.block_forward(cfg, bp, x)
    y2, _ = ssm.block_forward(cfg.with_(ssm_chunk=5), bp, x)
    np.testing.assert_allclose(y, y2, rtol=1e-4, atol=1e-4)
    c = ssm.init_block_cache(cfg, 2)
    outs = []
    for t in range(24):
        o, c = ssm.block_step(cfg, bp, x[:, t:t + 1], c)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), y,
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(cache.state, c.state, rtol=5e-4, atol=5e-4)


def test_ssd_state_decay(key):
    """With large dt*|a|, the state forgets the past (selectivity)."""
    cfg = get_reduced("zamba2-1.2b").with_(dtype="float32")
    bp = ssm.block_init(cfg, key)
    bp["A_log"] = jnp.full_like(bp["A_log"], 5.0)   # a = -e^5: fast decay
    bp["dt_bias"] = jnp.full_like(bp["dt_bias"], 5.0)
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    _, cache1 = ssm.block_forward(cfg, bp, x)
    x2 = x.at[:, 0].set(100.0)  # perturb the distant past
    _, cache2 = ssm.block_forward(cfg, bp, x2)
    # state barely remembers position 0
    rel = float(jnp.linalg.norm(cache1.state - cache2.state)
                / (jnp.linalg.norm(cache1.state) + 1e-9))
    assert rel < 0.2


@pytest.mark.slow
def test_xlstm_chunk_invariance_and_decode(key):
    cfg = get_reduced("xlstm-1.3b").with_(dtype="float32")
    p = xlstm.init(cfg, key)
    t = toks(key, cfg, 2, 12)
    logits = xlstm.forward(cfg, p, t)
    l2 = xlstm.forward(cfg.with_(ssm_chunk=3), p, t)
    np.testing.assert_allclose(logits, l2, rtol=2e-4, atol=2e-4)
    lg, cache = xlstm.prefill(cfg, p, t[:, :11])
    lg2, _ = xlstm.decode_step(cfg, p, cache, t[:, 11],
                               jnp.asarray(11, jnp.int32))
    np.testing.assert_allclose(lg2, logits[:, -1], rtol=5e-4, atol=5e-4)


def test_xlstm_no_nan_long_sequence(key):
    """exp input gates stay finite over 200 steps (stabilization check)."""
    cfg = get_reduced("xlstm-1.3b").with_(dtype="float32")
    p = xlstm.init(cfg, key)
    t = jax.random.randint(key, (1, 200), 0, cfg.vocab_size)
    logits = xlstm.forward(cfg, p, t)
    assert not bool(jnp.isnan(logits).any())


def test_hybrid_decode_and_shared_params(key):
    cfg = get_reduced("zamba2-1.2b").with_(dtype="float32", remat=False)
    p = hybrid.init(cfg, key)
    # ONE shared attention block: params have no stacked site axis
    assert p["shared_attn"]["wq"].ndim == 4
    t = toks(key, cfg, 2, 12)
    logits = hybrid.forward(cfg, p, t)
    lg, cache = hybrid.prefill(cfg, p, t[:, :11], chunk=None)
    cache = hybrid.HybridCache(
        mamba=cache.mamba,
        k=jnp.pad(cache.k, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))),
        v=jnp.pad(cache.v, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))))
    lg2, _ = hybrid.decode_step(cfg, p, cache, t[:, 11],
                                jnp.asarray(11, jnp.int32))
    np.testing.assert_allclose(lg2, logits[:, -1], rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# vlm / encdec
# ---------------------------------------------------------------------------


def test_vlm_gates_zero_init_is_pure_lm(key):
    cfg = get_reduced("llama-3.2-vision-11b").with_(dtype="float32",
                                                    remat=False)
    p = vlm.init(cfg, key)
    t = toks(key, cfg)
    img1 = jax.random.normal(key, (2, cfg.num_image_tokens, cfg.d_model))
    l1 = vlm.forward(cfg, p, t, img1)
    l2 = vlm.forward(cfg, p, t, img1 * 0)
    np.testing.assert_allclose(l1, l2, atol=1e-5)  # tanh(0) gates


def test_vlm_images_attend_after_gate_open(key):
    cfg = get_reduced("llama-3.2-vision-11b").with_(dtype="float32",
                                                    remat=False)
    p = vlm.init(cfg, key)
    p["cross_layers"]["gate_attn"] = jnp.full_like(
        p["cross_layers"]["gate_attn"], 1.0)
    t = toks(key, cfg)
    img = jax.random.normal(key, (2, cfg.num_image_tokens, cfg.d_model))
    assert bool(jnp.any(jnp.abs(
        vlm.forward(cfg, p, t, img) - vlm.forward(cfg, p, t, img * 0)) > 1e-4))


def test_encdec_decode_matches_forward(key):
    cfg = get_reduced("seamless-m4t-medium").with_(dtype="float32",
                                                   remat=False)
    p = encdec.init(cfg, key)
    b, s = 2, 12
    t = toks(key, cfg, b, s)
    audio = jax.random.normal(key, (b, cfg.num_audio_frames, cfg.d_model))
    logits = encdec.forward(cfg, p, t, audio)
    lg, cache = encdec.prefill(cfg, p, t[:, :s - 1], audio, chunk=None)
    cache = encdec.EncDecCache(
        k=jnp.pad(cache.k, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))),
        v=jnp.pad(cache.v, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))),
        mk=cache.mk, mv=cache.mv)
    lg2, _ = encdec.decode_step(cfg, p, cache, t[:, s - 1],
                                jnp.asarray(s - 1, jnp.int32))
    np.testing.assert_allclose(lg2, logits[:, -1], rtol=5e-4, atol=5e-4)


def test_encdec_encoder_bidirectional(key):
    """Future audio frames influence early decoder positions (non-causal)."""
    cfg = get_reduced("seamless-m4t-medium").with_(dtype="float32",
                                                   remat=False)
    p = encdec.init(cfg, key)
    t = toks(key, cfg, 1, 6)
    audio = jax.random.normal(key, (1, cfg.num_audio_frames, cfg.d_model))
    l1 = encdec.forward(cfg, p, t, audio)
    audio2 = audio.at[:, -1].add(10.0)  # perturb the LAST frame
    l2 = encdec.forward(cfg, p, t, audio2)
    assert bool(jnp.any(jnp.abs(l2[:, 0] - l1[:, 0]) > 1e-5))
