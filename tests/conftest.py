"""Shared pytest fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; only launch/dryrun.py forces 512 host devices."""
import importlib.util
import sys
from pathlib import Path

# Prefer the real `hypothesis` (pinned in pyproject, installed in CI); fall
# back to the deterministic shim in tests/_stubs for hermetic environments
# where it cannot be installed, so the suite runs instead of failing collection.
if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, str(Path(__file__).resolve().parent / "_stubs"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
