"""Shared pytest fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; only launch/dryrun.py forces 512 host devices."""
import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
