"""Production FL tier: weighted-loss aggregation semantics, microbatching,
AWGN, server loop, partitioners."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import FLConfig
from repro.data.synthetic import make_lm_tokens
from repro.federated import (ParameterServer, client_weights, make_fl_round,
                             per_client_losses, sorted_label_shards)
from repro.models.api import build_model
from repro.optim import sgd


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("qwen2-0.5b").with_(dtype="float32", remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _fl_batch(cfg, key, n_clients=4, per_client=2, s=16):
    b = n_clients * per_client
    return {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "client_ids": jnp.repeat(jnp.arange(n_clients), per_client),
    }


def test_client_weights_scaling():
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    cids = jnp.array([0, 0, 1, 2, 3, 3])
    w = client_weights(mask, cids, k=2.0)
    np.testing.assert_allclose(w, [2, 2, 0, 2, 0, 0])  # N/K = 2


def test_selection_mask_gates_gradient(small_model, key):
    """Unselected clients contribute NOTHING to the aggregated update."""
    cfg, model, params = small_model
    opt = sgd(0.1)
    batch = _fl_batch(cfg, key)
    rnd = jax.jit(make_fl_round(model, opt, 4, 2))
    mask_a = jnp.array([1.0, 1.0, 0.0, 0.0])
    p_a, _, _ = rnd(params, opt.init(params), batch, mask_a, key)
    # perturb an UNSELECTED client's data: update must not change
    batch2 = dict(batch)
    batch2["tokens"] = batch["tokens"].at[4:].set(0)  # clients 2,3 rows
    p_b, _, _ = rnd(params, opt.init(params), batch2, mask_a, key)
    for a, b in zip(jax.tree_util.tree_leaves(p_a),
                    jax.tree_util.tree_leaves(p_b), strict=True):
        np.testing.assert_allclose(a, b, atol=1e-7)


def test_microbatch_equivalence(small_model, key):
    cfg, model, params = small_model
    opt = sgd(0.1)
    batch = _fl_batch(cfg, key, n_clients=4, per_client=2)
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    r1 = jax.jit(make_fl_round(model, opt, 4, 2, microbatches=1))
    r4 = jax.jit(make_fl_round(model, opt, 4, 2, microbatches=4))
    p1, _, m1 = r1(params, opt.init(params), batch, mask, key)
    p4, _, m4 = r4(params, opt.init(params), batch, mask, key)
    np.testing.assert_allclose(m1.loss, m4.loss, rtol=1e-5)
    np.testing.assert_allclose(m1.client_losses, m4.client_losses, rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4), strict=True):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=2e-3)


def test_awgn_statistics(small_model, key):
    from repro.federated.rounds import add_awgn
    grads = {"big": jnp.zeros((16, 64, 64)), "small": jnp.zeros((7,))}
    noisy = add_awgn(grads, key, std=0.5)
    assert abs(float(jnp.std(noisy["big"])) - 0.5) < 0.02
    # scan path and direct path both seeded deterministically
    noisy2 = add_awgn(grads, key, std=0.5)
    np.testing.assert_allclose(noisy["big"], noisy2["big"])


def test_per_client_losses_segment_mean(small_model, key):
    cfg, model, params = small_model
    batch = _fl_batch(cfg, key, n_clients=4, per_client=2)
    losses = per_client_losses(model, params, batch, 4)
    assert losses.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(losses)))
    # microbatched probe identical
    losses2 = per_client_losses(model, params, batch, 4, microbatches=2)
    np.testing.assert_allclose(losses, losses2, rtol=1e-5)


def test_server_loop_energy_and_lambda(small_model, key):
    cfg, model, _ = small_model
    fl = FLConfig(num_clients=4, clients_per_round=2, rounds=4,
                  method="ca_afl", energy_C=8.0, noise_std=0.0)
    ps = ParameterServer(model, sgd(0.05), fl, seed=0)
    state = ps.init_state(key)

    def batches():
        k = key
        while True:
            k = jax.random.fold_in(k, 1)
            yield _fl_batch(cfg, k)

    state = ps.run(state, batches(), rounds=4, log_fn=None)
    assert state.round == 4
    assert state.energy_joules > 0
    np.testing.assert_allclose(float(jnp.sum(state.lam)), 1.0, atol=1e-4)
    assert len(state.history) == 4
    assert all(np.isfinite(h["loss"]) for h in state.history)


def test_greedy_uses_less_energy_than_fedavg(small_model, key):
    """The Prop. 2 limit is the energy-optimal selection."""
    cfg, model, _ = small_model
    res = {}
    for method in ("greedy", "fedavg"):
        fl = FLConfig(num_clients=8, clients_per_round=3, rounds=6,
                      method=method, noise_std=0.0)
        ps = ParameterServer(model, sgd(0.01), fl, seed=1)
        state = ps.init_state(key)

        def batches():
            k = key
            while True:
                k = jax.random.fold_in(k, 2)
                yield _fl_batch(cfg, k, n_clients=8, per_client=1)

        res[method] = ps.run(state, batches(), rounds=6,
                             log_fn=None).energy_joules
    assert res["greedy"] < res["fedavg"]


def test_sorted_label_shards_heterogeneity():
    x = np.arange(100, dtype=np.float32)[:, None]
    y = np.repeat(np.arange(10), 10).astype(np.int32)
    rng = np.random.default_rng(0)
    perm = rng.permutation(100)
    xs, ys = sorted_label_shards(x[perm], y[perm], 10)
    assert xs.shape == (10, 10, 1)
    # each client sees exactly one label (maximal heterogeneity)
    for c in range(10):
        assert len(np.unique(ys[c])) == 1


def test_make_lm_tokens_heterogeneity():
    c = make_lm_tokens(4, 2000, vocab_size=100, heterogeneity=1.0, seed=0)
    assert c.shape == (4, 2000)
    # client unigram distributions differ strongly
    h0 = np.bincount(c[0], minlength=100) / 2000
    h1 = np.bincount(c[1], minlength=100) / 2000
    assert 0.5 * np.abs(h0 - h1).sum() > 0.3  # total variation
