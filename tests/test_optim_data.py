"""Optimizer, schedule, checkpoint and data-substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.data.pipeline import ClientDataset, client_batch_iterator
from repro.data.synthetic import make_fmnist_like
from repro.optim import (adamw, apply_updates, chain, clip_by_global_norm,
                         cosine_decay, exponential_decay, sgd)
from repro.utils.tree import tree_l2_norm, tree_ravel, tree_size, tree_unravel


def _quadratic(opt, steps=200, lr_note=""):
    """Minimize ||x - c||^2; return final distance."""
    c = jnp.array([3.0, -2.0, 1.0])
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(lambda p: jnp.sum((p["x"] - c) ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(jnp.linalg.norm(params["x"] - c))


def test_sgd_converges():
    assert _quadratic(sgd(0.1)) < 1e-3


def test_sgd_momentum_converges():
    assert _quadratic(sgd(0.05, momentum=0.9)) < 1e-3


def test_adamw_converges():
    assert _quadratic(adamw(0.1)) < 1e-2


def test_adamw_weight_decay_shrinks():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"x": jnp.ones(3) * 10}
    state = opt.init(params)
    for _ in range(50):
        g = {"x": jnp.zeros(3)}  # zero gradient: only decay acts
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["x"]).max()) < 1.0


def test_clip_by_global_norm():
    opt = chain(clip_by_global_norm(1.0), sgd(1.0))
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    g = {"x": jnp.full(4, 100.0)}
    upd, _ = opt.update(g, state, params)
    assert abs(float(tree_l2_norm(upd)) - 1.0) < 1e-4


def test_exponential_decay_matches_paper():
    sch = exponential_decay(0.1, 0.998)
    np.testing.assert_allclose(float(sch(0)), 0.1)
    np.testing.assert_allclose(float(sch(500)), 0.1 * 0.998 ** 500, rtol=1e-4)


def test_cosine_decay_endpoints():
    sch = cosine_decay(1.0, 100)
    np.testing.assert_allclose(float(sch(0)), 1.0, atol=1e-6)
    np.testing.assert_allclose(float(sch(100)), 0.0, atol=1e-6)


@given(st.lists(st.integers(1, 7), min_size=1, max_size=4))
@settings(max_examples=30, deadline=None)
def test_tree_ravel_roundtrip(dims):
    key = jax.random.PRNGKey(sum(dims))
    tree = {"a": jax.random.normal(key, tuple(dims)),
            "b": {"c": jnp.arange(5, dtype=jnp.float32)}}
    vec = tree_ravel(tree)
    assert vec.shape == (tree_size(tree),)
    back = tree_unravel(tree, vec)
    np.testing.assert_allclose(back["a"], tree["a"], rtol=1e-6)
    np.testing.assert_allclose(back["b"]["c"], tree["b"]["c"])


def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"w": jax.random.normal(key, (4, 5)),
            "opt": {"step": jnp.asarray(7, jnp.int32)}}
    path = save_checkpoint(str(tmp_path), 7, tree)
    assert os.path.exists(path)
    restored = restore_checkpoint(str(tmp_path), tree)
    np.testing.assert_allclose(restored["w"], tree["w"], rtol=1e-7)
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_restore_is_writeable_and_donatable(tmp_path, key):
    """Regression (ISSUE 4): ``restore_checkpoint`` used to hand back
    read-only ``np.frombuffer`` views — in-place mutation raised and
    donating them to a jitted update step aliased unowned storage."""
    tree = {"w": jax.random.normal(key, (4, 5)),
            "step": jnp.asarray(7, jnp.int32)}
    save_checkpoint(str(tmp_path), 1, tree)
    restored = restore_checkpoint(str(tmp_path), tree)
    # mutate in place: the read-only view raised ValueError here
    restored["w"][0, 0] = 42.0
    assert restored["w"][0, 0] == 42.0
    # donate into a jitted step: must neither raise nor corrupt the result
    restored = restore_checkpoint(str(tmp_path), tree)
    bumped = jax.jit(
        lambda t: jax.tree.map(lambda x: x + 1, t), donate_argnums=0
    )(restored)
    np.testing.assert_allclose(np.asarray(bumped["w"]),
                               np.asarray(tree["w"]) + 1, rtol=1e-6)


def test_checkpoint_restore_validates_dtype(tmp_path, key):
    """A template whose dtype disagrees with the stored bytes must raise —
    the old code reinterpreted/absorbed the bytes silently."""
    tree = {"w": jax.random.normal(key, (4, 5))}  # f32 on disk
    save_checkpoint(str(tmp_path), 1, tree)
    bad_template = {"w": np.zeros((4, 5), np.float64)}
    try:
        restore_checkpoint(str(tmp_path), bad_template)
        raise AssertionError("dtype mismatch not detected")
    except ValueError as e:
        assert "dtype mismatch" in str(e)


def test_checkpoint_restore_accepts_scalar_template(tmp_path):
    """Dtype-less Python-scalar template leaves carry no width intent and
    must keep restoring (NumPy would infer int64/float64 for them)."""
    save_checkpoint(str(tmp_path), 1, {"step": jnp.asarray(7, jnp.int32)})
    restored = restore_checkpoint(str(tmp_path), {"step": 0})
    assert int(restored["step"]) == 7


def test_checkpoint_retention(tmp_path, key):
    tree = {"w": jnp.zeros(2)}
    for step in range(6):
        save_checkpoint(str(tmp_path), step, tree, keep=3)
    kept = sorted(os.listdir(tmp_path))
    assert len(kept) == 3


def test_synthetic_data_learnable_and_asymmetric():
    x, y, xt, yt = make_fmnist_like(num_train=3000, num_test=600, dim=64,
                                    seed=1)
    assert x.shape == (3000, 64) and y.shape == (3000,)
    assert set(np.unique(y)) == set(range(10))
    # linear probe beats chance comfortably (structure present)
    from repro.models.logreg import logistic_regression
    m = logistic_regression(64, 10)
    p = m.init(jax.random.PRNGKey(0))
    for _ in range(300):
        g = jax.grad(m.loss)(p, jnp.asarray(x), jnp.asarray(y))
        p = jax.tree.map(lambda a, b: a - 0.5 * b, p, g)
    acc = float(m.accuracy(p, jnp.asarray(xt), jnp.asarray(yt)))
    assert acc > 0.55
    # class difficulty is asymmetric (what DRO exploits)
    per_class = [float(m.accuracy(p, jnp.asarray(xt[yt == c]),
                                  jnp.asarray(yt[yt == c])))
                 for c in range(10)]
    assert max(per_class) - min(per_class) > 0.1


def test_client_batch_iterator_deterministic():
    ds = ClientDataset(x=np.arange(20)[:, None].astype(np.float32),
                       y=np.arange(20).astype(np.int32))
    it1 = client_batch_iterator(ds, 4, seed=3)
    it2 = client_batch_iterator(ds, 4, seed=3)
    for _ in range(5):
        a, b = next(it1), next(it2)
        np.testing.assert_array_equal(a[0], b[0])
