"""Uplink-transport layer suite (``repro.core.transport``).

Property tests (``property`` marker): the stochastic-rounding quantizer is
unbiased with error variance within the Δ²/4 bound; digital-OFDMA upload
energy is monotone in the payload bits and decreasing in SNR; the analog
deep-fade guard keeps an exactly-zero channel draw finite.

Differential pins: ``transport="analog"`` is bit-identical to the
pre-transport program across all 5 selection methods (its output is a
constant function of every transport knob, and the transport dispatch
delegates to the exact pre-existing calls); quantized at bits=32 matches
analog to f32 eps with the identical AWGN realization; digital aggregation
is the masked weighted mean with zero superposition noise; the sparse-K and
population-sharded paths equal the dense reference for every transport ×
{default, markov_fading, battery_constrained}; and a four-transport sweep
compiles one executable per scheme with every knob traced (the
error-feedback ``sparse`` scheme's invariants get their own suite,
``tests/test_sparse_transport.py``).
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import sharding, sweep, transport
from repro.core.aircomp import aircomp_aggregate_tree
from repro.core.channel import SCENARIOS
from repro.core.energy import round_energy, transmit_energy
from repro.core.simulator import run_simulation
from repro.core.transport import (TransportParams, digital_energy,
                                  digital_latency, quant_step, quantize_rows,
                                  transport_from_config, uplink_energy)
from repro.data.synthetic import make_fmnist_like
from repro.federated.partition import sorted_label_shards
from repro.kernels.aircomp.ops import quant_aircomp_flat
from repro.models.logreg import logistic_regression

N, DIM = 12, 32
MODEL = logistic_regression(dim=DIM, num_classes=10)
METHODS = ("fedavg", "afl", "ca_afl", "greedy", "gca")


@pytest.fixture(scope="module")
def tdata():
    x, y, xt, yt = make_fmnist_like(num_train=600, num_test=240, dim=DIM,
                                    seed=0)
    xs, ys = sorted_label_shards(x, y, N)
    xts, yts = sorted_label_shards(xt, yt, N)
    return xs, ys, xts, yts


def _fl(method="ca_afl", rounds=6, **kw):
    return FLConfig(num_clients=N, clients_per_round=5, rounds=rounds,
                    batch_size=16, method=method, lr0=0.3, lr_decay=0.995,
                    ascent_lr=2e-2, **kw)


def _hist_equal(a, b, msg="", **tol):
    for name in a._fields:
        if tol:
            np.testing.assert_allclose(
                np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
                err_msg=f"{msg}:{name}", **tol)
        else:
            np.testing.assert_array_equal(
                np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
                err_msg=f"{msg}:{name}")


# ---------------------------------------------------------------------------
# Quantizer properties: unbiasedness and the Δ²/4 variance bound
# ---------------------------------------------------------------------------


@pytest.mark.property
def test_quantizer_unbiased():
    """E[Q(x)] = x under stochastic rounding: the empirical mean over many
    independent rounding draws converges to the input at the CLT rate."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (3, 64))
    bits = 4.0
    trials = 4096
    cids = jnp.arange(3)

    def one(k):
        q, _ = quantize_rows(x, cids, k, bits)
        return q

    qs = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(1), trials))
    step = np.asarray(quant_step(x, bits))           # [3]
    err = np.asarray(qs.mean(0)) - np.asarray(x)     # [3, 64]
    # CLT: |mean error| <~ 4 * sqrt(Δ²/4 / trials) per coordinate
    bound = 4.0 * step[:, None] / 2.0 / np.sqrt(trials)
    assert (np.abs(err) <= bound).mean() > 0.99
    assert np.abs(err).max() <= 8.0 * step.max() / 2.0 / np.sqrt(trials)


@pytest.mark.property
def test_quantizer_variance_bound():
    """Var[Q(x)] = Δ²·p(1−p) ≤ Δ²/4 per coordinate (stochastic rounding on a
    Δ-grid); the empirical variance stays within the bound plus CLT slack."""
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 48)) * 3.0
    bits = 3.0
    trials = 4096
    cids = jnp.arange(2)
    qs = jax.vmap(lambda k: quantize_rows(x, cids, k, bits)[0])(
        jax.random.split(jax.random.PRNGKey(3), trials))
    step = np.asarray(quant_step(x, bits))
    var = np.asarray(qs).var(axis=0)                 # [2, 48]
    bound = (step[:, None] ** 2) / 4.0
    assert (var <= bound * 1.15).all()


@pytest.mark.property
def test_quantizer_error_bounded_and_zero_rows_exact():
    """Every realization lands on one of the two neighbouring grid points
    (|Q(x) − x| < Δ always), and an all-zero payload row passes through
    exactly (Δ = 0 disables the grid)."""
    bits = 4.0
    rows = jnp.stack([jnp.linspace(-1.0, 1.0, 16), jnp.zeros((16,))])
    step = quant_step(rows, bits)
    assert float(step[1]) == 0.0
    for trial in range(8):
        q, _ = quantize_rows(rows, jnp.arange(2), jax.random.PRNGKey(trial),
                             bits)
        assert np.abs(np.asarray(q[0]) - np.asarray(rows[0])).max() \
            < float(step[0])
        np.testing.assert_array_equal(np.asarray(q[1]), np.zeros((16,)))


# ---------------------------------------------------------------------------
# Digital energy properties + the analog deep-fade guard
# ---------------------------------------------------------------------------


@pytest.mark.property
def test_digital_energy_monotone_in_payload_and_snr():
    h = jnp.asarray([0.1, 0.5, 1.0, 2.5])
    tp = TransportParams(bits=8.0, tx_power=0.1, bandwidth=1e5, rx_noise=1e-2)
    e = np.asarray(digital_energy(h, 1000, tp))
    e2 = np.asarray(digital_energy(h, 2000, tp))
    assert (e2 > e).all()                        # monotone in model bits M·32
    np.testing.assert_allclose(e2, 2.0 * e, rtol=1e-6)   # airtime is linear
    assert (np.diff(e) < 0).all()                # decreasing in channel SNR
    e_less_noise = np.asarray(digital_energy(h, 1000,
                                             replace(tp, rx_noise=1e-3)))
    assert (e_less_noise < e).all()              # decreasing in SNR, N0 axis
    lat = np.asarray(digital_latency(h, 1000, tp))
    np.testing.assert_allclose(e, 0.1 * lat, rtol=1e-6)  # E = P · t
    # `bits` is the QUANTIZED scheme's knob: the digital PS decodes the full
    # f32 payload, so its bill must not shrink with bits (the free-lunch
    # regression — a b-bit price for a 32-bit delivery would make digital
    # cells dominate every Pareto comparison they appear in)
    np.testing.assert_array_equal(
        e, np.asarray(digital_energy(h, 1000, replace(tp, bits=1.0))))


@pytest.mark.property
def test_digital_energy_zero_knobs_stay_finite():
    """Regression: tx_power=0 gave rate 0 → 0·inf = NaN energy (and
    bandwidth=0 gave inf), poisoning the ledger and battery gating for all
    clients. The rate floor keeps degenerate traced knobs finite."""
    h = jnp.asarray([0.05, 1.0])
    tp = TransportParams(tx_power=0.0, bandwidth=1e5, rx_noise=1e-2)
    assert np.isfinite(np.asarray(digital_energy(h, 1000, tp))).all()
    tp = TransportParams(tx_power=0.1, bandwidth=0.0, rx_noise=1e-2)
    e = np.asarray(digital_energy(h, 1000, tp))
    assert np.isfinite(e).all() and (e > 0).all()


@pytest.mark.property
def test_digital_energy_zero_rx_noise_not_free():
    """Regression: rx_noise=0 made the Shannon SNR infinite, the rate
    infinite and the airtime zero — digital uploads billed at exactly 0 J,
    so digital cells dominated every Pareto front they appeared in. The
    noise clamp keeps the rate (hence the bill) finite and positive."""
    h = jnp.asarray([0.05, 1.0])
    tp = TransportParams(tx_power=0.1, bandwidth=1e5, rx_noise=0.0)
    e = np.asarray(digital_energy(h, 1000, tp))
    assert np.isfinite(e).all() and (e > 0).all()
    # a vanishing-but-positive noise must behave the same way (no knife edge)
    e_tiny = np.asarray(digital_energy(
        h, 1000, TransportParams(tx_power=0.1, bandwidth=1e5,
                                 rx_noise=1e-30)))
    assert np.isfinite(e_tiny).all() and (e_tiny > 0).all()


@pytest.mark.property
def test_quant_step_degenerate_bits_stay_finite(tdata):
    """Regression: bits=0 gave 2^0 − 1 = 0 grid levels → Δ = max|x|/0 = inf
    → NaN payloads after rounding. The level floor pins Δ finite on the
    whole degenerate edge, and a traced bits-grid sweep crossing 0/1 stays
    finite end-to-end (bits is a TRACED knob: one executable serves the
    grid, so one poisoned cell would share its program with healthy ones).
    The billed energy floors at the 1-bit payload — bits=0 must not upload
    for free."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 64))
    for bits in (0.0, 0.5, 1.0, 2.0):
        step = np.asarray(quant_step(x, bits))
        assert np.isfinite(step).all(), bits
        q, _ = quantize_rows(x, jnp.arange(3), jax.random.PRNGKey(1), bits)
        assert np.isfinite(np.asarray(q)).all(), bits
    fl = _fl("fedavg", rounds=3)
    specs = [(f"b{b}", replace(fl, transport="quantized", quant_bits=b))
             for b in (0.0, 1.0, 4.0, 32.0)]
    result = sweep.run_sweep(MODEL, tdata, specs, seeds=(3,))
    s = result.summary(window=2)
    for lbl in ("b0.0", "b1.0", "b4.0", "b32.0"):
        assert np.isfinite(s[lbl]["energy"]), lbl
        assert np.isfinite(s[lbl]["avg_acc"]), lbl
        assert s[lbl]["energy"] > 0.0, lbl
    # the bits=0 bill floors at exactly the 1-bit price
    np.testing.assert_allclose(s["b0.0"]["energy"], s["b1.0"]["energy"],
                               rtol=1e-6)
    assert s["b1.0"]["energy"] < s["b32.0"]["energy"]


@pytest.mark.property
def test_deep_fade_guard_zero_channel_draw():
    """Regression: an exactly-zero channel used to give inf/NaN upload energy
    (1/h²), poisoning battery depletion and greedy scores. Energy is now
    priced at max(h, floor) for every scheme."""
    h = jnp.asarray([0.0, 0.05, 1.0])
    e = np.asarray(transmit_energy(h, 7850, 0.5e-3, 1e-3))
    assert np.isfinite(e).all()
    assert e[0] == e[1]  # the zero draw prices exactly at the floor
    total = round_energy(h, jnp.ones((3,)), 7850, 0.5e-3, 1e-3)
    assert np.isfinite(float(total))
    scen = sweep.sweep_point_from_config(FLConfig()).scenario
    for scheme in transport.TRANSPORTS:
        tp = transport_from_config(replace(FLConfig(), transport=scheme))
        en = np.asarray(uplink_energy(scheme, tp, h, 7850, scen))
        assert np.isfinite(en).all(), scheme
    # a custom floor stays authoritative: clamping never overrides a LOWER
    # scenario floor (which would silently change that scenario's ledger)
    e_low = np.asarray(transmit_energy(jnp.asarray([0.01]), 100, 1.0, 1.0,
                                       floor=0.01))
    np.testing.assert_allclose(e_low, 1e6, rtol=1e-5)


# ---------------------------------------------------------------------------
# Fused quantize-aggregate kernel: Pallas (interpret) == jnp oracle
# ---------------------------------------------------------------------------


def test_quant_kernel_matches_reference():
    key = jax.random.PRNGKey(5)
    c, m = 7, 1536
    x = jax.random.normal(key, (c, m))
    w = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0])
    d = quant_step(x, 6.0)
    u = jax.random.uniform(jax.random.fold_in(key, 1), (c, m))
    z = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    ref = quant_aircomp_flat(x, w, d, u, z, noise_std=0.3, k=5.0,
                             use_pallas=False)
    pal = quant_aircomp_flat(x, w, d, u, z, noise_std=0.3, k=5.0,
                             use_pallas=True)  # interpret mode off-TPU
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    # traced scalars: no recompile across noise_std/k values
    f = jax.jit(lambda ns, k: quant_aircomp_flat(
        x, w, d, u, z, noise_std=ns, k=k, use_pallas=True))
    np.testing.assert_allclose(np.asarray(f(0.3, 5.0)), np.asarray(pal),
                               rtol=1e-6)
    f(0.1, 3.0)  # same executable, different scalars


def test_sparse_kernel_matches_reference():
    """The fused compress-aggregate kernel: Pallas (interpret) == jnp oracle,
    with traced noise_std/k scalars sharing one executable."""
    from repro.kernels.aircomp.ops import sparse_aircomp_flat
    from repro.core.transport import sparse_thresholds

    key = jax.random.PRNGKey(9)
    c, m = 7, 1536
    x = jax.random.normal(key, (c, m))
    w = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0])
    thr = sparse_thresholds(x, 77)
    z = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    ref = sparse_aircomp_flat(x, w, thr, z, noise_std=0.3, k=5.0,
                              use_pallas=False)
    pal = sparse_aircomp_flat(x, w, thr, z, noise_std=0.3, k=5.0,
                              use_pallas=True)  # interpret mode off-TPU
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    # an all-zero payload row thresholds at 0, keeps itself and adds zeros
    x0 = x.at[2].set(0.0)
    thr0 = sparse_thresholds(x0, 77)
    assert float(thr0[2]) == 0.0
    out0 = sparse_aircomp_flat(x0, w, thr0, z, noise_std=0.0, k=5.0,
                               use_pallas=True)
    assert np.isfinite(np.asarray(out0)).all()
    # traced scalars: no recompile across noise_std/k values
    f = jax.jit(lambda ns, k: sparse_aircomp_flat(
        x, w, thr, z, noise_std=ns, k=k, use_pallas=True))
    np.testing.assert_allclose(np.asarray(f(0.3, 5.0)), np.asarray(pal),
                               rtol=1e-6)
    f(0.1, 3.0)  # same executable, different scalars


# ---------------------------------------------------------------------------
# Differential pins: analog bit-identity, bits=32 ≈ analog, digital == mean
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS)
def test_analog_is_invariant_to_transport_knobs(tdata, method):
    """The pre-PR pin: the analog program's output is a CONSTANT function of
    every transport knob (the pre-transport FLConfig had none, so any
    dependence would mean the analog path no longer compiles the pre-PR
    program). Masks, λ, energy and trajectories: bit-for-bit."""
    base = run_simulation(MODEL, _fl(method), tdata, seed=3)
    tweaked = run_simulation(
        MODEL, _fl(method, quant_bits=3.0, tx_power=9.9, ofdma_bandwidth=1.0,
                   rx_noise=123.0, sparse_density=0.5), tdata, seed=3)
    _hist_equal(base, tweaked, msg=f"analog-knobs:{method}")


def test_quantized_bits32_matches_analog(tdata):
    """At bits=32 the rounding grid is below f32 resolution and the energy
    scale factor bits/32 is exactly 1, so the quantized transport reproduces
    analog to f32 eps — with the IDENTICAL AWGN realization (same per-leaf
    streams)."""
    fl = _fl("ca_afl", noise_std=1e-3)
    ha = run_simulation(MODEL, fl, tdata, seed=3)
    hq = run_simulation(MODEL, replace(fl, transport="quantized",
                                       quant_bits=32.0), tdata, seed=3)
    eps = float(np.finfo(np.float32).eps)
    _hist_equal(ha, hq, msg="q32", rtol=64 * eps, atol=64 * eps)


def test_quantized_energy_scales_with_bits(tdata):
    """Quantized airtime (hence the ledger) is exactly bits/32 of analog.
    FedAvg's uniform draw is λ- and energy-independent, so both transports
    schedule the identical sets and the ledgers are directly comparable."""
    fl = _fl("fedavg")
    ha = run_simulation(MODEL, fl, tdata, seed=3)
    hq = run_simulation(MODEL, replace(fl, transport="quantized",
                                       quant_bits=8.0), tdata, seed=3)
    np.testing.assert_array_equal(np.asarray(hq.num_scheduled),
                                  np.asarray(ha.num_scheduled))
    np.testing.assert_allclose(np.asarray(hq.energy),
                               np.asarray(ha.energy) * (8.0 / 32.0),
                               rtol=1e-6)


def test_digital_aggregation_is_masked_weighted_mean():
    """The digital PS decodes each payload exactly: the aggregate is the
    plain masked weighted mean with NO superposition noise, regardless of
    the scenario's noise_std."""
    key = jax.random.PRNGKey(6)
    stack = {"w": jax.random.normal(key, (N, 5, 3)),
             "b": jax.random.normal(jax.random.fold_in(key, 1), (N, 3))}
    mask = (jax.random.uniform(jax.random.fold_in(key, 2), (N,)) > 0.5
            ).astype(jnp.float32)
    k = jnp.maximum(jnp.sum(mask), 1.0)
    # the simulator's digital branch: analog aggregation with a STATIC zero
    # noise_std — the AWGN draw is structurally elided
    agg = aircomp_aggregate_tree(stack, mask, jax.random.fold_in(key, 3),
                                 0.0, k)
    for name in ("w", "b"):
        manual = jnp.einsum("n...,n->...", stack[name], mask) / k
        np.testing.assert_allclose(np.asarray(agg[name]), np.asarray(manual),
                                   rtol=1e-6, atol=1e-7)


def test_digital_trajectories_equal_analog_sans_energy(tdata):
    """On a noise-free static scenario the digital round computes the exact
    same update as analog (weighted mean, no AWGN on either) — only the
    energy ledger differs (OFDMA rate/latency vs channel inversion)."""
    fl = _fl("ca_afl")
    ha = run_simulation(MODEL, fl, tdata, seed=3)
    hd = run_simulation(MODEL, replace(fl, transport="digital"), tdata,
                        seed=3)
    for name in ha._fields:
        if name == "energy":
            continue
        np.testing.assert_array_equal(np.asarray(getattr(ha, name)),
                                      np.asarray(getattr(hd, name)),
                                      err_msg=name)
    assert not np.allclose(np.asarray(ha.energy), np.asarray(hd.energy))


# ---------------------------------------------------------------------------
# Sparse-K == dense reference for every transport × scenario family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", ("default", "markov_fading",
                                      "battery_constrained"))
@pytest.mark.parametrize("transport_name", ("quantized", "digital", "sparse"))
def test_sparse_matches_dense_per_transport(tdata, transport_name, scenario):
    """The hot-path contract holds per transport: the selected-K gather
    round equals the dense [N, model] reference (control plane exact, model
    trajectory to summation order — quantized rows are content-addressed by
    client id, so the K gathered rows round bit-identically to dense).
    Analog is covered by tests/test_hotpath.py."""
    fl = replace(_fl("ca_afl", transport=transport_name, quant_bits=6.0),
                 **SCENARIOS[scenario])
    got = run_simulation(MODEL, fl, tdata, seed=3)
    ref = run_simulation(MODEL, fl, tdata, seed=3, dense=True)
    np.testing.assert_array_equal(np.asarray(got.num_scheduled),
                                  np.asarray(ref.num_scheduled))
    _hist_equal(got, ref, msg=f"{transport_name}@{scenario}",
                rtol=1e-4, atol=1e-5)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="population sharding needs >1 device; CI sets "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8")
@pytest.mark.parametrize("scenario", ("default", "markov_fading",
                                      "battery_constrained"))
@pytest.mark.parametrize("transport_name",
                         ("analog", "quantized", "digital", "sparse"))
def test_sharded_matches_dense_per_transport(tdata, transport_name, scenario):
    """Population sharding per transport: client-mesh rounds equal the dense
    reference (psum == eq. (10); quantized streams addressed by GLOBAL id,
    so shard-local rows round identically to the dense program's)."""
    fl = replace(_fl("ca_afl", rounds=5, transport=transport_name,
                     quant_bits=6.0), **SCENARIOS[scenario])
    mesh = sharding.client_mesh(sharding.population_device_count(N))
    ref = run_simulation(MODEL, fl, tdata, seed=3, dense=True)
    got = run_simulation(MODEL, fl, tdata, seed=3, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got.num_scheduled),
                                  np.asarray(ref.num_scheduled))
    _hist_equal(got, ref, msg=f"shard:{transport_name}@{scenario}",
                rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Sweep integration: one compile per scheme, knobs traced
# ---------------------------------------------------------------------------


def test_sweep_compiles_one_executable_per_transport(tdata):
    """A four-transport grid is four compilation groups (the scheme is
    structural), while a bits/power/downlink sub-grid WITHIN a scheme rides
    the vmap axis of one executable; the analog cell equals run_simulation
    exactly."""
    fl = _fl("ca_afl", rounds=4)
    specs = [
        ("analog", fl),
        ("quantized_b4", replace(fl, transport="quantized", quant_bits=4.0)),
        ("quantized_b8", replace(fl, transport="quantized", quant_bits=8.0)),
        ("digital", replace(fl, transport="digital")),
        ("digital_hp", replace(fl, transport="digital", tx_power=0.5)),
        ("sparse", replace(fl, transport="sparse")),
        ("sparse_dl", replace(fl, transport="sparse", dl_rx_power=1e-4)),
    ]
    sweep.reset_trace_log()
    result = sweep.run_sweep(MODEL, tdata, specs, seeds=(3,))
    # analog + quantized + digital + sparse (dl_rx_power stays traced)
    assert sweep.trace_count() == 4
    ref = run_simulation(MODEL, fl, tdata, seed=3)
    got = jax.tree.map(lambda x: x[0], result.history("analog"))
    _hist_equal(got, ref, msg="sweep-analog")
    s = result.summary(window=2)
    assert s["quantized_b4"]["energy"] < s["analog"]["energy"]
    assert s["digital"]["energy"] > s["analog"]["energy"]
    # the sparse uplink uploads ~density of the payload: cheapest of all
    assert s["sparse"]["energy"] < s["quantized_b4"]["energy"]
    # the downlink ledger is additive-only: identical trajectories, larger
    # total energy, and the share is exactly the dl_energy column
    assert s["sparse_dl"]["dl_energy"] > 0.0
    assert s["sparse"]["dl_energy"] == 0.0
    np.testing.assert_allclose(
        s["sparse_dl"]["energy"] - s["sparse_dl"]["dl_energy"],
        s["sparse"]["energy"], rtol=1e-5)
    np.testing.assert_allclose(s["sparse_dl"]["avg_acc"],
                               s["sparse"]["avg_acc"], rtol=1e-6)
