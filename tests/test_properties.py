"""Property-test hardening of the core statistical invariants.

Runs under the real ``hypothesis`` (CI) or the deterministic shim in
``tests/_stubs`` (hermetic envs). The unmarked tests are the shim-backed
fast-lane subset (small ``max_examples``); the ``slow``-marked sweeps rerun
the same properties at nightly-lane depth.

Invariants:
  - λ stays on the probability simplex under ARBITRARY ascent inputs;
  - round energy is zero for the empty mask, non-negative, and monotone in
    the participant set (cumulative ledgers can never decrease);
  - exact-K selection masks have exactly K ones even under tied scores
    (regression for the old ``scores >= thresh`` over-selection);
  - Gumbel-top-K inclusion frequencies match the Plackett-Luce inclusion
    probabilities of the paper's Prop. 2 sampling law;
  - an unavailable client is NEVER scheduled, by any method.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.dro import lambda_ascent
from repro.core.energy import round_energy
from repro.core.selection import gumbel_topk_mask, select_clients, topk_mask

pytestmark = pytest.mark.property

FINITE = st.floats(min_value=-50.0, max_value=50.0, allow_nan=False)
POSITIVE = st.floats(min_value=0.05, max_value=10.0, allow_nan=False)


# ---------------------------------------------------------------------------
# λ simplex invariance
# ---------------------------------------------------------------------------


def _check_lambda_simplex(lam_raw, losses, mask_bits, gamma):
    lam = jnp.asarray(lam_raw)
    mask = jnp.asarray(mask_bits, jnp.float32)
    out = np.asarray(lambda_ascent(lam, jnp.asarray(losses), mask, gamma))
    assert np.all(out >= -1e-6)
    # f32 round-off in the projection scales with the pre-projection
    # magnitude (γ·loss can reach thousands here): tolerance follows suit
    scale = float(np.abs(np.asarray(lam_raw)).max()
                  + gamma * np.abs(np.asarray(losses)).max())
    np.testing.assert_allclose(out.sum(), 1.0,
                               atol=max(1e-4, 3e-7 * scale * len(out)))


@given(hnp.arrays(np.float32, (16,), elements=FINITE),
       hnp.arrays(np.float32, (16,), elements=FINITE),
       hnp.arrays(np.int32, (16,), elements=st.integers(0, 1)),
       st.floats(min_value=0.0, max_value=5.0))
@settings(max_examples=20, deadline=None)
def test_lambda_stays_on_simplex(lam_raw, losses, mask_bits, gamma):
    """Even from an off-simplex λ and adversarial (negative, huge) losses,
    one ascent step lands exactly back on the simplex."""
    _check_lambda_simplex(lam_raw, losses, mask_bits, gamma)


@pytest.mark.slow
@given(hnp.arrays(np.float32, st.integers(2, 200).map(lambda n: (n,)),
                  elements=FINITE),
       st.floats(min_value=0.0, max_value=100.0))
@settings(max_examples=300, deadline=None)
def test_lambda_stays_on_simplex_deep(losses, gamma):
    n = len(losses)
    rng = np.random.default_rng(n)
    lam = rng.normal(size=n).astype(np.float32)
    mask = (rng.random(n) > 0.5).astype(np.int32)
    _check_lambda_simplex(lam, losses, mask, gamma)


# ---------------------------------------------------------------------------
# Energy ledger monotonicity
# ---------------------------------------------------------------------------


def _check_energy(h, mask_bits):
    h = jnp.asarray(h)
    mask = jnp.asarray(mask_bits, jnp.float32)
    e_empty = float(round_energy(h, jnp.zeros_like(mask), 100, 1e-3, 1e-3))
    assert e_empty == 0.0
    e = float(round_energy(h, mask, 100, 1e-3, 1e-3))
    assert e >= 0.0
    # adding one more participant never decreases the round energy
    off = np.flatnonzero(np.asarray(mask_bits) == 0)
    if len(off):
        grown = mask.at[int(off[0])].set(1.0)
        assert float(round_energy(h, grown, 100, 1e-3, 1e-3)) >= e


@given(hnp.arrays(np.float32, (12,), elements=POSITIVE),
       hnp.arrays(np.int32, (12,), elements=st.integers(0, 1)))
@settings(max_examples=25, deadline=None)
def test_energy_zero_empty_and_monotone_in_mask(h, mask_bits):
    _check_energy(h, mask_bits)


@pytest.mark.slow
@given(hnp.arrays(np.float32, st.integers(2, 100).map(lambda n: (n,)),
                  elements=POSITIVE))
@settings(max_examples=300, deadline=None)
def test_energy_monotone_deep(h):
    rng = np.random.default_rng(len(h))
    _check_energy(h, (rng.random(len(h)) > 0.5).astype(np.int32))


# ---------------------------------------------------------------------------
# Exact-K selection under ties (regression: thresholding over-selected)
# ---------------------------------------------------------------------------


def test_topk_mask_exactly_k_with_tied_scores():
    """Quantized/floor-clipped channels tie; the mask must still be exact-K."""
    vals = jnp.array([1.0, 1.0, 1.0, 0.5, 0.25])
    assert int(topk_mask(vals, 2).sum()) == 2      # 3-way tie at the top
    assert int(topk_mask(jnp.full((7,), 0.05), 3).sum()) == 3  # all equal


def test_gumbel_topk_exactly_k_with_tied_neg_inf_logits():
    """-inf-masked logits produce tied -inf scores (gumbel cannot separate
    them); the old >=-threshold mask selected ALL of them."""
    logits = jnp.array([-jnp.inf, -jnp.inf, -jnp.inf, 0.0, 0.0])
    mask = gumbel_topk_mask(jax.random.PRNGKey(0), logits, 4)
    assert int(mask.sum()) == 4


@given(st.integers(1, 11), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_exact_k_for_all_methods(k, seed):
    key = jax.random.PRNGKey(seed)
    n = 12
    lam = jax.nn.softmax(jax.random.normal(key, (n,)))
    # quantized channels: heavy ties by construction
    h = jnp.round(jnp.exp(jax.random.normal(jax.random.fold_in(key, 1),
                                            (n,))) * 2) / 2 + 0.5
    for method in ("fedavg", "afl", "ca_afl", "greedy"):
        mask = select_clients(method, key, lam, h, k, C=4.0)
        assert int(mask.sum()) == k, (method, k)


# ---------------------------------------------------------------------------
# Gumbel-top-K == Plackett-Luce inclusion probabilities (Prop. 2 law)
# ---------------------------------------------------------------------------


def _pl_inclusion_top2(p):
    """P(i in top-2) under sequential renormalized sampling w/o replacement."""
    p = np.asarray(p, np.float64)
    first = p
    second = np.array([
        sum(p[j] * p[i] / (1.0 - p[j]) for j in range(len(p)) if j != i)
        for i in range(len(p))])
    return first + second


def _check_gumbel_matches_pl(logits, draws, tol):
    logits = jnp.asarray(logits)
    p = np.asarray(jax.nn.softmax(logits))
    keys = jax.random.split(jax.random.PRNGKey(0), draws)
    masks = jax.vmap(lambda k: gumbel_topk_mask(k, logits, 2))(keys)
    freq = np.asarray(masks.mean(0))
    np.testing.assert_allclose(freq, _pl_inclusion_top2(p), atol=tol)


@given(hnp.arrays(np.float32, (5,),
                  elements=st.floats(-1.5, 1.5, allow_nan=False)))
@settings(max_examples=5, deadline=None)
def test_gumbel_topk_matches_plackett_luce(logits):
    _check_gumbel_matches_pl(logits, draws=3000, tol=0.06)


@pytest.mark.slow
@given(hnp.arrays(np.float32, (6,),
                  elements=st.floats(-2.5, 2.5, allow_nan=False)))
@settings(max_examples=25, deadline=None)
def test_gumbel_topk_matches_plackett_luce_deep(logits):
    _check_gumbel_matches_pl(logits, draws=12000, tol=0.035)


# ---------------------------------------------------------------------------
# Availability: an unavailable client is never scheduled, by any method
# ---------------------------------------------------------------------------


def _check_never_scheduled(avail_bits, seed):
    n = len(avail_bits)
    key = jax.random.PRNGKey(seed)
    avail = jnp.asarray(avail_bits, jnp.float32)
    lam = jax.nn.softmax(jax.random.normal(key, (n,)))
    h = jnp.exp(jax.random.normal(jax.random.fold_in(key, 1), (n,))) + 0.05
    g = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (n,))) + 0.1
    for method in ("fedavg", "afl", "ca_afl", "greedy", "gca"):
        mask = select_clients(method, key, lam, h, 3, C=4.0, grad_norms=g,
                              avail=avail)
        viol = np.asarray(mask * (1.0 - avail))
        assert not viol.any(), method
        assert float(mask.sum()) <= max(float(avail.sum()), 3)


@given(hnp.arrays(np.int32, (9,), elements=st.integers(0, 1)),
       st.integers(0, 500))
@settings(max_examples=25, deadline=None)
def test_unavailable_never_scheduled_any_method(avail_bits, seed):
    """Holds for every availability pattern — including nobody available."""
    _check_never_scheduled(avail_bits, seed)


@pytest.mark.slow
@given(hnp.arrays(np.int32, st.integers(3, 40).map(lambda n: (n,)),
                  elements=st.integers(0, 1)),
       st.integers(0, 10_000))
@settings(max_examples=300, deadline=None)
def test_unavailable_never_scheduled_deep(avail_bits, seed):
    _check_never_scheduled(avail_bits, seed)
